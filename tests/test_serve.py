"""Serving-tier correctness (PR 6 tentpole).

The batched fused driver answers a [B] batch of point queries in ONE
dispatch over shared subgraph structure; convergence masking freezes
finished queries while stragglers run. The contract pinned here: every
query's values AND stats are bit-identical to a single-source `run_bsp`
call — across programs × drivers × compute backends, through the AOT
`BatchExecutable` path, and through the full `GraphQueryServer` loop
(admission queue, bucket padding, executable cache).
"""
import doctest

import numpy as np
import pytest

import repro.graph.engine as eng
import repro.serve.padding as padding
from repro.graph import algorithms as alg
from repro.serve.cache import ExecutableCache
from repro.serve.padding import DEFAULT_BUCKETS, bucket_size, pad_batch_rows, pad_items, padding_waste
from repro.serve.queue import AdmissionQueue, Query
from repro.serve.trace import synthetic_trace

from tests.test_drivers import assert_stats_equal

SOURCE_PROGRAMS = ("sssp", "bfs")
FREE_PROGRAMS = ("cc", "reach")


def _sources(graph, n: int) -> list:
    """n covered vertices spanning the degree range (hub first, leaf last)
    so batched queries converge at different supersteps."""
    cov = graph.covered_vertices()
    order = cov[np.argsort(-graph.degrees()[cov])]
    idx = np.linspace(0, len(order) - 1, n).astype(int)
    return [int(v) for v in order[idx]]


def _singles(sub, prog, sources=None, batch=None, driver="fused", backend="xla", **kw):
    if sources is not None:
        return [
            eng.run_bsp(sub, prog, source=s, driver=driver, compute_backend=backend, **kw)
            for s in sources
        ]
    return [
        eng.run_bsp(sub, prog, driver=driver, compute_backend=backend, **kw)
        for _ in range(batch)
    ]


def assert_batch_matches_singles(vals, stats, singles):
    assert vals.shape[0] == len(singles)
    for b, (v1, s1) in enumerate(singles):
        np.testing.assert_array_equal(np.asarray(vals[b]), np.asarray(v1), err_msg=f"query {b}")
        assert_stats_equal(stats[b], s1)


# ------------------------------------------------------------- padding


def test_padding_doctests():
    """The bucket-boundary examples in the docstrings are executable."""
    failures, tried = doctest.testmod(padding)
    assert failures == 0 and tried > 0


def test_bucket_size_boundaries():
    assert [bucket_size(n) for n in (1, 2, 3, 4, 5, 8, 9, 64)] == [1, 2, 4, 4, 8, 8, 16, 64]
    with pytest.raises(ValueError, match="64"):
        bucket_size(65)
    with pytest.raises(ValueError):
        bucket_size(0)
    assert bucket_size(3, buckets=(2, 6)) == 6


def test_padding_waste():
    assert padding_waste(8, 8) == 0.0
    assert padding_waste(3, 4) == pytest.approx(0.25)
    assert padding_waste(5, 8) == pytest.approx(3 / 8)


def test_pad_items_repeats_last_real_item():
    assert pad_items([7, 9], 4) == [7, 9, 9, 9]
    assert pad_items([1], 1) == [1]
    with pytest.raises(ValueError):
        pad_items([], 4)


def test_pad_batch_rows():
    x = np.arange(6).reshape(3, 2)
    y = pad_batch_rows(x, 4)
    assert y.shape == (4, 2)
    np.testing.assert_array_equal(y[:3], x)
    np.testing.assert_array_equal(y[3], x[2])  # last real row repeated
    np.testing.assert_array_equal(pad_batch_rows(x, 3), x)  # already at bucket


# ------------------------------------------- source validation (satellite)


def test_init_source_out_of_range_names_argument(built_small):
    g, _, sub = built_small
    for bad in (-1, g.num_vertices, 10**7):
        with pytest.raises(ValueError, match="source"):
            alg.sssp(sub, bad, num_vertices=g.num_vertices)
        with pytest.raises(ValueError, match="source"):
            alg.bfs(sub, bad, num_vertices=g.num_vertices)


def test_batched_bad_source_fails_fast(built_small):
    """One bad source in a batch fails BEFORE any init is built or any
    dispatch happens — it cannot poison the rest of the batch."""
    g, _, sub = built_small
    good = _sources(g, 2)
    before = eng.DISPATCH_COUNTS["batch"]
    with pytest.raises(ValueError, match=f"source={g.num_vertices}"):
        eng.run_bsp_batch(sub, "bfs", good + [g.num_vertices], num_vertices=g.num_vertices)
    assert eng.DISPATCH_COUNTS["batch"] == before


def test_batch_init_argument_errors(built_small):
    _, sub, _ = built_small
    with pytest.raises(ValueError, match="sources"):
        eng.batch_init("sssp", sub)  # source-rooted without sources
    with pytest.raises(ValueError, match="batch"):
        eng.batch_init("cc", sub)  # source-free without a batch size
    assert eng.batch_init("cc", sub, batch=3).shape[0] == 3


def test_batched_driver_rejects_staleness(built_small):
    _, sub, _ = built_small
    with pytest.raises(ValueError, match="exchange_period"):
        eng.run_bsp_batch(sub, "cc", batch=2, exchange_period=3)


# ------------------------------------------------------- batched parity


@pytest.mark.parametrize("B", (1, 3, 8))
@pytest.mark.parametrize("prog", SOURCE_PROGRAMS + FREE_PROGRAMS)
@pytest.mark.parametrize("driver", ("fused", "host"))
def test_batch_matches_singles_xla(built_small, prog, B, driver):
    """values + per-query stats bit-identical to B single runs, vs BOTH
    single-query drivers (which are themselves pinned equal)."""
    g, sub_sym, sub_dir = built_small
    sub = sub_dir if prog in SOURCE_PROGRAMS else sub_sym
    srcs = _sources(g, B) if prog in SOURCE_PROGRAMS else None
    vals, stats = eng.run_bsp_batch(
        sub, prog, srcs, batch=B, num_vertices=g.num_vertices
    )
    singles = _singles(sub, prog, srcs, batch=B, driver=driver, num_vertices=g.num_vertices)
    assert_batch_matches_singles(vals, stats, singles)


@pytest.mark.parametrize("backend", ("ref", "pallas"))
@pytest.mark.parametrize("prog", ("cc", "sssp"))
def test_batch_matches_singles_kernel_backends(built_small, prog, backend):
    g, sub_sym, sub_dir = built_small
    sub = sub_dir if prog in SOURCE_PROGRAMS else sub_sym
    srcs = _sources(g, 3) if prog in SOURCE_PROGRAMS else None
    vals, stats = eng.run_bsp_batch(
        sub, prog, srcs, batch=3, num_vertices=g.num_vertices, compute_backend=backend
    )
    singles = _singles(sub, prog, srcs, batch=3, backend=backend, num_vertices=g.num_vertices)
    assert_batch_matches_singles(vals, stats, singles)


def test_batch_pagerank_fixed_iters(built_small):
    """f32 whole-graph program: batched lanes bitwise-match single runs."""
    g, sub, _ = built_small
    vals, stats = eng.run_bsp_batch(
        sub, "pr", batch=3, max_supersteps=10, num_vertices=g.num_vertices
    )
    singles = _singles(sub, "pr", batch=3, max_supersteps=10, num_vertices=g.num_vertices)
    assert_batch_matches_singles(vals, stats, singles)


def test_masking_lets_stragglers_run(built_small):
    """A batch whose queries converge at DIFFERENT supersteps: each query
    reports the steps IT paid (not the batch max), finished queries stop
    sending messages, and values still bitwise-match single runs."""
    g, _, sub = built_small
    srcs = _sources(g, 4)
    singles = _singles(sub, "bfs", srcs, num_vertices=g.num_vertices)
    step_counts = [s.supersteps for _, s in singles]
    assert len(set(step_counts)) > 1, step_counts  # precondition: real straggler
    vals, stats = eng.run_bsp_batch(sub, "bfs", srcs, num_vertices=g.num_vertices)
    assert [s.supersteps for s in stats] == step_counts
    assert_batch_matches_singles(vals, stats, singles)
    # A finished query's message series is exactly its single-run series:
    # masking zeroed its lanes afterwards and assembly truncated them away.
    fastest = int(np.argmin(step_counts))
    np.testing.assert_array_equal(
        stats[fastest].messages_per_step, singles[fastest][1].messages_per_step
    )


def test_batch_single_dispatch(built_small):
    g, _, sub = built_small
    srcs = _sources(g, 3)
    eng.run_bsp_batch(sub, "bfs", srcs, num_vertices=g.num_vertices)  # warm
    base = dict(eng.DISPATCH_COUNTS)
    eng.run_bsp_batch(sub, "bfs", srcs, num_vertices=g.num_vertices)
    assert eng.DISPATCH_COUNTS["batch"] == base["batch"] + 1
    assert eng.DISPATCH_COUNTS["fused"] == base["fused"]
    assert eng.DISPATCH_COUNTS["host"] == base["host"]


# ------------------------------------------------------ AOT executables


def test_compiled_executable_matches_run_bsp_batch(built_small):
    g, _, sub = built_small
    srcs = _sources(g, 4)
    exe = eng.compile_batch_executable(sub, "bfs", 4, num_vertices=g.num_vertices)
    assert exe.compile_s > 0
    init = eng.batch_init("bfs", sub, srcs, num_vertices=g.num_vertices)
    vals, stats = exe.run(init)
    singles = _singles(sub, "bfs", srcs, num_vertices=g.num_vertices)
    assert_batch_matches_singles(vals, stats, singles)


def test_executable_rejects_wrong_batch(built_small):
    g, _, sub = built_small
    exe = eng.compile_batch_executable(sub, "bfs", 4, num_vertices=g.num_vertices)
    init = eng.batch_init("bfs", sub, _sources(g, 2), num_vertices=g.num_vertices)
    with pytest.raises(ValueError, match="pad the batch"):
        exe.run(init)


# ------------------------------------------------- queue / cache units


def _q(qid, t, program="bfs", source=0):
    return Query(qid=qid, program=program, source=source, t_arrival=t)


def test_admission_queue_full_flush():
    q = AdmissionQueue(max_batch=2, max_delay_s=1.0)
    q.push(_q(0, 0.0))
    assert q.pop_full() == []  # one query: lane not full yet
    q.push(_q(1, 0.1))
    (batch,) = q.pop_full()
    assert [x.qid for x in batch] == [0, 1]
    assert len(q) == 0


def test_admission_queue_deadline_flush():
    q = AdmissionQueue(max_batch=8, max_delay_s=0.5)
    q.push(_q(0, 0.0))
    q.push(_q(1, 0.2, program="cc", source=None))
    assert q.next_deadline() == pytest.approx(0.5)  # oldest head + delay
    assert q.pop_due(0.4) == []  # nobody has waited max_delay yet
    due = q.pop_due(0.5)
    assert [[x.qid for x in b] for b in due] == [[0]]  # bfs lane due, cc lane not
    assert len(q) == 1
    assert q.next_deadline() == pytest.approx(0.7)


def test_admission_queue_pop_all_and_program_lanes():
    q = AdmissionQueue(max_batch=8, max_delay_s=1.0)
    q.push(_q(0, 0.0, program="bfs"))
    q.push(_q(1, 0.0, program="sssp"))
    q.push(_q(2, 0.0, program="bfs"))
    batches = q.pop_all()
    assert sorted(sorted(x.qid for x in b) for b in batches) == [[0, 2], [1]]
    assert q.next_deadline() is None and len(q) == 0


def test_executable_cache_builds_once():
    cache = ExecutableCache()
    built = []
    for _ in range(5):
        cache.get(("bfs", 4), lambda: built.append(1) or object())
    assert len(built) == 1
    assert cache.misses == 1 and cache.hits == 4
    assert cache.hit_rate == pytest.approx(0.8)
    stats = cache.stats()
    assert stats["keys"] == 1 and stats["compiles_per_key_max"] == 1
    cache.get(("bfs", 8), lambda: object())
    assert cache.stats()["keys"] == 2
    assert cache.stats()["compiles_per_key_max"] == 1


# --------------------------------------------------------------- server


@pytest.fixture(scope="module")
def served_pipeline(small_powerlaw):
    from repro.api import GraphPipeline

    return GraphPipeline(small_powerlaw).partition("ebg", parts=4)


def test_server_answers_match_single_runs(served_pipeline):
    g = served_pipeline.graph
    srcs = _sources(g, 3)
    server = served_pipeline.serve(max_batch=4, max_delay_s=0.01)
    qids = [server.submit("bfs", s, at=0.0) for s in srcs]
    qid_cc = server.submit("cc", at=0.001)
    assert server.pump(now=1.0) == 4  # both lanes past deadline
    for qid, s in zip(qids, srcs):
        r = server.result(qid)
        single = served_pipeline.run("bfs", source=s)
        np.testing.assert_array_equal(r.values, single.values)  # padding lane discarded
        assert r.supersteps == single.stats.supersteps
        assert r.batch == 3 and r.bucket == 4  # padded 3 -> 4
        assert r.latency_s > 0
    np.testing.assert_array_equal(
        server.result(qid_cc).values, served_pipeline.run("cc").values
    )


def test_server_admission_validation(served_pipeline):
    server = served_pipeline.serve()
    with pytest.raises(ValueError, match="source"):
        server.submit("bfs", served_pipeline.graph.num_vertices)
    with pytest.raises(ValueError, match="whole-graph"):
        server.submit("cc", 5)
    assert len(server.queue) == 0  # rejected queries never enter the queue
    with pytest.raises(KeyError, match="still queued"):
        qid = server.submit("bfs", _sources(served_pipeline.graph, 1)[0])
        server.result(qid)


def test_server_full_batch_flushes_immediately(served_pipeline):
    srcs = _sources(served_pipeline.graph, 2)
    server = served_pipeline.serve(max_batch=2, max_delay_s=1e9)
    for s in srcs:
        server.submit("bfs", s, at=0.0)
    assert server.pump(now=0.0) == 2  # full lane fires with no deadline wait
    assert server.drain() == 0


def test_server_bucket_ladder_and_warm(served_pipeline):
    server = served_pipeline.serve(max_batch=8)
    assert server.buckets == (1, 2, 4, 8)
    compile_s = server.warm(["bfs"])
    assert compile_s > 0 and len(server.cache) == 4
    server.warm(["bfs"])  # second warm is all cache hits
    assert server.cache.stats()["compiles_per_key_max"] == 1
    with pytest.raises(ValueError, match="bucket"):
        served_pipeline.serve(max_batch=8, buckets=(1, 2, 4))


def test_run_trace_report(served_pipeline):
    g = served_pipeline.graph
    server = served_pipeline.serve(max_batch=4, max_delay_s=0.002)
    trace = synthetic_trace(g, 24, rate_qps=2000.0, mix=(("bfs", 0.7), ("cc", 0.3)), seed=1)
    assert len(trace) == 24 and all(t2 >= t1 for (t1, _, _), (t2, _, _) in zip(trace, trace[1:]))
    report = server.run_trace(trace)
    row = report.row()
    assert row["queries"] == 24
    assert row["throughput_qps"] > 0
    assert 0 <= row["latency_p50_s"] <= row["latency_p99_s"]
    assert 0 <= row["padding_waste"] < 1
    assert row["cache"]["compiles_per_key_max"] <= 1  # warm replay never recompiles
    assert row["batches"] >= 24 / 4
    # Trace answers are the same bits a cold single run produces.
    r = next(r for r in server._results.values() if r.program == "bfs")
    np.testing.assert_array_equal(
        r.values, served_pipeline.run("bfs", source=r.source).values
    )


# --------------------------------------------------------------- facade


def test_pipeline_run_batch_facade(served_pipeline):
    g = served_pipeline.graph
    srcs = _sources(g, 3)
    batch = served_pipeline.run_batch("bfs", srcs)
    assert len(batch) == 3 and batch.sources == tuple(srcs)
    singles = [served_pipeline.run("bfs", source=s) for s in srcs]
    for i in range(3):
        np.testing.assert_array_equal(batch.values[i], singles[i].values)
        assert_stats_equal(batch.stats[i], singles[i].stats)
        # query(i) is a full PipelineRun view, global scatter included.
        np.testing.assert_array_equal(
            batch.query(i).to_global(), singles[i].to_global()
        )
    np.testing.assert_array_equal(
        batch.supersteps_per_query, [s.stats.supersteps for s in singles]
    )


def test_pipeline_run_batch_validates_sources(served_pipeline):
    with pytest.raises(ValueError, match="source"):
        served_pipeline.run_batch("bfs", [0, -3])
