"""Tests for the `repro.api` registry + `GraphPipeline` facade."""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.api import (
    EBGConfig,
    EBVConfig,
    GraphPipeline,
    HashConfig,
    MetisLikeConfig,
    NEConfig,
    benchmark_partitioners,
    get_partitioner,
    list_partitioners,
    partitioner_names,
)
from repro.core import PARTITIONERS

ALL_NAMES = partitioner_names()


# ------------------------------------------------------------------ registry


def test_registry_discovers_all_partitioners():
    assert set(ALL_NAMES) == {
        "ebg", "ebg_chunked", "hdrf", "greedy", "dbh", "cvc", "ne", "metis", "hash"
    }


def test_legacy_dict_is_registry_view():
    """`repro.core.PARTITIONERS` is a live view of the registry — no
    hand-maintained dict, and late registrations stay visible."""
    from repro.api.config import PartitionerConfig
    from repro.api.registry import _REGISTRY, register_partitioner

    specs = {s.name: s for s in list_partitioners()}
    assert set(PARTITIONERS) == set(specs)
    for name, fn in PARTITIONERS.items():
        assert fn is specs[name].fn

    @register_partitioner("_test_late", config=PartitionerConfig, benchmark_default=False)
    def late(graph, num_parts):  # pragma: no cover - lookup only
        raise NotImplementedError

    try:
        assert PARTITIONERS["_test_late"] is late
        assert "_test_late" in PARTITIONERS
    finally:
        _REGISTRY.pop("_test_late")


def test_benchmark_enumeration_is_capability_driven():
    bench = benchmark_partitioners()
    assert "ebg" in bench and "dbh" in bench
    # the paper's streaming baselines ride in the default comparison table
    assert "hdrf" in bench and "greedy" in bench
    # variants/baselines flagged out of the default suite stay registered
    assert "ebg_chunked" not in bench and "hash" not in bench
    assert set(bench) <= set(ALL_NAMES)


def test_capability_flags():
    assert get_partitioner("ebg").jit_compatible
    assert get_partitioner("ebg_chunked").chunked
    assert not get_partitioner("ne").jit_compatible
    assert all(s.deterministic for s in list_partitioners())


def test_unknown_partitioner_raises():
    with pytest.raises(KeyError, match="unknown partitioner"):
        get_partitioner("nope")


# ------------------------------------------------------------------- configs


def test_config_validation_raises_value_error():
    with pytest.raises(ValueError):
        EBGConfig(alpha=-1.0)
    with pytest.raises(ValueError):
        EBGConfig(beta=0.0)
    with pytest.raises(ValueError):
        EBGConfig(block=0)
    with pytest.raises(ValueError):
        HashConfig(seed=-3)
    with pytest.raises(ValueError):
        NEConfig(seed=-1)
    with pytest.raises(ValueError):
        MetisLikeConfig(coarsen_to=1)


def test_ebv_alias_is_paper_name():
    assert EBVConfig is EBGConfig


def test_config_replace_revalidates():
    cfg = EBGConfig(alpha=2.0)
    assert cfg.replace(beta=3.0).beta == 3.0
    with pytest.raises(ValueError):
        cfg.replace(alpha=-2.0)


def test_bad_num_parts_raises(tiny_powerlaw):
    with pytest.raises(ValueError):
        get_partitioner("ebg").partition(tiny_powerlaw, 0)
    with pytest.raises(ValueError):
        GraphPipeline(tiny_powerlaw).partition("ebg", parts=-2)
    with pytest.raises(ValueError):
        GraphPipeline(tiny_powerlaw).partition("ebg", parts=2, alpha=-1.0)


def test_wrong_config_type_raises(tiny_powerlaw):
    with pytest.raises(TypeError):
        GraphPipeline(tiny_powerlaw).partition("hash", parts=4, config=EBGConfig())
    with pytest.raises(TypeError):
        GraphPipeline(tiny_powerlaw).partition("hash", parts=4, alpha=2.0)


def test_override_unused_by_algorithm_raises(tiny_powerlaw):
    """`block` is a valid EBGConfig field but the unblocked scan ignores it —
    naming it explicitly must error, not silently no-op."""
    with pytest.raises(ValueError, match="does not use"):
        GraphPipeline(tiny_powerlaw).partition("ebg", parts=4, block=1024)
    # ...while the chunked variant consumes it.
    pipe = GraphPipeline(tiny_powerlaw).partition("ebg_chunked", parts=4, block=64)
    assert pipe.config.block == 64


# ------------------------------------------------------------------ pipeline


@pytest.mark.parametrize("name", ALL_NAMES)
def test_every_partitioner_end_to_end_through_pipeline(tiny_powerlaw, name):
    """Each registered partitioner runs partition → build → CC → metrics."""
    run = GraphPipeline(tiny_powerlaw).partition(name, parts=4).run("cc")
    m = run.metrics
    assert m.replication_factor >= 1.0 - 1e-9
    assert m.edges_per_part.sum() == tiny_powerlaw.num_edges
    assert m.edge_imbalance >= 1.0 and m.vertex_imbalance >= 1.0
    assert run.stats.supersteps >= 1 and run.stats.total_messages > 0
    assert run.values.shape[0] == 4
    assert run.edges_per_worker.sum() == 2 * tiny_powerlaw.num_edges  # CC symmetrizes


def test_pipeline_cc_matches_reference(tiny_powerlaw):
    from repro.graph import algorithms as alg

    run = GraphPipeline(tiny_powerlaw).partition("ebg", parts=4).run("cc")
    glob = run.to_global()
    ref = alg.cc_reference(tiny_powerlaw)
    cov = tiny_powerlaw.covered_vertices()
    np.testing.assert_array_equal(glob[cov], ref[cov])


def test_pipeline_stages_are_cached(tiny_powerlaw):
    pipe = GraphPipeline(tiny_powerlaw).partition("ebg", parts=4)
    assert pipe.result is pipe.result
    assert pipe.metrics is pipe.metrics
    sym = pipe.build(symmetrize=True)
    assert sym.subgraphs is sym.subgraphs
    # build cache is shared across fluent views, keyed by build params
    assert sym.subgraphs is pipe.subgraphs_for(symmetrize=True)
    assert sym.subgraphs is not pipe.subgraphs_for(symmetrize=False)
    # a run without explicit build reuses the program-default build
    assert pipe.run("cc").subgraphs is sym.subgraphs


def test_explicit_pad_multiple_overrides_pinned_build(tiny_powerlaw):
    pipe = GraphPipeline(tiny_powerlaw).partition("ebg", parts=4).build(symmetrize=True)
    run = pipe.run("cc", pad_multiple=16)
    assert run.subgraphs.max_e % 16 == 0
    assert run.subgraphs is pipe.subgraphs_for(symmetrize=True, pad_multiple=16)


def test_clear_builds_keeps_partition_and_metrics(tiny_powerlaw):
    pipe = GraphPipeline(tiny_powerlaw).partition("ebg", parts=4)
    result, metrics = pipe.result, pipe.metrics
    first = pipe.subgraphs_for(symmetrize=True)
    pipe.clear_builds()
    assert pipe.result is result and pipe.metrics is metrics
    assert pipe.subgraphs_for(symmetrize=True) is not first


def test_pipeline_run_programs(tiny_powerlaw):
    pipe = GraphPipeline(tiny_powerlaw).partition("ebg", parts=4)
    sssp = pipe.run("sssp")
    assert np.isfinite(sssp.to_global()[pipe.default_source()])
    pr = pipe.run("pr", num_iters=5)
    total = pr.to_global(reduce="min")
    cov = tiny_powerlaw.covered_vertices()
    assert np.isfinite(total[cov]).all()
    with pytest.raises(ValueError):
        pipe.run("not_a_program")
    with pytest.raises(ValueError):
        pipe.run("cc", mode="warp")


def test_program_instances_accepted_initless_rejected(tiny_powerlaw):
    """`.run` takes registered names OR VertexProgram instances; an instance
    without an init_fn cannot produce initial values through the facade."""
    from repro.graph.engine import CC, SSSP, VertexProgram

    pipe = GraphPipeline(tiny_powerlaw).partition("ebg", parts=4)
    by_obj = pipe.run(CC)
    by_name = pipe.run("cc")
    np.testing.assert_array_equal(by_obj.values, by_name.values)
    assert pipe.run(SSSP).program == "sssp"
    with pytest.raises(ValueError, match="init_fn"):
        pipe.run(VertexProgram(name="custom_noinit", dtype="int32"))


def test_graph_validate_raises_value_error():
    """Graph.validate raises ValueError naming the offending field (it used
    bare `assert`s that vanish under `python -O`)."""
    from repro.core.types import Graph

    src = np.array([0, 1], np.int32)
    Graph(src=src, dst=np.array([1, 0], np.int32), num_vertices=2).validate()
    with pytest.raises(ValueError, match="dst.*num_vertices"):
        Graph(src=src, dst=np.array([1, 7], np.int32), num_vertices=2).validate()
    with pytest.raises(ValueError, match="src has negative"):
        Graph(src=np.array([-1, 0], np.int32), dst=src, num_vertices=2).validate()
    with pytest.raises(ValueError, match="same shape"):
        Graph(src=src, dst=np.array([0], np.int32), num_vertices=2).validate()


def test_pipeline_requires_partition_stage(tiny_powerlaw):
    with pytest.raises(RuntimeError, match="partition"):
        GraphPipeline(tiny_powerlaw).run("cc")


# --------------------------------------------------------------------- shims


@pytest.mark.parametrize("name", ALL_NAMES)
def test_legacy_entry_points_match_pipeline_bit_for_bit(tiny_powerlaw, name):
    """`PARTITIONERS[name](g, p)` and the registry/pipeline path agree
    exactly — the shim is behavior-preserving."""
    legacy = PARTITIONERS[name](tiny_powerlaw, 8)
    piped = GraphPipeline(tiny_powerlaw).partition(name, parts=8).result
    np.testing.assert_array_equal(legacy.part_in_input_order(), piped.part_in_input_order())


def test_chunked_pad_edges_not_committed(paper_example):
    """Single-block runs with and without pad edges assign real edges
    identically: pads are masked out of the commit loop and the balance
    normalization uses the real |E|."""
    from repro.core import ebg_partition_chunked

    E = paper_example.num_edges  # 12
    no_pad = ebg_partition_chunked(paper_example, 2, block=E)
    padded = ebg_partition_chunked(paper_example, 2, block=E + 4)
    np.testing.assert_array_equal(np.asarray(no_pad.part), np.asarray(padded.part))


# ------------------------------------------------------------------- dry-run


def test_abstract_spec_shapes():
    from repro.api import SubgraphSpec
    from repro.graph.engine import CC

    spec = SubgraphSpec(num_parts=4, max_v=16, max_e=32, max_msg=8)
    arrays, statics = spec.array_specs()
    assert arrays["lsrc"].shape == (4, 32)
    assert arrays["send_idx"].shape == (4, 4, 8)
    assert statics == dict(
        num_parts=4, max_v=16, max_e=32, max_msg=8, addressing="two_level"
    )
    assert spec.value_spec(CC).shape == (4, 17)


def test_spec_of_built_subgraphs(tiny_powerlaw):
    from repro.api import SubgraphSpec

    pipe = GraphPipeline(tiny_powerlaw).partition("ebg", parts=4)
    sub = pipe.build(symmetrize=True).subgraphs
    spec = SubgraphSpec.of(sub)
    assert spec.num_parts == 4
    assert spec.max_v == sub.max_v and spec.max_e == sub.max_e


def test_dist_mode_and_lower_match_sim():
    """mode='dist' + .lower() need >1 device; XLA locks the device count at
    first init, so this runs in a subprocess (same mechanism as
    tests/test_system.py)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run(
        [sys.executable, "-c", """
import numpy as np
from repro.api import GraphPipeline
from repro.graph.generate import make_graph
from repro.launch.mesh import make_host_mesh

g = make_graph('tiny_powerlaw')
pipe = GraphPipeline(g).partition('ebg', parts=4)
mesh = make_host_mesh(4)
sim = pipe.run('cc')
dist = pipe.run('cc', mode='dist', mesh=mesh, num_supersteps=10, inner_cap=100)
np.testing.assert_array_equal(sim.values, dist.values)
assert dist.stats.total_messages > 0
try:
    pipe.run('cc', mode='dist', mesh=make_host_mesh(2), num_supersteps=2)
except ValueError as e:
    assert 'parts' in str(e)
else:
    raise AssertionError('mesh/parts mismatch not caught')
low = pipe.lower(mesh=mesh, program='cc', num_supersteps=2, inner_cap=8)
assert low.compiled.memory_analysis() is not None
print('OK')
"""],
        capture_output=True, text=True, env=env, timeout=560,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "OK" in out.stdout
