"""`VertexProgram` unification suite (PR 4 tentpole).

One generic superstep / fused driver / host driver / distributed stepper
run every program. Pins: the new programs (BFS hop-count, max-label
reachability) against numpy host oracles across all compute backends and
both sim drivers; the max-combine negation path; distributed PageRank
(previously rejected) matching sim-mode bit-for-bit with full stats
equality — including the previously-zeroed `comp_work_per_worker`; and the
program registry surface.
"""
import dataclasses
import os
import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

import repro.graph.engine as eng
from repro.graph import algorithms as alg
from repro.kernels import ops, ref

BACKENDS = ("xla", "ref", "pallas")

I32_INF = 2**31 - 1


def _source(g):
    cov = g.covered_vertices()
    return int(cov[np.argmax(g.degrees()[cov])])


def assert_stats_equal(a: eng.BSPStats, b: eng.BSPStats):
    assert a.supersteps == b.supersteps
    np.testing.assert_array_equal(a.messages_per_worker, b.messages_per_worker)
    np.testing.assert_array_equal(a.messages_per_step, b.messages_per_step)
    np.testing.assert_array_equal(a.messages_per_step_worker, b.messages_per_step_worker)
    np.testing.assert_array_equal(a.inner_iters_per_step, b.inner_iters_per_step)
    np.testing.assert_array_equal(a.comp_work_per_worker, b.comp_work_per_worker)


# ------------------------------------------------------------- registry


def test_registry_stock_programs():
    assert eng.program_names() == ("bfs", "cc", "pr", "reach", "sssp")
    assert eng.get_program("pagerank") is eng.PR
    assert eng.get_program("connected_components") is eng.CC
    assert eng.get_program("reachability") is eng.REACH
    assert eng.get_program(eng.BFS) is eng.BFS  # instances pass through
    with pytest.raises(ValueError, match="unknown program"):
        eng.get_program("not_a_program")
    with pytest.raises(ValueError, match="already registered"):
        eng.register_program(dataclasses.replace(eng.CC, aliases=()))


def test_rejected_registration_leaves_registry_untouched():
    """A later-alias collision must not half-register the program."""
    bad = dataclasses.replace(eng.CC, name="_pr4_tmp", aliases=("cc",))
    with pytest.raises(ValueError, match="already registered"):
        eng.register_program(bad)
    assert "_pr4_tmp" not in eng.PROGRAMS
    with pytest.raises(ValueError, match="unknown program"):
        eng.get_program("_pr4_tmp")


def test_pagerank_default_steps_is_twenty(built_small):
    """A bare facade/engine PageRank run keeps the classic 20-power-iteration
    default (not the generic 200-superstep fixpoint budget)."""
    g, _, sub = built_small
    assert eng.PR.default_steps == 20
    _, stats = alg.run_program(sub, eng.PR, num_vertices=g.num_vertices)
    assert stats.supersteps == 20


def test_pagerank_without_num_vertices_raises(built_small):
    g, _, sub = built_small
    with pytest.raises(ValueError, match="num_vertices"):
        alg.run_program(sub, eng.PR)


def test_source_rooted_program_without_source_raises(built_small):
    _, _, sub = built_small
    for prog in (eng.SSSP, eng.BFS):
        with pytest.raises(ValueError, match="source"):
            alg.run_program(sub, prog)


def test_registry_lookup_is_case_insensitive(built_small):
    """Registered keys are lowercased to match get_program's lookup, so a
    MixedCase custom name stays reachable."""
    mixed = dataclasses.replace(eng.CC, name="Pr4CaseCheck", aliases=())
    try:
        eng.register_program(mixed)
        assert eng.get_program("Pr4CaseCheck") is mixed
        assert eng.get_program("pr4casecheck") is mixed
    finally:
        eng.PROGRAMS.pop("pr4casecheck", None)


def test_vertex_program_validation():
    with pytest.raises(ValueError, match="combine"):
        eng.VertexProgram(name="x", dtype="int32", combine="xor")
    with pytest.raises(ValueError, match="dtype"):
        eng.VertexProgram(name="x", dtype="int8")
    with pytest.raises(ValueError, match="sweep"):
        eng.VertexProgram(name="x", dtype="float32", combine="sum", local="fixpoint")
    with pytest.raises(ValueError, match="sum"):
        eng.VertexProgram(name="x", dtype="float32", apply="pagerank", combine="min")


def test_program_identities():
    assert int(eng.CC.identity) == I32_INF
    assert int(eng.REACH.identity) == -I32_INF
    assert float(eng.PR.identity) == 0.0
    assert float(eng.SSSP.identity) == float(eng.INF_F32)


def test_exchange_period_rejected_for_sweep_programs(built_small):
    g, _, sub = built_small
    with pytest.raises(ValueError, match="exchange_period"):
        alg.pagerank(sub, g.num_vertices, exchange_period=2)


# --------------------------------------------- new programs vs host oracles


@pytest.mark.parametrize("backend", BACKENDS)
def test_bfs_matches_oracle(built_small, backend):
    g, _, sub = built_small
    src_v = _source(g)
    ref_hops = alg.bfs_reference(g, src_v)
    cov = g.covered_vertices()
    hops, stats = alg.bfs(sub, src_v, compute_backend=backend)
    glob = alg.scatter_to_global(sub, hops, g.num_vertices)
    np.testing.assert_array_equal(glob[cov].astype(np.int64), ref_hops[cov])
    assert stats.supersteps >= 1 and stats.total_messages > 0


@pytest.mark.parametrize("backend", BACKENDS)
def test_reachability_matches_oracle(built_small, backend):
    g, sub, _ = built_small
    ref_lab = alg.reachability_reference(g)
    cov = g.covered_vertices()
    lab, stats = alg.reachability(sub, compute_backend=backend)
    glob = alg.scatter_to_global(sub, lab, g.num_vertices)
    np.testing.assert_array_equal(glob[cov].astype(np.int64), ref_lab[cov])
    assert stats.total_messages > 0


@pytest.mark.parametrize("prog", ["bfs", "reach"])
def test_new_programs_fused_matches_host(built_small, prog):
    g, sub_sym, sub_dir = built_small
    if prog == "bfs":
        run = lambda d: alg.bfs(sub_dir, _source(g), driver=d)
    else:
        run = lambda d: alg.reachability(sub_sym, driver=d)
    h, sh = run("host")
    f, sf = run("fused")
    np.testing.assert_array_equal(f, h)  # exact int32
    assert_stats_equal(sf, sh)


def test_reach_bounded_staleness_same_fixpoint(built_small):
    """Max-combine is monotone too: bounded staleness converges to the same
    fixpoint through the negation path."""
    _, sub, _ = built_small
    a, _ = alg.reachability(sub)
    b, stats = alg.reachability(sub, exchange_period=3, inner_cap=2)
    np.testing.assert_array_equal(a, b)
    assert stats.supersteps >= 1


def test_reach_labels_partition_like_cc(built_small):
    """Reachability labels induce the same vertex partition as CC labels
    (both are per-component constants on the undirected view)."""
    g, sub, _ = built_small
    cov = g.covered_vertices()
    cc = alg.scatter_to_global(sub, alg.connected_components(sub)[0], g.num_vertices)[cov]
    rc = alg.scatter_to_global(sub, alg.reachability(sub)[0], g.num_vertices)[cov]
    assert len(np.unique(cc)) == len(np.unique(rc))
    # same grouping: each CC label maps to exactly one reach label
    pairs = {(int(a), int(b)) for a, b in zip(cc, rc)}
    assert len(pairs) == len(np.unique(cc))


def test_run_program_accepts_names_and_instances(built_small):
    _, sub, _ = built_small
    by_name, _ = alg.run_program(sub, "cc")
    by_inst, _ = alg.run_program(sub, eng.CC)
    np.testing.assert_array_equal(by_name, by_inst)


def test_custom_program_through_generic_driver(built_small):
    """The abstraction holds for programs the repo never shipped: min-plus
    over DOUBLED edge weights is SSSP with distances scaled by 2."""
    g, _, sub = built_small
    src_v = _source(g)
    base, _ = alg.sssp(sub, src_v)
    doubled = dataclasses.replace(eng.SSSP, name="sssp2x")
    sub2 = dataclasses.replace(sub, weight=sub.weight * 2.0, weight_s=sub.weight_s * 2.0)
    got, _ = alg.run_program(sub2, doubled, source=src_v)
    fin = base < 1e38
    np.testing.assert_allclose(got[fin], base[fin] * 2.0)


# ----------------------------------------------------- facade integration


def test_pipeline_runs_new_programs(small_powerlaw):
    from repro.api import GraphPipeline

    pipe = GraphPipeline(small_powerlaw).partition("ebg", parts=4)
    cov = small_powerlaw.covered_vertices()
    b = pipe.run("bfs")  # default source = highest-degree covered vertex
    assert b.program == "bfs"
    glob = b.to_global()
    ref_hops = alg.bfs_reference(small_powerlaw, pipe.default_source())
    np.testing.assert_array_equal(glob[cov].astype(np.int64), ref_hops[cov])
    r = pipe.run("reach")
    glob = r.to_global()
    np.testing.assert_array_equal(
        glob[cov].astype(np.int64), alg.reachability_reference(small_powerlaw)[cov]
    )
    # reach symmetrizes by default (bidirectional), bfs keeps direction
    assert r.subgraphs is pipe.subgraphs_for(symmetrize=True)
    assert b.subgraphs is pipe.subgraphs_for(symmetrize=False)


# ------------------------------------------------------- max-combine kernel


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_segment_max_matches_numpy(impl):
    """ops.segment_max — the max-combine entry point — must agree with the
    numpy scatter-max oracle; it runs on the min-plus kernels via negation."""
    rng = np.random.default_rng(31)
    E, num_out = 200, 33
    ldst = np.sort(rng.integers(0, num_out - 1, E)).astype(np.int32)
    lsrc = rng.integers(0, num_out - 1, E).astype(np.int32)
    w = np.where(rng.random(E) < 0.2, float(ref.INF), 0.0).astype(np.float32)  # some pads
    val = ((rng.random(num_out) - 0.5) * 10).astype(np.float32)
    want = val.copy()
    live = w < float(ref.INF)
    np.maximum.at(want, ldst[live], val[lsrc[live]])
    got = ops.segment_max(
        jnp.array(lsrc), jnp.array(ldst), jnp.array(w), jnp.array(val),
        num_out=num_out, impl=impl, block_e=64,
    )
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6)


# ------------------------------------------------- distributed (subprocess)


def test_distributed_any_program_matches_sim():
    """Distributed PageRank (previously `mode='dist' supports min-semiring
    programs only`), BFS, and reachability all run through the ONE
    distributed stepper and match sim-mode values AND stats exactly —
    including `comp_work_per_worker`, which dist mode used to zero out.
    Needs >1 device, so it runs in a subprocess (same mechanism as
    tests/test_system.py)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run(
        [sys.executable, "-c", """
import numpy as np
from repro.api import GraphPipeline
from repro.graph.generate import make_graph
from repro.launch.mesh import make_host_mesh

g = make_graph('tiny_powerlaw')
pipe = GraphPipeline(g).partition('ebg', parts=4)
mesh = make_host_mesh(4)

def stats_eq(a, b, what):
    assert a.supersteps == b.supersteps, what
    np.testing.assert_array_equal(a.messages_per_worker, b.messages_per_worker, err_msg=what)
    np.testing.assert_array_equal(a.messages_per_step_worker, b.messages_per_step_worker, err_msg=what)
    np.testing.assert_array_equal(a.inner_iters_per_step, b.inner_iters_per_step, err_msg=what)
    np.testing.assert_array_equal(a.comp_work_per_worker, b.comp_work_per_worker, err_msg=what)
    assert a.comp_work_per_worker.sum() > 0, what  # the dist zeroing bug

sim = pipe.run('pr', num_iters=10)
dist = pipe.run('pr', mode='dist', mesh=mesh, num_iters=10)
np.testing.assert_array_equal(sim.values, dist.values)
stats_eq(sim.stats, dist.stats, 'pr')

for prog in ('cc', 'bfs', 'reach'):
    s = pipe.run(prog)
    d = pipe.run(prog, mode='dist', mesh=mesh, num_supersteps=30)
    np.testing.assert_array_equal(s.values, d.values, err_msg=prog)
    stats_eq(s.stats, d.stats, prog)

low = pipe.lower(mesh=mesh, program='pr', num_supersteps=2)
assert low.compiled.memory_analysis() is not None and low.program == 'pr'
print('OK')
"""],
        capture_output=True, text=True, env=env, timeout=560,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "OK" in out.stdout
