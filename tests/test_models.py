"""Per-arch smoke tests (reduced configs) + decode-vs-full consistency +
SSD correctness + config parameter counts vs published sizes."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models.config import ModelConfig
from repro.models.ssm import ssd_chunked
from repro.models.steps import make_serve_step, make_train_step
from repro.models.transformer import forward, init_caches, init_params
from repro.optim.adam import AdamWConfig, init_opt_state

B, S = 2, 32
OPT = AdamWConfig(warmup_steps=2, total_steps=10)


def _batch(cfg: ModelConfig, rng):
    batch = dict(targets=jnp.zeros((B, S), jnp.int32))
    if cfg.frontend:
        batch["embeds"] = jnp.array(rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    else:
        batch["tokens"] = jnp.array(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    if cfg.is_encdec:
        batch["enc_embeds"] = jnp.array(rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_smoke_train_and_decode(arch):
    """One train step + one decode step on a reduced same-family config:
    output shapes correct, no NaNs."""
    cfg = configs.reduced_config(arch)
    rng = np.random.default_rng(0)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    opt_state = init_opt_state(params, OPT)
    batch = _batch(cfg, rng)
    params, opt_state, metrics = jax.jit(make_train_step(cfg, OPT))(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    caches = init_caches(cfg, B, 64, jnp.float32)
    dbatch = dict(pos=jnp.int32(0))
    if cfg.frontend:
        dbatch["embed"] = jnp.array(rng.standard_normal((B, 1, cfg.d_model)), jnp.float32)
    else:
        dbatch["token"] = jnp.zeros((B, 1), jnp.int32)
    if cfg.is_encdec:
        dbatch["enc_embeds"] = batch["enc_embeds"]
    logits, _ = jax.jit(make_serve_step(cfg))(params, caches, dbatch)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize(
    "arch", ["llama3_2_3b", "gemma2_27b", "mamba2_780m", "jamba_1_5_large",
             "phi3_5_moe", "qwen2_vl_2b", "seamless_m4t_large_v2", "qwen3_4b"]
)
def test_decode_matches_full_forward(arch):
    cfg = configs.reduced_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(1), jnp.float32)
    rng = np.random.default_rng(0)
    kw = {}
    if cfg.frontend:
        kw["embeds"] = jnp.array(rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    else:
        kw["tokens"] = jnp.array(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    if cfg.is_encdec:
        kw["enc_embeds"] = jnp.array(rng.standard_normal((B, 8, cfg.d_model)), jnp.float32)
    logits_full, _ = forward(cfg, params, **kw, remat=False)
    half = S // 2
    caches = init_caches(cfg, B, S, jnp.float32)
    kw_pre = dict(kw)
    for key in ("tokens", "embeds"):
        if key in kw:
            kw_pre[key] = kw[key][:, :half]
    logits, caches = forward(cfg, params, **kw_pre, caches=caches, cache_pos=jnp.int32(0), remat=False)
    outs = [logits]
    for t in range(half, S):
        kw_t = {k: v for k, v in kw.items() if k == "enc_embeds"}
        for key in ("tokens", "embeds"):
            if key in kw:
                kw_t[key] = kw[key][:, t : t + 1]
        lg, caches = forward(cfg, params, **kw_t, caches=caches, cache_pos=jnp.int32(t), remat=False)
        outs.append(lg)
    err = float(jnp.abs(jnp.concatenate(outs, axis=1) - logits_full).max())
    assert err < 2e-3, f"{arch}: {err}"


def test_ssd_chunked_matches_sequential():
    rng = np.random.default_rng(0)
    b, s, h, p, n = 2, 64, 3, 8, 16
    x = jnp.array(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.array(rng.random((b, s, h)) * 0.5 + 0.05, jnp.float32)
    A = jnp.array(-np.exp(rng.standard_normal(h) * 0.3), jnp.float32)
    Bm = jnp.array(rng.standard_normal((b, s, n)) * 0.5, jnp.float32)
    Cm = jnp.array(rng.standard_normal((b, s, n)) * 0.5, jnp.float32)
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        dec = jnp.exp(dt[:, t] * A[None, :])
        state = state * dec[..., None, None] + jnp.einsum("bh,bn,bhp->bhpn", dt[:, t], Bm[:, t], x[:, t])
        ys.append(jnp.einsum("bn,bhpn->bhp", Cm[:, t], state))
    y_ref = jnp.stack(ys, 1)
    for chunk in (8, 32, 64):
        y, st = ssd_chunked(x, dt, A, Bm, Cm, chunk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(st), np.asarray(state), rtol=2e-4, atol=2e-4)


def test_published_param_counts():
    """Configs must land near the published model sizes."""
    expect = {
        "llama3_2_3b": 3.2e9,
        "qwen2_72b": 72.7e9,
        "gemma2_27b": 27.2e9,
        "qwen3_4b": 4.0e9,
        "phi3_5_moe": 41.9e9,
        "kimi_k2": 1.04e12,
        "jamba_1_5_large": 398e9,
        "mamba2_780m": 0.78e9,
    }
    for arch, n in expect.items():
        got = configs.get_config(arch).num_params()
        assert abs(got - n) / n < 0.06, (arch, got, n)
    # active params for the MoEs
    assert abs(configs.get_config("kimi_k2").num_active_params() - 31e9) / 31e9 < 0.1
    assert abs(configs.get_config("phi3_5_moe").num_active_params() - 6.6e9) / 6.6e9 < 0.05


def test_shape_skip_rules():
    assert "long_500k" in configs.runnable_shapes("mamba2_780m")
    assert "long_500k" in configs.runnable_shapes("jamba_1_5_large")
    assert "long_500k" not in configs.runnable_shapes("llama3_2_3b")
    assert "long_500k" not in configs.runnable_shapes("gemma2_27b")
    for a in configs.ARCHS:
        assert "train_4k" in configs.runnable_shapes(a)
