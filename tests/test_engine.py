"""BSP engine integration tests: CC/SSSP/PR vs host oracles, message
accounting, bounded staleness, per-partitioner correctness."""
import numpy as np
import pytest

from repro.core import PARTITIONERS
from repro.graph import algorithms as alg
from repro.graph.build import build_subgraphs


@pytest.fixture(scope="module", params=["ebg", "dbh", "ne", "metis"])
def built(request, tiny_powerlaw):
    res = PARTITIONERS[request.param](tiny_powerlaw, 4)
    sub_sym = build_subgraphs(tiny_powerlaw, res, symmetrize=True)
    sub_dir = build_subgraphs(tiny_powerlaw, res, symmetrize=False)
    return tiny_powerlaw, sub_sym, sub_dir


def _covered(g):
    return np.unique(np.concatenate([np.asarray(g.src), np.asarray(g.dst)]))


def test_cc(built):
    g, sub, _ = built
    labels, stats = alg.connected_components(sub)
    glob = alg.scatter_to_global(sub, labels, g.num_vertices)
    ref = alg.cc_reference(g)
    cov = _covered(g)
    np.testing.assert_array_equal(glob[cov], ref[cov])
    assert stats.supersteps >= 1 and stats.total_messages > 0


def test_sssp(built):
    g, _, sub = built
    cov = _covered(g)
    src_vtx = int(cov[np.argmax(g.degrees()[cov])])
    dist, _ = alg.sssp(sub, src_vtx)
    glob = alg.scatter_to_global(sub, dist, g.num_vertices)
    ref = alg.sssp_reference(g, src_vtx)
    reach_ref = ref[cov] < np.inf
    reach_got = glob[cov] < 1e38
    np.testing.assert_array_equal(reach_got, reach_ref)
    np.testing.assert_allclose(glob[cov][reach_ref], ref[cov][reach_ref])


def test_pagerank(built):
    g, _, sub = built
    pr, stats = alg.pagerank(sub, g.num_vertices, num_iters=12)
    glob = alg.scatter_to_global(sub, pr, g.num_vertices, reduce="min")
    ref = alg.pagerank_reference(g, num_iters=12)
    cov = _covered(g)
    np.testing.assert_allclose(glob[cov], ref[cov], rtol=1e-5, atol=1e-8)
    # PR sends every superstep: messages = supersteps × 2 × #mirror-links
    assert stats.total_messages > 0


def test_bounded_staleness_same_fixpoint(tiny_powerlaw):
    res = PARTITIONERS["ebg"](tiny_powerlaw, 4)
    sub = build_subgraphs(tiny_powerlaw, res, symmetrize=True)
    a, stats_a = alg.connected_components(sub)
    b, stats_b = alg.connected_components(sub, exchange_period=3, inner_cap=2)
    np.testing.assert_array_equal(a, b)
    # staleness trades supersteps for fewer exchanges
    assert stats_b.supersteps >= stats_a.supersteps


def test_message_counts_scale_with_replication(tiny_powerlaw):
    """Paper Table IV: message count tracks the replication factor."""
    from repro.core import partition_metrics

    msgs, reps = {}, {}
    for name in ("ebg", "hash"):
        res = PARTITIONERS[name](tiny_powerlaw, 8)
        reps[name] = partition_metrics(tiny_powerlaw, res).replication_factor
        sub = build_subgraphs(tiny_powerlaw, res, symmetrize=True)
        _, stats = alg.connected_components(sub)
        msgs[name] = stats.total_messages
    assert reps["hash"] > reps["ebg"]
    assert msgs["hash"] > msgs["ebg"]
