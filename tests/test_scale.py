"""Scaling-past-2^24 suite: on-disk edge shards, the out-of-core
partition pipeline, the streamed builder, and two-level addressing.

Everything here runs on downscaled twins of the large-graph pipeline —
the oracles are the in-memory implementations, asserted bit-for-bit:

  * shard store roundtrip / external degrees / external §IV-C order
  * out-of-core partition == in-memory chunked partition (per scorer,
    backend, commit mode; sharded state layout == replicated)
  * streamed two-pass builder == vectorized in-memory builder (bitwise)
  * end-to-end: shards -> partition -> streamed build -> CC == in-memory
  * the 2^24 guard boundary: flat addressing raises at exactly 2^24,
    passes at 2^24 - 1; two-level passes both on every backend
  * vectorized generators == their legacy samplers (fixed seed)
  * resilient crash/resume carries the two-level value codec through the
    checkpoint
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import outofcore as oc
from repro.core.streaming import degree_sum_order, streaming_chunked_partition
from repro.data import edgeshards as es
from repro.graph import engine as eng
from repro.graph.build import build_subgraphs
from repro.graph.build_stream import build_subgraphs_stream
from repro.graph.generate import barabasi, barabasi_legacy, rmat

V, E, P = 1 << 10, 1 << 12, 4


@pytest.fixture(scope="module")
def graph():
    return rmat(V, E, seed=3)


@pytest.fixture(scope="module")
def store(graph, tmp_path_factory):
    path = tmp_path_factory.mktemp("shards") / "store"
    return es.write_graph(graph, path, shard_edges=500)  # >= 4 shards


# ------------------------------------------------------------ shard store


def test_store_roundtrip_and_manifest(graph, store):
    assert store.num_shards >= 4
    g2 = es.load_graph(store)
    np.testing.assert_array_equal(np.asarray(graph.src, np.int64), g2.src)
    np.testing.assert_array_equal(np.asarray(graph.dst, np.int64), g2.dst)
    assert g2.num_vertices == V
    # manifest: shard edge counts sum to E; every shard carries its
    # log2-bucketed degree histogram (#distinct endpoints, bucketed)
    assert sum(s["num_edges"] for s in store.shards) == graph.num_edges
    for s in store.shards:
        assert sum(s["degree_hist"]) >= 1


def test_iter_blocks_spans_shards(graph, store):
    ss, ii = [], []
    for s, d, i in store.iter_blocks(333):  # not a divisor of shard size
        assert s.shape == d.shape == i.shape
        ss.append(s)
        ii.append(i)
    np.testing.assert_array_equal(np.concatenate(ss), np.asarray(graph.src, np.int64))
    np.testing.assert_array_equal(np.concatenate(ii), np.arange(graph.num_edges))


def test_degrees_from_shards(graph, store):
    np.testing.assert_array_equal(es.degrees_from_shards(store), graph.degrees())


def test_external_degree_sum_order(graph, store, tmp_path):
    stream = es.degree_sum_stream(store, workdir=tmp_path / "order")
    try:
        assert stream.num_buckets >= 1
        np.testing.assert_array_equal(
            stream.permutation(), np.asarray(degree_sum_order(graph), np.int64)
        )
    finally:
        stream.cleanup()


def test_rmat_to_store_deterministic_and_valid(tmp_path):
    s1 = es.rmat_to_store(tmp_path / "r1", V, E, seed=7, shard_edges=700, chunk=900)
    s2 = es.rmat_to_store(tmp_path / "r2", V, E, seed=7, shard_edges=700, chunk=900)
    ga, gb = es.load_graph(s1), es.load_graph(s2)
    np.testing.assert_array_equal(np.asarray(ga.src), np.asarray(gb.src))
    np.testing.assert_array_equal(np.asarray(ga.dst), np.asarray(gb.dst))
    assert ga.num_edges == E
    key = np.asarray(ga.src, np.int64) * V + np.asarray(ga.dst, np.int64)
    assert np.all(np.diff(key) > 0)  # key-sorted, deduped, no self loops
    assert np.all(key // V != key % V)


# -------------------------------------------- out-of-core == in-memory


@pytest.mark.parametrize("commit", ("frozen", "window"))
@pytest.mark.parametrize(
    "scorer,backend",
    [("ebv", "xla"), ("ebv", "ref"), ("hdrf", "xla"), ("hdrf", "ref"), ("greedy", "xla")],
)
def test_partition_store_matches_in_memory(graph, store, tmp_path, scorer, backend, commit):
    r_mem = streaming_chunked_partition(
        graph, P, scorer, block=128, compute_backend=backend, commit=commit
    )
    r_oc = oc.partition_store(
        store, P, scorer, block=128, compute_backend=backend, commit=commit,
        order_workdir=tmp_path / "order",
    )
    np.testing.assert_array_equal(np.asarray(r_mem.part), np.asarray(r_oc.result.part))
    np.testing.assert_array_equal(
        np.asarray(r_mem.part_in_input_order()),
        np.asarray(r_oc.result.part_in_input_order()),
    )
    assert r_oc.replication_factor >= 1.0


def test_sharded_state_layout_matches_replicated(store, tmp_path):
    r_rep = oc.partition_store(store, P, "ebv", block=128, order_workdir=tmp_path / "a")
    r_sh = oc.partition_store(
        store, P, "ebv", block=128, state_layout="sharded", order_workdir=tmp_path / "b"
    )
    np.testing.assert_array_equal(np.asarray(r_rep.result.part), np.asarray(r_sh.result.part))
    np.testing.assert_array_equal(r_rep.e_count, r_sh.e_count)
    np.testing.assert_array_equal(r_rep.v_count, r_sh.v_count)


def test_edge_part_stream_replays_every_edge(graph, store, tmp_path):
    r_oc = oc.partition_store(store, P, "ebv", block=128, order_workdir=tmp_path / "o")
    total = 0
    for s, d, pt in r_oc.edge_part_stream(200):
        assert s.shape == d.shape == pt.shape
        assert pt.min() >= 0 and pt.max() < P
        total += s.shape[0]
    assert total == graph.num_edges


# ------------------------------------------------------ streamed builder


@pytest.mark.parametrize("symmetrize", (False, True))
def test_build_stream_bitwise_equals_in_memory(graph, store, tmp_path, symmetrize):
    r_oc = oc.partition_store(store, P, "ebv", block=128, order_workdir=tmp_path / "o")
    part_in = r_oc.result.part_in_input_order().astype(np.int64)

    def factory():
        for s, d, i in store.iter_blocks(300):
            yield s, d, part_in[i]

    a = build_subgraphs(graph, r_oc.result, symmetrize=symmetrize)
    b = build_subgraphs_stream(factory, V, P, symmetrize=symmetrize)
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, (int, str)):
            assert va == vb, f.name
        else:
            np.testing.assert_array_equal(np.asarray(va), np.asarray(vb), err_msg=f.name)
    assert b.addressing == "two_level"
    l2g = b.local_to_global
    assert l2g.dtype == np.int64 and l2g.shape == (P, b.max_v)


def test_end_to_end_out_of_core_cc_matches_in_memory(graph, store, tmp_path):
    """shards -> external order -> out-of-core partition -> streamed build
    -> CC, against the fully in-memory pipeline on the same graph."""
    r_mem = streaming_chunked_partition(graph, P, "ebv", block=128)
    sub_mem = build_subgraphs(graph, r_mem, symmetrize=True)
    val_mem, stats_mem = eng.run_bsp(sub_mem, "cc")

    r_oc = oc.partition_store(store, P, "ebv", block=128, order_workdir=tmp_path / "o")
    part_in = r_oc.result.part_in_input_order().astype(np.int64)

    def factory():
        for s, d, i in store.iter_blocks(300):
            yield s, d, part_in[i]

    sub_oc = build_subgraphs_stream(factory, V, P, symmetrize=True)
    val_oc, stats_oc = eng.run_bsp(sub_oc, "cc")
    np.testing.assert_array_equal(np.asarray(val_mem), np.asarray(val_oc))
    assert stats_mem.supersteps == stats_oc.supersteps


# ----------------------------------------------------- the 2^24 boundary


@pytest.fixture(scope="module")
def boundary_subs():
    """The same tiny subgraph set with gids shifted so max(gid) sits at
    exactly 2^24 - 1 (`below`) and exactly 2^24 (`at`)."""
    g = rmat(256, 1024, seed=3)
    res = streaming_chunked_partition(g, P, "ebv")
    sub = build_subgraphs(g, res, symmetrize=True)
    maxg = int(jnp.max(sub.gid))
    out = {}
    for name, top in (("below", (1 << 24) - 1), ("at", 1 << 24)):
        shift = top - maxg
        out[name] = dataclasses.replace(
            sub, gid=jnp.where(sub.vmask, sub.gid + shift, sub.gid)
        )
    return out


@pytest.mark.parametrize("backend", ("xla", "ref", "pallas"))
def test_flat_guard_boundary(boundary_subs, backend):
    """Flat addressing: ids up to 2^24 - 1 pass every backend; the first
    id at 2^24 raises the named ValueError on kernel backends only."""
    below = dataclasses.replace(boundary_subs["below"], addressing="flat")
    at = dataclasses.replace(boundary_subs["at"], addressing="flat")
    val, _ = eng.run_bsp(below, "cc", compute_backend=backend)
    assert int(jnp.max(jnp.where(below.vmask, val[:, : below.max_v], 0))) < 1 << 24
    if backend == "xla":
        eng.run_bsp(at, "cc", compute_backend=backend)  # xla is exact: no guard
    else:
        with pytest.raises(ValueError, match="vertex ids"):
            eng.run_bsp(at, "cc", compute_backend=backend)


@pytest.mark.parametrize("backend", ("xla", "ref", "pallas"))
def test_two_level_passes_boundary(boundary_subs, backend):
    """Two-level addressing: the same 2^24-id graph runs clean on every
    backend and agrees with the exact xla labels bit-for-bit."""
    at = boundary_subs["at"]
    assert at.addressing == "two_level"
    val, _ = eng.run_bsp(at, "cc", compute_backend=backend)
    val_x, _ = eng.run_bsp(at, "cc", compute_backend="xla")
    np.testing.assert_array_equal(np.asarray(val), np.asarray(val_x))


def test_two_level_bfs_value_bound(boundary_subs):
    """BFS on big gids: hop counts stay tiny, so two-level runs clean on
    kernel backends where the flat gid guard would refuse."""
    at = boundary_subs["at"]
    val_r, _ = eng.run_bsp(at, "bfs", source=0, compute_backend="ref")
    val_x, _ = eng.run_bsp(at, "bfs", source=0, compute_backend="xla")
    np.testing.assert_array_equal(np.asarray(val_r), np.asarray(val_x))


def test_builder_rejects_past_engine_ceiling():
    with pytest.raises(ValueError, match="engine ceiling"):
        build_subgraphs_stream(lambda: iter(()), (1 << 31) + 8, P)


# ------------------------------------------------- vectorized generators


@pytest.mark.parametrize("v,attach,seed", [(200, 8, 0), (500, 4, 7), (300, 16, 2)])
def test_barabasi_matches_legacy(v, attach, seed):
    g1 = barabasi(v, attach, seed=seed)
    g2 = barabasi_legacy(v, attach, seed=seed)
    np.testing.assert_array_equal(np.asarray(g1.src), np.asarray(g2.src))
    np.testing.assert_array_equal(np.asarray(g1.dst), np.asarray(g2.dst))
    assert g1.num_vertices == g2.num_vertices


# ------------------------------------------- codec through checkpoints


def test_resilient_resume_restores_value_codec(boundary_subs, tmp_path):
    """Crash/resume on a 2^24-id two-level run: the rank codec rides in
    the checkpoint, so the resumed kernel-backend run decodes to the
    uninterrupted labels."""
    from repro.resilience import FaultPlan, WorkerCrashError
    from repro.resilience.bsp import resume_bsp

    at = boundary_subs["at"]
    base_val, base_stats = eng.run_bsp(at, "cc", compute_backend="ref")
    crash_at = max(1, base_stats.supersteps // 2)
    ckpt = tmp_path / "ckpt"
    with pytest.raises(WorkerCrashError):
        eng.run_bsp(
            at, "cc", compute_backend="ref", checkpoint_every=1, ckpt_dir=ckpt,
            fault_plan=FaultPlan(seed=3, crash_at_superstep=crash_at),
        )
    val, stats = resume_bsp(at, ckpt_dir=ckpt, compute_backend="ref")
    np.testing.assert_array_equal(np.asarray(val), np.asarray(base_val))
    assert stats.supersteps == base_stats.supersteps
