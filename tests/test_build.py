"""Vectorized `build_subgraphs` vs the legacy per-part-loop builder.

The vectorized builder must reproduce the legacy output BIT-FOR-BIT —
same dtypes, same padding, same intra-part edge order (stable dst/src
sorts), same exchange-table slot layout — on power-law and road-like
graphs, with and without symmetrization/weights, including master-election
tie-break cases.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import PARTITIONERS
from repro.core.types import Graph, PartitionResult
from repro.graph.build import SubgraphSet, build_subgraphs, build_subgraphs_legacy

_FIELDS = [f.name for f in dataclasses.fields(SubgraphSet)]


def assert_bit_identical(a: SubgraphSet, b: SubgraphSet):
    for name in _FIELDS:
        x, y = getattr(a, name), getattr(b, name)
        if isinstance(x, int):
            assert x == y, name
            continue
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype, name
        np.testing.assert_array_equal(x, y, err_msg=name)


@pytest.mark.parametrize("graph_key", ["tiny_powerlaw", "tiny_road"])
@pytest.mark.parametrize("partitioner", ["ebg", "hash", "metis"])
@pytest.mark.parametrize("symmetrize", [False, True])
def test_vectorized_matches_legacy(request, graph_key, partitioner, symmetrize):
    g = request.getfixturevalue(graph_key)
    res = PARTITIONERS[partitioner](g, 6)
    a = build_subgraphs(g, res, symmetrize=symmetrize)
    b = build_subgraphs_legacy(g, res, symmetrize=symmetrize)
    assert_bit_identical(a, b)


def test_vectorized_matches_legacy_weights_and_padding(tiny_powerlaw):
    res = PARTITIONERS["dbh"](tiny_powerlaw, 5)
    w = np.random.default_rng(7).random(tiny_powerlaw.num_edges).astype(np.float32)
    for pad in (1, 4, 16):
        a = build_subgraphs(tiny_powerlaw, res, weights=w, symmetrize=True, pad_multiple=pad)
        b = build_subgraphs_legacy(tiny_powerlaw, res, weights=w, symmetrize=True, pad_multiple=pad)
        assert_bit_identical(a, b)


def test_master_election_tie_breaks(paper_example):
    """Vertices covered by several parts with EQUAL incident-endpoint counts
    must elect the same (lowest-id) master in both builders."""
    # Hand-crafted assignment: vertex 0 appears in parts 0/1/2 with equal
    # counts; vertices 1 and 2 tie between two parts each.
    E = paper_example.num_edges  # 12 directed edges (6 undirected)
    part = np.array([0, 1, 2, 0, 1, 2] * 2, dtype=np.int32)[:E]
    res = PartitionResult(part=part, num_parts=3)
    a = build_subgraphs(paper_example, res, symmetrize=False)
    b = build_subgraphs_legacy(paper_example, res, symmetrize=False)
    assert_bit_identical(a, b)
    # every covered vertex has exactly one master replica (all 6 covered)
    assert int(np.asarray(a.is_master).sum()) == 6


def test_duplicate_edges_and_singleton_parts():
    """Duplicate edges, an empty part, and a part with a single self-edge —
    the degenerate layouts the padding paths must agree on."""
    src = np.array([0, 0, 0, 1, 2, 2], np.int32)
    dst = np.array([1, 1, 1, 2, 0, 2], np.int32)
    g = Graph(src=src, dst=dst, num_vertices=5)  # vertices 3, 4 uncovered
    part = np.array([0, 0, 1, 1, 1, 3], np.int32)  # part 2 empty
    res = PartitionResult(part=part, num_parts=4)
    for sym in (False, True):
        a = build_subgraphs(g, res, symmetrize=sym)
        b = build_subgraphs_legacy(g, res, symmetrize=sym)
        assert_bit_identical(a, b)


def test_partition_order_permutation_respected(tiny_powerlaw):
    """PartitionResult.order (EBG's degree-sum permutation) must be mapped
    back identically by both builders."""
    res = PARTITIONERS["ebg"](tiny_powerlaw, 4)
    assert res.order is not None
    assert_bit_identical(
        build_subgraphs(tiny_powerlaw, res, symmetrize=True),
        build_subgraphs_legacy(tiny_powerlaw, res, symmetrize=True),
    )
