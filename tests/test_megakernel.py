"""Megakernel coverage matrix (PR 9 tentpole).

`ops.bsp_superstep` — the per-worker Pallas superstep megakernel — must be
BIT-identical to the ref oracle (values AND per-worker iteration counts)
for the full VertexProgram combine vocabulary across block sizes and
interpret modes, including edge streams that do not divide `block_e` and
tail blocks of pure padding. At the engine level the pallas backend must be
bit-identical — values and BSPStats — to the xla path for all five
registered programs at every `block_e`. And the speculative window commit
must make the chunked partition driver bit-identical to the
one-edge-at-a-time scan for every registered scorer on every backend.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PARTITIONERS, ebg_partition_chunked
from repro.core.streaming import streaming_chunked_partition, streaming_scan_partition
from repro.core.types import Graph
from repro.graph import algorithms as alg
from repro.kernels import dispatch, ops, ref

BLOCKS = (1, 64, 256)
PROGRAMS = ("cc", "bfs", "sssp", "reach", "pr")
SCORERS = ("ebv", "hdrf", "greedy")


def _stats_equal(a, b):
    assert a.supersteps == b.supersteps
    np.testing.assert_array_equal(a.messages_per_worker, b.messages_per_worker)
    np.testing.assert_array_equal(a.messages_per_step, b.messages_per_step)
    np.testing.assert_array_equal(a.messages_per_step_worker, b.messages_per_step_worker)
    np.testing.assert_array_equal(a.inner_iters_per_step, b.inner_iters_per_step)
    np.testing.assert_array_equal(a.comp_work_per_worker, b.comp_work_per_worker)


# ------------------------------------------------- ops-level bit parity


def _streams(seed=0, p=4, V=33, E=77):
    """Random [p, E] edge streams; E=77 divides none of BLOCKS, so the
    wrapper's batched block padding is live in every pallas run."""
    rng = np.random.default_rng(seed)
    lsrc = jnp.asarray(rng.integers(0, V, (p, E)), jnp.int32)
    ldst = jnp.asarray(np.sort(rng.integers(0, V, (p, E)), axis=1), jnp.int32)
    w = jnp.asarray(rng.random((p, E), np.float32) + 0.1, jnp.float32)
    val = jnp.asarray(rng.random((p, V), np.float32) * 10, jnp.float32)
    deg = jnp.asarray(rng.integers(0, 5, (p, V)), jnp.float32)
    return lsrc, ldst, w, val, deg


@pytest.mark.parametrize("interpret", [True, None], ids=["interpret", "sniffed"])
@pytest.mark.parametrize("combine", ["min", "max", "sum"])
def test_ops_bsp_superstep_bit_parity(combine, interpret):
    lsrc, ldst, w, val, deg = _streams()
    kw = dict(num_out=33, combine=combine, inner_cap=7)
    if combine == "sum":
        kw["out_degree"] = deg
    r_out, r_it = ops.bsp_superstep(lsrc, ldst, w, val, impl="ref", **kw)
    for block_e in BLOCKS:
        p_out, p_it = ops.bsp_superstep(
            lsrc, ldst, w, val, impl="pallas", interpret=interpret, block_e=block_e, **kw
        )
        np.testing.assert_array_equal(np.asarray(p_out), np.asarray(r_out),
                                      err_msg=f"{combine} values @ block_e={block_e}")
        np.testing.assert_array_equal(np.asarray(p_it), np.asarray(r_it),
                                      err_msg=f"{combine} iters @ block_e={block_e}")


@pytest.mark.parametrize("combine", ["min", "sum"])
def test_ops_all_padded_tail_block(combine):
    """A caller-supplied tail block of nothing but identity-weight edges at
    the dump slot must be a no-op for the accumulator AND the convergence
    flag (an all-pad block must not keep the fixpoint loop spinning)."""
    rng = np.random.default_rng(5)
    p, V, block = 2, 17, 64
    identity = 0.0 if combine == "sum" else float(ref.INF)
    lsrc = jnp.asarray(np.concatenate(
        [rng.integers(0, V, (p, block)), np.zeros((p, block))], axis=1), jnp.int32)
    ldst = jnp.asarray(np.concatenate(
        [np.sort(rng.integers(0, V - 1, (p, block)), axis=1),
         np.full((p, block), V - 1)], axis=1), jnp.int32)
    w = jnp.asarray(np.concatenate(
        [rng.random((p, block), np.float32) + 0.1,
         np.full((p, block), identity, np.float32)], axis=1), jnp.float32)
    val = jnp.asarray(rng.random((p, V), np.float32) * 10, jnp.float32)
    kw = dict(num_out=V, combine=combine, inner_cap=5)
    if combine == "sum":
        kw["out_degree"] = jnp.asarray(rng.integers(0, 5, (p, V)), jnp.float32)
    r_out, r_it = ops.bsp_superstep(lsrc, ldst, w, val, impl="ref", **kw)
    p_out, p_it = ops.bsp_superstep(
        lsrc, ldst, w, val, impl="pallas", interpret=True, block_e=block, **kw
    )
    np.testing.assert_array_equal(np.asarray(p_out), np.asarray(r_out))
    np.testing.assert_array_equal(np.asarray(p_it), np.asarray(r_it))


# ------------------------------------------- engine-level program parity


def _run(name, built, backend, block_e, driver="fused"):
    g, sub_sym, sub_dir = built
    kw = dict(compute_backend=backend, block_e=block_e, driver=driver)
    if name == "cc":
        return alg.connected_components(sub_sym, **kw)
    if name == "reach":
        return alg.reachability(sub_sym, **kw)
    if name == "pr":
        return alg.pagerank(sub_dir, g.num_vertices, num_iters=5, **kw)
    cov = g.covered_vertices()
    src_v = int(cov[np.argmax(g.degrees()[cov])])
    return (alg.bfs if name == "bfs" else alg.sssp)(sub_dir, src_v, **kw)


@pytest.mark.parametrize("name", PROGRAMS)
def test_engine_megakernel_parity_across_blocks(built_small, name):
    """compute_backend="pallas" (megakernel) ≡ "xla" ≡ "ref": bit-identical
    values and BSPStats at every block_e — the acceptance pin for routing
    the fused driver through ops.bsp_superstep."""
    xla_vals, xla_stats = _run(name, built_small, "xla", 512)
    ref_vals, ref_stats = _run(name, built_small, "ref", 512)
    np.testing.assert_array_equal(np.asarray(ref_vals), np.asarray(xla_vals))
    _stats_equal(ref_stats, xla_stats)
    for block_e in BLOCKS:
        vals, stats = _run(name, built_small, "pallas", block_e)
        np.testing.assert_array_equal(np.asarray(vals), np.asarray(xla_vals),
                                      err_msg=f"{name} @ block_e={block_e}")
        _stats_equal(stats, xla_stats)


def test_host_driver_threads_block_e(built_small):
    """block_e reaches the per-superstep host driver too (it rides the
    _jit_superstep_sim statics, not just the fused loop's)."""
    _, sub, _ = built_small
    base_vals, base_stats = alg.connected_components(sub, driver="host")
    for block_e in (1, 256):
        vals, stats = alg.connected_components(
            sub, driver="host", compute_backend="pallas", block_e=block_e
        )
        np.testing.assert_array_equal(np.asarray(vals), np.asarray(base_vals))
        _stats_equal(stats, base_stats)


# ------------------------------------------------ window-commit ≡ scan


def _rand_graph(seed=7, V=200, E=900):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, V, E).astype(np.int32)
    dst = rng.integers(0, V, E).astype(np.int32)
    m = src != dst
    return Graph(src=src[m], dst=dst[m], num_vertices=V)


@pytest.mark.parametrize("scorer", SCORERS)
def test_window_commit_matches_scan(scorer):
    g, p = _rand_graph(), 8
    scan = np.asarray(streaming_scan_partition(g, p, scorer).part)
    for backend in ("xla", "ref", "pallas"):
        for block in BLOCKS:
            win = np.asarray(streaming_chunked_partition(
                g, p, scorer, block=block, compute_backend=backend, commit="window"
            ).part)
            np.testing.assert_array_equal(
                win, scan, err_msg=f"{scorer}/{backend}/block={block}"
            )


def test_frozen_commit_actually_diverges():
    """Discriminator: the window≡scan pin above would be vacuous if frozen
    block commits already matched the scan on this graph."""
    g, p = _rand_graph(), 8
    diverged = False
    for scorer in SCORERS:
        scan = np.asarray(streaming_scan_partition(g, p, scorer).part)
        frz = np.asarray(streaming_chunked_partition(
            g, p, scorer, block=256, commit="frozen"
        ).part)
        diverged |= bool((frz != scan).any())
    assert diverged, "frozen==scan for every scorer: graph too easy to discriminate"


def test_ebg_chunked_window_equals_faithful_partitioner():
    """The registered partitioners surface the commit knob: ebg_chunked
    with commit="window" reproduces the faithful ebg scan exactly."""
    g, p = _rand_graph(11), 8
    scan = np.asarray(PARTITIONERS["ebg"](g, p).part)
    win = np.asarray(ebg_partition_chunked(g, p, block=64, commit="window").part)
    np.testing.assert_array_equal(win, scan)


def test_commit_mode_validation():
    from repro.api.config import EBGConfig

    g = _rand_graph(3, V=20, E=40)
    with pytest.raises(ValueError, match="commit"):
        streaming_chunked_partition(g, 4, "ebv", commit="optimistic")
    with pytest.raises(ValueError, match="commit"):
        EBGConfig(commit="optimistic")


# --------------------------------------------- dispatch platform cache


def test_platform_sniff_cached_once(monkeypatch):
    calls = {"n": 0}
    real = jax.default_backend

    def counting():
        calls["n"] += 1
        return real()

    monkeypatch.setattr(dispatch.jax, "default_backend", counting)
    dispatch.set_platform_is_tpu(None)  # drop the cache -> next call re-sniffs
    try:
        first = dispatch.default_interpret(None)
        for _ in range(5):
            assert dispatch.default_interpret(None) == first
        assert calls["n"] == 1  # one sniff per process, not per resolution
        dispatch.set_platform_is_tpu(True)  # forced platform: no re-sniff
        assert dispatch.default_interpret(None) is False
        assert dispatch.default_interpret(True) is True  # explicit wins
        dispatch.set_platform_is_tpu(False)
        assert dispatch.default_interpret(None) is True
        assert dispatch.default_interpret(False) is False
        assert calls["n"] == 1
    finally:
        dispatch.set_platform_is_tpu(None)  # other tests re-sniff the real backend
