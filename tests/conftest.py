import numpy as np
import pytest

from repro.core.types import Graph
from repro.graph.generate import make_graph, rmat


@pytest.fixture(scope="session")
def tiny_powerlaw() -> Graph:
    return make_graph("tiny_powerlaw")


@pytest.fixture(scope="session")
def small_powerlaw() -> Graph:
    """Smaller-than-tiny power-law graph: keeps pallas-interpret engine
    runs fast (shared by the backend-parity and driver-parity suites)."""
    return rmat(256, 1024, seed=3)


@pytest.fixture(scope="session")
def built_small(small_powerlaw):
    """(graph, symmetrized SubgraphSet, directed SubgraphSet) on the EBG
    4-part partition of `small_powerlaw`."""
    from repro.core import PARTITIONERS
    from repro.graph.build import build_subgraphs

    res = PARTITIONERS["ebg"](small_powerlaw, 4)
    sub_sym = build_subgraphs(small_powerlaw, res, symmetrize=True)
    sub_dir = build_subgraphs(small_powerlaw, res, symmetrize=False)
    return small_powerlaw, sub_sym, sub_dir


@pytest.fixture(scope="session")
def tiny_road() -> Graph:
    return make_graph("tiny_road")


@pytest.fixture(scope="session")
def paper_example() -> Graph:
    """The 6-vertex undirected example from the paper's Fig. 1 / App. B.

    Vertices A..F = 0..5; undirected edges {AB, AC, AD, AE, AF, BC}
    stored as two directed edges each (paper §III).
    """
    und = [(0, 1), (0, 2), (0, 3), (0, 4), (0, 5), (1, 2)]
    src = np.array([u for u, v in und] + [v for u, v in und], np.int32)
    dst = np.array([v for u, v in und] + [u for u, v in und], np.int32)
    return Graph(src=src, dst=dst, num_vertices=6)
