import numpy as np
import pytest

from repro.core.types import Graph
from repro.graph.generate import make_graph


@pytest.fixture(scope="session")
def tiny_powerlaw() -> Graph:
    return make_graph("tiny_powerlaw")


@pytest.fixture(scope="session")
def tiny_road() -> Graph:
    return make_graph("tiny_road")


@pytest.fixture(scope="session")
def paper_example() -> Graph:
    """The 6-vertex undirected example from the paper's Fig. 1 / App. B.

    Vertices A..F = 0..5; undirected edges {AB, AC, AD, AE, AF, BC}
    stored as two directed edges each (paper §III).
    """
    und = [(0, 1), (0, 2), (0, 3), (0, 4), (0, 5), (1, 2)]
    src = np.array([u for u, v in und] + [v for u, v in und], np.int32)
    dst = np.array([v for u, v in und] + [u for u, v in und], np.int32)
    return Graph(src=src, dst=dst, num_vertices=6)
