"""Unit tests for the paper's partitioners (EBG + baselines)."""
import numpy as np
import pytest

from repro.core import (
    PARTITIONERS,
    cvc_partition,
    dbh_partition,
    degree_sum_order,
    ebg_partition,
    ebg_partition_chunked,
    ebg_partition_np,
    metis_like_partition,
    ne_partition,
    partition_metrics,
    random_hash_partition,
)

ALL = list(PARTITIONERS)


@pytest.mark.parametrize("name", ALL)
def test_every_edge_assigned_exactly_once(tiny_powerlaw, name):
    p = 8
    res = PARTITIONERS[name](tiny_powerlaw, p)
    part = res.part_in_input_order()
    assert part.shape == (tiny_powerlaw.num_edges,)
    assert part.min() >= 0 and part.max() < p


def test_jax_ebg_matches_numpy_oracle(tiny_powerlaw):
    for p in (2, 5, 8):
        a = ebg_partition(tiny_powerlaw, p)
        b = ebg_partition_np(tiny_powerlaw, p)
        np.testing.assert_array_equal(np.asarray(a.part), b.part)


def test_chunked_block1_equals_faithful(tiny_powerlaw):
    a = ebg_partition(tiny_powerlaw, 4)
    b = ebg_partition_chunked(tiny_powerlaw, 4, block=1)
    np.testing.assert_array_equal(np.asarray(a.part), np.asarray(b.part))


def test_chunked_quality_close(tiny_powerlaw):
    base = partition_metrics(tiny_powerlaw, ebg_partition(tiny_powerlaw, 8))
    chnk = partition_metrics(tiny_powerlaw, ebg_partition_chunked(tiny_powerlaw, 8, block=256))
    assert chnk.replication_factor < base.replication_factor * 1.10
    assert chnk.edge_imbalance < 1.2


def test_degree_sum_order(paper_example):
    order = degree_sum_order(paper_example)
    deg = paper_example.degrees()
    src = np.asarray(paper_example.src)
    dst = np.asarray(paper_example.dst)
    keys = deg[src[order]] + deg[dst[order]]
    assert (np.diff(keys) >= 0).all()


def test_paper_example_partition(paper_example):
    """Appendix B: EBG on the Fig.1 graph cuts exactly one vertex (A) and
    groups {AB, AC, BC} vs {AD, AE, AF} — up to subgraph relabeling."""
    res = ebg_partition(paper_example, 2)
    m = partition_metrics(paper_example, res)
    # one replicated vertex → rep factor = 7/6
    assert abs(m.replication_factor - 7 / 6) < 1e-6
    assert m.edge_imbalance == 1.0
    part = res.part_in_input_order()
    src = np.asarray(paper_example.src)
    dst = np.asarray(paper_example.dst)
    groups = {}
    for e in range(len(part)):
        key = frozenset((int(src[e]), int(dst[e])))
        groups.setdefault(key, set()).add(int(part[e]))
    # both directions of each undirected edge land in the same subgraph
    assert all(len(v) == 1 for v in groups.values())
    spoke = {frozenset(p) for p in [(0, 3), (0, 4), (0, 5)]}
    tri = {frozenset(p) for p in [(0, 1), (0, 2), (1, 2)]}
    lab = {next(iter(groups[k])) for k in spoke}
    lab2 = {next(iter(groups[k])) for k in tri}
    assert len(lab) == 1 and len(lab2) == 1 and lab != lab2


def test_ebg_alpha_beta_sensitivity(tiny_powerlaw):
    """Large alpha/beta should tighten balance at the cost of replication."""
    loose = partition_metrics(tiny_powerlaw, ebg_partition(tiny_powerlaw, 8, alpha=0.1, beta=0.1))
    tight = partition_metrics(tiny_powerlaw, ebg_partition(tiny_powerlaw, 8, alpha=10.0, beta=10.0))
    assert tight.edge_imbalance <= loose.edge_imbalance + 1e-9
    assert tight.replication_factor >= loose.replication_factor - 1e-9


def test_paper_qualitative_claims(tiny_powerlaw):
    """Table III pattern: EBG < min(DBH, CVC) on replication; NE edge-balanced
    but vertex-imbalanced; hash worst replication."""
    p = 8
    ebg = partition_metrics(tiny_powerlaw, ebg_partition(tiny_powerlaw, p))
    dbh = partition_metrics(tiny_powerlaw, dbh_partition(tiny_powerlaw, p))
    cvc = partition_metrics(tiny_powerlaw, cvc_partition(tiny_powerlaw, p))
    ne = partition_metrics(tiny_powerlaw, ne_partition(tiny_powerlaw, p))
    hsh = partition_metrics(tiny_powerlaw, random_hash_partition(tiny_powerlaw, p))
    assert ebg.replication_factor < min(dbh.replication_factor, cvc.replication_factor)
    assert ebg.edge_imbalance < 1.15 and ebg.vertex_imbalance < 1.15
    assert ne.edge_imbalance < 1.05
    assert ne.vertex_imbalance > ebg.vertex_imbalance
    assert hsh.replication_factor > ebg.replication_factor


def test_metis_like_on_road_vs_powerlaw(tiny_road, tiny_powerlaw):
    """The paper's METIS pathology: fine on road-like graphs, edge-imbalanced
    on power-law graphs."""
    road = partition_metrics(tiny_road, metis_like_partition(tiny_road, 8))
    pl = partition_metrics(tiny_powerlaw, metis_like_partition(tiny_powerlaw, 8))
    assert road.replication_factor < 1.6
    assert pl.edge_imbalance > road.edge_imbalance
