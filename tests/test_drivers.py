"""Fused-vs-host driver equivalence (PR 3 tentpole).

The fused drivers run the whole BSP loop as one jitted lax.while_loop and
sync with the host once per run; the host drivers dispatch one jitted
superstep per Python iteration. Final values, superstep counts, and every
per-step / per-worker stat series must be identical across CC/SSSP/PR ×
compute backends — and the fused path must cost exactly one dispatch.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.graph.engine as eng
from repro.graph import algorithms as alg

BACKENDS = ("xla", "ref", "pallas")


def assert_stats_equal(a: eng.BSPStats, b: eng.BSPStats):
    assert a.supersteps == b.supersteps
    np.testing.assert_array_equal(a.messages_per_worker, b.messages_per_worker)
    np.testing.assert_array_equal(a.messages_per_step, b.messages_per_step)
    np.testing.assert_array_equal(a.messages_per_step_worker, b.messages_per_step_worker)
    np.testing.assert_array_equal(a.inner_iters_per_step, b.inner_iters_per_step)
    np.testing.assert_array_equal(a.comp_work_per_worker, b.comp_work_per_worker)


@pytest.mark.parametrize("backend", BACKENDS)
def test_cc_fused_matches_host(built_small, backend):
    _, sub, _ = built_small
    h, sh = alg.connected_components(sub, driver="host", compute_backend=backend)
    f, sf = alg.connected_components(sub, driver="fused", compute_backend=backend)
    np.testing.assert_array_equal(f, h)  # exact int32 labels
    assert_stats_equal(sf, sh)


@pytest.mark.parametrize("backend", BACKENDS)
def test_sssp_fused_matches_host(built_small, backend):
    g, _, sub = built_small
    cov = g.covered_vertices()
    src_v = int(cov[np.argmax(g.degrees()[cov])])
    h, sh = alg.sssp(sub, src_v, driver="host", compute_backend=backend)
    f, sf = alg.sssp(sub, src_v, driver="fused", compute_backend=backend)
    np.testing.assert_array_equal(f, h)  # same op order -> bitwise equal f32
    assert_stats_equal(sf, sh)


@pytest.mark.parametrize("backend", BACKENDS)
def test_pagerank_fused_matches_host(built_small, backend):
    g, _, sub = built_small
    h, sh = alg.pagerank(sub, g.num_vertices, num_iters=10, driver="host", compute_backend=backend)
    f, sf = alg.pagerank(sub, g.num_vertices, num_iters=10, driver="fused", compute_backend=backend)
    np.testing.assert_array_equal(f, h)
    assert_stats_equal(sf, sh)


def test_pagerank_tol_early_exit_matches(built_small):
    g, _, sub = built_small
    h, sh = alg.pagerank(sub, g.num_vertices, num_iters=50, tol=1e-4, driver="host")
    f, sf = alg.pagerank(sub, g.num_vertices, num_iters=50, tol=1e-4, driver="fused")
    assert sh.supersteps < 50  # tol actually fired
    np.testing.assert_array_equal(f, h)
    assert_stats_equal(sf, sh)


def test_bounded_staleness_fused_matches_host(built_small):
    _, sub, _ = built_small
    h, sh = alg.connected_components(sub, exchange_period=3, inner_cap=2, driver="host")
    f, sf = alg.connected_components(sub, exchange_period=3, inner_cap=2, driver="fused")
    np.testing.assert_array_equal(f, h)
    assert_stats_equal(sf, sh)


def test_fused_driver_single_dispatch(built_small):
    """The whole point of the fused driver: one device dispatch per run,
    vs one per superstep for the host driver."""
    g, sub, sub_dir = built_small
    # Warm the executable caches so the counted runs measure dispatches only.
    alg.connected_components(sub, driver="fused")
    base_f, base_h = eng.DISPATCH_COUNTS["fused"], eng.DISPATCH_COUNTS["host"]
    _, stats = alg.connected_components(sub, driver="fused")
    assert eng.DISPATCH_COUNTS["fused"] - base_f == 1
    assert eng.DISPATCH_COUNTS["host"] == base_h  # fused path never host-steps

    base_h = eng.DISPATCH_COUNTS["host"]
    _, stats_h = alg.connected_components(sub, driver="host")
    assert eng.DISPATCH_COUNTS["host"] - base_h == stats_h.supersteps

    base_f = eng.DISPATCH_COUNTS["fused"]
    alg.pagerank(sub_dir, g.num_vertices, num_iters=5, driver="fused")
    assert eng.DISPATCH_COUNTS["fused"] - base_f == 1


def _nested_jaxprs(v):
    if hasattr(v, "jaxpr"):  # ClosedJaxpr
        yield v.jaxpr
    elif hasattr(v, "eqns"):  # Jaxpr
        yield v
    elif isinstance(v, (tuple, list)):
        for x in v:
            yield from _nested_jaxprs(x)


def _collect_converts(jaxpr, in_loop, out):
    """(eqn, in_loop) for every convert_element_type, recursing through
    nested jaxprs; in_loop flips once inside a while_loop's sub-jaxprs."""
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "convert_element_type":
            out.append((eqn, in_loop))
        inside = in_loop or eqn.primitive.name == "while"
        for v in eqn.params.values():
            for j in _nested_jaxprs(v):
                _collect_converts(j, inside, out)


def _is_int_float_convert(eqn):
    src = eqn.invars[0].aval.dtype
    dst = eqn.params["new_dtype"]
    int_to_float = jnp.issubdtype(src, jnp.integer) and jnp.issubdtype(dst, jnp.floating)
    float_to_int = jnp.issubdtype(src, jnp.floating) and jnp.issubdtype(dst, jnp.integer)
    return int_to_float or float_to_int


def test_fused_no_inloop_remap(built_small):
    """Kernel backends run int32 programs in f32: the INF_I32 <-> INF_F32
    remap must be hoisted to the driver boundary (paid once per run), not
    traced into the fused while_loop body (paid once per superstep — the
    `reach` fused wall regression). bool->int32 converts for message
    counting are legitimate and must not trip this."""
    _, sub, _ = built_small
    prog = eng.get_program("reach")
    exec_prog, negate = eng._exec_view(prog)
    val = prog.init(sub, num_vertices=0, source=None)
    val = -val if negate else val
    closed = jax.make_jaxpr(
        functools.partial(
            eng._fused_bsp, prog=exec_prog, max_supersteps=8, inner_cap=4,
            exchange_period=1, tol=0.0, num_vertices=0, backend="ref",
        )
    )(sub, val)
    converts = []
    _collect_converts(closed.jaxpr, False, converts)
    remaps_outside = [e for e, in_loop in converts if not in_loop and _is_int_float_convert(e)]
    remaps_inside = [e for e, in_loop in converts if in_loop and _is_int_float_convert(e)]
    assert remaps_outside, "boundary remap vanished — is the trace still the int32 kernel path?"
    assert not remaps_inside, (
        "int32<->float32 remap traced inside the fused loop body: "
        + "; ".join(str(e) for e in remaps_inside)
    )


def test_messages_per_step_worker_consistent(built_small):
    """The new [steps, p] matrix marginalizes to the legacy fields."""
    _, sub, _ = built_small
    for driver in ("fused", "host"):
        _, stats = alg.connected_components(sub, driver=driver)
        m = stats.messages_per_step_worker
        assert m.shape == (stats.supersteps, sub.num_parts)
        np.testing.assert_array_equal(m.sum(axis=0), stats.messages_per_worker)
        np.testing.assert_array_equal(m.sum(axis=1), stats.messages_per_step)


def test_driver_validation(built_small):
    _, sub, _ = built_small
    with pytest.raises(ValueError, match="driver"):
        alg.connected_components(sub, driver="turbo")


def test_pipeline_surfaces_driver(small_powerlaw):
    from repro.api import GraphPipeline

    pipe = GraphPipeline(small_powerlaw).partition("ebg", parts=4)
    f = pipe.run("cc")  # fused is the default
    h = pipe.run("cc", driver="host")
    np.testing.assert_array_equal(f.values, h.values)
    assert_stats_equal(f.stats, h.stats)
    with pytest.raises(ValueError, match="driver"):
        pipe.run("cc", driver="turbo")
    with pytest.raises(ValueError, match="driver"):
        pipe.run("cc", mode="dist", driver="host", mesh=None)
