"""End-to-end behaviour tests for the full system.

The dry-run and distributed-engine tests need >1 placeholder device, and
XLA locks the device count at first init — so those run in subprocesses
with their own XLA_FLAGS (exactly how launch/dryrun.py works).
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=560
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_training_end_to_end_loss_drops():
    from repro.launch import train

    losses = train.main(["--preset", "tiny", "--steps", "40", "--log-every", "100"])
    assert np.mean(losses[-5:]) < losses[0] - 0.5


def test_serving_end_to_end():
    from repro.launch import serve

    out = serve.main(["--preset", "tiny", "--tokens", "8", "--batch", "2"])
    assert np.asarray(out).shape == (2, 8)


def test_distributed_bsp_matches_simulation():
    _run(
        """
import numpy as np, jax
from repro.core import ebg_partition
from repro.graph.generate import make_graph
from repro.graph.build import build_subgraphs
from repro.graph import algorithms as alg
from repro.graph.engine import CC, init_cc, make_distributed_stepper, subgraphs_to_arrays

g = make_graph("tiny_powerlaw")
res = ebg_partition(g, 8)
sub = build_subgraphs(g, res, symmetrize=True)
labels_sim, stats_sim = alg.connected_components(sub)
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((8,), ("workers",))
arrays, statics = subgraphs_to_arrays(sub)
stepper = make_distributed_stepper(mesh, "workers", CC, statics, num_supersteps=10, inner_cap=100)
with mesh:
    val, msgs, steps, msgs_steps, iters_steps = jax.jit(stepper)(arrays, init_cc(sub))
assert np.array_equal(labels_sim, np.asarray(val[:, :-1]))
# Convergence exit: the while_loop stops early and its per-step message
# series matches the simulation driver's (same superstep semantics).
steps = int(steps)
assert steps == stats_sim.supersteps < 10
assert np.array_equal(np.asarray(msgs_steps)[:steps], stats_sim.messages_per_step_worker)
assert np.array_equal(np.asarray(msgs), stats_sim.messages_per_worker)
assert np.array_equal(np.asarray(iters_steps)[:steps], stats_sim.inner_iters_per_step)
print("OK")
"""
    )


def test_dryrun_lowers_on_multidevice_mesh():
    """Reduced-config train_step lowers + compiles on an 8-device 2-axis mesh
    (same code path as the 512-chip production dry-run)."""
    _run(
        """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.launch.sharding import batch_shardings, opt_state_shardings, param_shardings
from repro.models.pspec import activation_axes
from repro.models.steps import make_train_step
from repro.models.transformer import init_params
from repro.optim.adam import AdamWConfig, init_opt_state

cfg = configs.reduced_config("phi3_5_moe")
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((4, 2), ("data", "model"))
params_shape = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0), jnp.bfloat16))
p_shard = param_shardings(cfg, params_shape, mesh)
opt = AdamWConfig()
opt_shape = jax.eval_shape(lambda: init_opt_state(params_shape, opt))
o_shard = opt_state_shardings(p_shard, mesh)
batch = dict(tokens=jax.ShapeDtypeStruct((8, 32), jnp.int32),
             targets=jax.ShapeDtypeStruct((8, 32), jnp.int32))
b_shard = batch_shardings(batch, mesh)
step = make_train_step(cfg, opt)
with mesh, activation_axes(mesh, dp=("data",), tp="model"):
    lowered = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                      out_shardings=(p_shard, o_shard, None)).lower(params_shape, opt_shape, batch)
    compiled = lowered.compile()
assert compiled.memory_analysis() is not None
from repro.compat import cost_analysis_compat
cost = cost_analysis_compat(compiled)
assert cost.get("flops", 0) > 0
print("OK")
"""
    )


def test_roofline_collective_parser():
    from repro.launch.roofline import parse_collectives

    hlo = """
  %ag = f32[32,1024,256]{2,1,0} all-gather(%x), replica_groups=[32,16]<=[512], dimensions={0}
  %ar = bf16[1000]{0} all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%add
  %aa = f32[8,128]{1,0} all-to-all(%z), replica_groups=[64,8]<=[512]
  %cp = f32[4,4]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
"""
    s = parse_collectives(hlo)
    assert s.per_op["all-gather"]["count"] == 1
    ag_bytes = 32 * 1024 * 256 * 4 * 15 / 16
    assert abs(s.per_op["all-gather"]["bytes"] - ag_bytes) < 1
    ar_bytes = 2 * 1000 * 2 * 3 / 4
    assert abs(s.per_op["all-reduce"]["bytes"] - ar_bytes) < 1
    assert s.per_op["all-to-all"]["count"] == 1
    assert s.total_link_bytes > 0


def test_dryrun_records_exist_and_complete():
    """The committed dry-run sweep must cover every runnable cell × mesh."""
    from repro import configs

    d = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
    if not d.exists():
        pytest.skip("dry-run sweep not generated yet")
    missing = []
    for arch in configs.ARCHS:
        for shape in configs.runnable_shapes(arch):
            for mesh in ("sp", "mp"):
                f = d / f"{arch}__{shape}__{mesh}__baseline.json"
                if not f.exists():
                    missing.append(f.name)
    assert not missing, missing
    rec = json.loads((d / "llama3_2_3b__train_4k__sp__baseline.json").read_text())
    assert rec["flops_per_device"] > 0 and rec["bottleneck"] in ("compute", "memory", "collective")


def test_moe_ep_shard_map_matches_reference():
    """The §Perf `ep` plan (manual shard_map MoE dispatch) must be
    numerically identical to the GSPMD scatter path, gradients included."""
    _run(
        """
import numpy as np, jax, jax.numpy as jnp
from repro import configs
from repro.models import moe as MOE
from repro.models.pspec import activation_axes
from repro.models.transformer import init_params

cfg = configs.reduced_config("phi3_5_moe")
params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
p = jax.tree.map(lambda x: x[0], params["groups"]["layer_0"])["moe"]
rng = np.random.default_rng(0)
x = jnp.array(rng.standard_normal((4, 16, cfg.d_model)), jnp.float32)
y_ref = MOE.moe_ffn(cfg, p, x)
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((4, 2), ("data", "model"))
with mesh, activation_axes(mesh, dp=("data",), tp="model", ep_shard_map=True):
    y_ep = jax.jit(lambda p, x: MOE.moe_ffn_ep(cfg, p, x))(p, x)
    g = jax.jit(jax.grad(lambda p, x: MOE.moe_ffn_ep(cfg, p, x).sum()))(p, x)
assert float(jnp.abs(y_ep - y_ref).max()) < 1e-4
assert all(np.isfinite(np.asarray(v)).all() for v in jax.tree.leaves(g))
print("OK")
"""
    )


def test_perf_plan_records_exist():
    """§Perf hillclimb artifacts: every logged plan has a JSON record."""
    d = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
    if not d.exists():
        pytest.skip("dry-run sweep not generated yet")
    for f in [
        "kimi_k2__train_4k__sp__ep+cap1.json",
        "jamba_1_5_large__train_4k__sp__ep+vp+sp.json",
        "llama3_2_3b__decode_32k__sp__don+repl.json",
        "phi3_5_moe__train_4k__sp__ep.json",
    ]:
        assert (d / f).exists(), f
    base = json.loads((d / "kimi_k2__train_4k__sp__baseline.json").read_text())
    opt = json.loads((d / "kimi_k2__train_4k__sp__ep+cap1.json").read_text())
    assert opt["bound_s"] < base["bound_s"] / 10  # ≥10x hillclimb win locked in


def test_expert_placement_beats_random():
    from repro.core.placement import ebg_expert_placement, placement_report

    rng = np.random.default_rng(0)
    E, D, T = 64, 8, 50_000
    pop = 1.0 / (1 + np.arange(E)) ** 0.9
    pop /= pop.sum()
    pairs = rng.choice(E, size=(T, 2), p=pop)
    perm = ebg_expert_placement(pairs, E, D)
    rep = placement_report(pairs, perm, E, D)
    rand = placement_report(pairs, np.argsort(rng.random(E)), E, D)
    assert rep["load_max_mean"] < rand["load_max_mean"]
    # permutation sanity
    assert sorted(perm.tolist()) == list(range(E))
