"""Backend-parity suite: compute_backend in {"xla", "ref", "pallas"} must
agree on the engine programs (exact for int32 CC, atol=1e-5 for f32) and on
chunked-EBG assignments, plus segment-reduce edge cases the shape sweeps in
test_kernels.py miss (runs spanning blocks, all-padded tail blocks,
non-multiple-of-block edge streams)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PARTITIONERS, ebg_partition_chunked
from repro.graph import algorithms as alg
from repro.graph.build import build_subgraphs
from repro.kernels import ops, ref

BACKENDS = ("xla", "ref", "pallas")

# small_powerlaw / built_small fixtures live in conftest.py (shared with
# tests/test_drivers.py).


# ------------------------------------------------- segment-reduce edge cases


@pytest.mark.parametrize("op", ["min", "sum"])
def test_dst_run_spans_two_blocks(op):
    """One destination's edge run crosses the block_e boundary — the kernel
    must merge the two per-block partials through the accumulator."""
    rng = np.random.default_rng(11)
    E, block = 256, 128
    num_out = 33
    # dst 5 owns edges [0, 100); dst 9 owns [100, 256) — spans blocks 0 and 1.
    ldst = np.concatenate([np.full(100, 5), np.full(156, 9)]).astype(np.int32)
    lsrc = rng.integers(0, 32, E).astype(np.int32)
    w = rng.random(E).astype(np.float32) + 0.1
    val = (rng.random(num_out) * 10).astype(np.float32)
    fn = ops.segment_min_plus if op == "min" else ops.segment_sum_scaled
    a = fn(jnp.array(lsrc), jnp.array(ldst), jnp.array(w), jnp.array(val),
           num_out=num_out, impl="ref")
    b = fn(jnp.array(lsrc), jnp.array(ldst), jnp.array(w), jnp.array(val),
           num_out=num_out, impl="pallas", block_e=block, interpret=True)
    np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("op", ["min", "sum"])
def test_all_padded_tail_block(op):
    """A tail block of nothing but identity-weight pad edges must be a no-op."""
    rng = np.random.default_rng(12)
    E, block = 256, 128
    num_out = 65
    identity = float(ref.INF) if op == "min" else 0.0
    ldst = np.concatenate([
        np.sort(rng.integers(0, 64, 128)),
        np.full(128, num_out - 1),  # pads point at the dump slot
    ]).astype(np.int32)
    lsrc = np.concatenate([rng.integers(0, 64, 128), np.zeros(128)]).astype(np.int32)
    w = np.concatenate([
        rng.random(128).astype(np.float32) + 0.1,
        np.full(128, identity, np.float32),
    ])
    val = (rng.random(num_out) * 10).astype(np.float32)
    fn = ops.segment_min_plus if op == "min" else ops.segment_sum_scaled
    a = fn(jnp.array(lsrc), jnp.array(ldst), jnp.array(w), jnp.array(val),
           num_out=num_out, impl="ref")
    b = fn(jnp.array(lsrc), jnp.array(ldst), jnp.array(w), jnp.array(val),
           num_out=num_out, impl="pallas", block_e=block, interpret=True)
    np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=1e-5, atol=1e-6)
    # real slots other than the dump row are untouched by the pad block
    np.testing.assert_allclose(np.asarray(b)[:64],
                               np.asarray(a)[:64], rtol=1e-5, atol=1e-6)


def test_ops_pad_non_multiple_edge_stream():
    """The ops wrappers own block padding: E need not divide block_e."""
    rng = np.random.default_rng(13)
    E, num_out = 100, 17
    ldst = np.sort(rng.integers(0, 16, E)).astype(np.int32)
    lsrc = rng.integers(0, 16, E).astype(np.int32)
    w = rng.random(E).astype(np.float32) + 0.1
    val = (rng.random(num_out) * 10).astype(np.float32)
    a = ops.segment_min_plus(jnp.array(lsrc), jnp.array(ldst), jnp.array(w),
                             jnp.array(val), num_out=num_out, impl="ref")
    b = ops.segment_min_plus(jnp.array(lsrc), jnp.array(ldst), jnp.array(w),
                             jnp.array(val), num_out=num_out, impl="pallas", block_e=512)
    np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=1e-5, atol=1e-6)
    # membership wrapper pads and slices back too
    keep = rng.random((4, 64)) < 0.3
    kb = ops.pack_keep_bits(jnp.array(keep))
    u = rng.integers(0, 64, E).astype(np.int32)
    v = rng.integers(0, 64, E).astype(np.int32)
    ma = ops.ebg_membership(kb, jnp.array(u), jnp.array(v), impl="ref")
    mb = ops.ebg_membership(kb, jnp.array(u), jnp.array(v), impl="pallas", block_e=64)
    assert mb.shape == (4, E)
    np.testing.assert_array_equal(np.asarray(mb), np.asarray(ma))


def test_explicit_interpret_override():
    """`impl="pallas"` must not re-sniff the backend for interpret: an
    explicit interpret= wins, so compiled Pallas is forceable off-TPU."""
    import jax

    on_tpu = jax.default_backend() == "tpu"
    assert ops._resolve_impl("pallas", None) == ("pallas", not on_tpu)
    assert ops._resolve_impl("pallas", True) == ("pallas", True)
    assert ops._resolve_impl("pallas", False) == ("pallas", False)
    assert ops._resolve_impl(None, None) == (ops._default_impl(), not on_tpu)
    assert ops._resolve_impl("ref", False)[0] == "ref"
    with pytest.raises(ValueError, match="impl"):
        ops._resolve_impl("xla_is_not_a_kernel_impl", None)


# --------------------------------------------------- engine backend parity


def test_cc_parity_across_backends(built_small):
    g, sub, _ = built_small
    base, stats_base = alg.connected_components(sub, compute_backend="xla")
    for backend in ("ref", "pallas"):
        got, stats = alg.connected_components(sub, compute_backend=backend)
        np.testing.assert_array_equal(got, base)  # exact int32 labels
        assert stats.supersteps == stats_base.supersteps
        np.testing.assert_array_equal(stats.messages_per_worker,
                                      stats_base.messages_per_worker)
    glob = alg.scatter_to_global(sub, base, g.num_vertices)
    ref_labels = alg.cc_reference(g)
    cov = g.covered_vertices()
    np.testing.assert_array_equal(glob[cov], ref_labels[cov])


def test_sssp_parity_across_backends(built_small):
    g, _, sub = built_small
    cov = g.covered_vertices()
    src_v = int(cov[np.argmax(g.degrees()[cov])])
    base, _ = alg.sssp(sub, src_v, compute_backend="xla")
    for backend in ("ref", "pallas"):
        got, _ = alg.sssp(sub, src_v, compute_backend=backend)
        np.testing.assert_allclose(got, base, atol=1e-5)


def test_pagerank_parity_across_backends(built_small):
    g, _, sub = built_small
    base, _ = alg.pagerank(sub, g.num_vertices, num_iters=10, compute_backend="xla")
    for backend in ("ref", "pallas"):
        got, _ = alg.pagerank(sub, g.num_vertices, num_iters=10, compute_backend=backend)
        np.testing.assert_allclose(got, base, atol=1e-5)


def test_ref_backend_parity_on_benchmark_fixture(tiny_powerlaw):
    """xla vs ref on the standard benchmark-family fixture (pallas-interpret
    parity runs on the smaller graph above to keep the suite fast)."""
    res = PARTITIONERS["ebg"](tiny_powerlaw, 8)
    sub_sym = build_subgraphs(tiny_powerlaw, res, symmetrize=True)
    sub_dir = build_subgraphs(tiny_powerlaw, res, symmetrize=False)
    cc_x, _ = alg.connected_components(sub_sym, compute_backend="xla")
    cc_r, _ = alg.connected_components(sub_sym, compute_backend="ref")
    np.testing.assert_array_equal(cc_r, cc_x)
    cov = tiny_powerlaw.covered_vertices()
    src_v = int(cov[np.argmax(tiny_powerlaw.degrees()[cov])])
    d_x, _ = alg.sssp(sub_dir, src_v, compute_backend="xla")
    d_r, _ = alg.sssp(sub_dir, src_v, compute_backend="ref")
    np.testing.assert_allclose(d_r, d_x, atol=1e-5)
    p_x, _ = alg.pagerank(sub_dir, tiny_powerlaw.num_vertices, num_iters=10, compute_backend="xla")
    p_r, _ = alg.pagerank(sub_dir, tiny_powerlaw.num_vertices, num_iters=10, compute_backend="ref")
    np.testing.assert_allclose(p_r, p_x, atol=1e-5)


def test_engine_rejects_unknown_backend(built_small):
    _, sub, _ = built_small
    with pytest.raises(ValueError, match="compute_backend"):
        alg.connected_components(sub, compute_backend="cuda")


def test_cc_kernel_backend_rejects_huge_vertex_ids(built_small):
    """int32 CC labels ride through f32 on the kernel backends — under FLAT
    addressing ids at or above 2^24 would corrupt silently, so the driver
    must refuse them (two-level addressing rank-compresses instead;
    tests/test_scale.py pins its clean passage)."""
    import dataclasses

    _, sub, _ = built_small
    big = dataclasses.replace(
        sub, gid=jnp.where(sub.vmask, sub.gid + (1 << 24), sub.gid), addressing="flat"
    )
    with pytest.raises(ValueError, match="vertex ids"):
        alg.connected_components(big, compute_backend="ref")
    # the xla path holds full int32 precision and keeps working
    alg.connected_components(big, compute_backend="xla", max_supersteps=2)


def test_batch_kernel_backend_rejects_huge_vertex_ids(built_small):
    """The same 2^24 guard must fire on the batched driver and the AOT
    compile path BEFORE any f32 remap (or any lowering work) happens."""
    import dataclasses

    from repro.graph.engine import compile_batch_executable, run_bsp_batch

    _, sub, _ = built_small
    big = dataclasses.replace(
        sub, gid=jnp.where(sub.vmask, sub.gid + (1 << 24), sub.gid), addressing="flat"
    )
    with pytest.raises(ValueError, match="vertex ids"):
        run_bsp_batch(big, "cc", batch=2, compute_backend="ref")
    with pytest.raises(ValueError, match="vertex ids"):
        compile_batch_executable(big, "cc", 2, compute_backend="ref")
    # xla batch keeps full int32 precision
    run_bsp_batch(big, "cc", batch=2, compute_backend="xla", max_supersteps=2)


def test_distributed_stepper_rejects_huge_vertex_ids(small_powerlaw):
    """Eagerly calling the distributed stepper with a kernel backend and
    ids >= 2^24 must raise the named ValueError before the shard_map runs;
    under jit tracing the guard defers to the pipeline's concrete
    pre-check instead of breaking the trace."""
    import dataclasses

    from repro.core import PARTITIONERS
    from repro.graph.build import build_subgraphs
    from repro.graph.engine import (
        CC,
        init_cc,
        make_distributed_stepper,
        subgraphs_to_arrays,
    )
    from repro.launch.mesh import make_mesh_compat

    res = PARTITIONERS["ebg"](small_powerlaw, 1)
    sub = build_subgraphs(small_powerlaw, res, symmetrize=True)
    big = dataclasses.replace(
        sub, gid=jnp.where(sub.vmask, sub.gid + (1 << 24), sub.gid), addressing="flat"
    )
    mesh = make_mesh_compat((1,), ("workers",))
    arrays, statics = subgraphs_to_arrays(big)
    stepper = make_distributed_stepper(
        mesh, "workers", CC, statics, num_supersteps=4, inner_cap=100,
        compute_backend="ref",
    )
    with pytest.raises(ValueError, match="vertex ids"):
        stepper(arrays, init_cc(big))
    # the guard is backend-scoped: xla runs huge ids at full precision
    stepper_x = make_distributed_stepper(
        mesh, "workers", CC, statics, num_supersteps=2, inner_cap=8
    )
    val, _, steps, _, _ = stepper_x(arrays, init_cc(big))
    assert int(steps) == 2 and val.shape == init_cc(big).shape


def test_pipeline_surfaces_compute_backend(small_powerlaw):
    from repro.api import GraphPipeline

    pipe = GraphPipeline(small_powerlaw).partition("ebg", parts=4)
    base = pipe.run("cc")
    other = pipe.run("cc", compute_backend="ref")
    np.testing.assert_array_equal(other.values, base.values)
    with pytest.raises(ValueError, match="compute_backend"):
        pipe.run("cc", compute_backend="nope")


def test_registry_compute_backend_capability():
    from repro.api import COMPUTE_BACKENDS, get_partitioner

    assert get_partitioner("ebg_chunked").compute_backends == COMPUTE_BACKENDS
    assert get_partitioner("ebg").compute_backends == ("xla",)


# --------------------------------------------------- fused EBG block commit


def _commit_oracle_dense(keep_bool, e_count, v_count, u, v, valid, alpha, beta, inv_e, inv_v):
    """The pre-fusion in-engine commit path: dense (p, V) bool membership +
    per-edge fori_loop with separate scatter updates (exactly the old
    `_ebg_chunked` block body). Independent representation (bool table vs
    packed bitset), same jnp arithmetic — the fused op must match it
    bit-for-bit."""
    import jax

    @jax.jit
    def run(keep, e_c, v_c, ub, vb, valb):
        p = keep.shape[0]
        miss_u = ~keep[:, ub]
        miss_v = ~keep[:, vb]
        memb = miss_u.astype(jnp.float32) + miss_v.astype(jnp.float32)

        def body(j, carry):
            e_c, v_c, parts = carry
            score = memb[:, j] + alpha * e_c * inv_e + beta * v_c * inv_v
            i = jnp.argmin(score).astype(jnp.int32)
            live = valb[j].astype(jnp.float32)
            e_c = e_c.at[i].add(live)
            v_c = v_c.at[i].add(live * memb[i, j])
            return e_c, v_c, parts.at[j].set(jnp.where(valb[j], i, p))

        e_c, v_c, parts = jax.lax.fori_loop(
            0, ub.shape[0], body, (e_c, v_c, jnp.zeros(ub.shape, jnp.int32))
        )
        keep = keep.at[parts, ub].set(True, mode="drop")
        keep = keep.at[parts, vb].set(True, mode="drop")
        return keep, e_c, v_c, parts

    keep, e_c, v_c, parts = run(
        jnp.asarray(keep_bool), jnp.asarray(e_count), jnp.asarray(v_count),
        jnp.asarray(u), jnp.asarray(v), jnp.asarray(valid),
    )
    return np.asarray(ops.pack_keep_bits(keep)), np.asarray(e_c), np.asarray(v_c), np.asarray(parts)


@pytest.mark.parametrize("impl", ["ref", "pallas"])
@pytest.mark.parametrize("block", [1, 64, 256])
def test_ebg_commit_block_matches_oracle(impl, block):
    """The fused op (membership + argmin + balance commit + bitset update in
    one kernel) is bit-identical to the unfused per-edge semantics,
    including pad edges, shared endpoint words, and warm-start counters."""
    rng = np.random.default_rng(21)
    p, V = 4, 100
    keep = rng.random((p, V)) < 0.2
    kb = ops.pack_keep_bits(jnp.array(keep))
    e_c = jnp.asarray(rng.integers(0, 50, p).astype(np.float32))
    v_c = jnp.asarray(rng.integers(0, 30, p).astype(np.float32))
    u = rng.integers(0, V, block).astype(np.int32)
    v = rng.integers(0, V, block).astype(np.int32)
    valid = rng.random(block) < 0.9  # some pad edges sprinkled in
    alpha, beta, inv_e, inv_v = 1.0, 1.0, p / 500.0, p / float(V)
    got = ops.ebg_commit_block(
        kb, e_c, v_c, jnp.asarray(u), jnp.asarray(v), jnp.asarray(valid),
        alpha=alpha, beta=beta, inv_e=inv_e, inv_v=inv_v, impl=impl,
    )
    want = _commit_oracle_dense(keep, e_c, v_c, u, v, valid, alpha, beta, inv_e, inv_v)
    for g, w, name in zip(got, want, ("keep_bits", "e_count", "v_count", "parts")):
        np.testing.assert_array_equal(np.asarray(g), w, err_msg=name)


def test_ebg_commit_block_ref_pallas_identical():
    rng = np.random.default_rng(22)
    p, V, B = 8, 64, 128
    kb = ops.pack_keep_bits(jnp.array(rng.random((p, V)) < 0.3))
    e_c = jnp.zeros((p,), jnp.float32)
    v_c = jnp.zeros((p,), jnp.float32)
    u = jnp.asarray(rng.integers(0, V, B).astype(np.int32))
    v = jnp.asarray(rng.integers(0, V, B).astype(np.int32))
    valid = jnp.ones((B,), bool)
    kw = dict(alpha=1.0, beta=1.0, inv_e=p / 1000.0, inv_v=p / float(V))
    a = ops.ebg_commit_block(kb, e_c, v_c, u, v, valid, impl="ref", **kw)
    b = ops.ebg_commit_block(kb, e_c, v_c, u, v, valid, impl="pallas", **kw)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------- chunked EBG bitset parity


@pytest.mark.parametrize("block", [1, 64, 256])
def test_chunked_bitset_matches_dense(small_powerlaw, block):
    """The packed-bitset score phase assigns every edge exactly as the dense
    bool membership table does, for ref and (interpreted) pallas kernels."""
    dense = ebg_partition_chunked(small_powerlaw, 4, block=block, compute_backend="xla")
    for backend in ("ref", "pallas"):
        bits = ebg_partition_chunked(small_powerlaw, 4, block=block, compute_backend=backend)
        np.testing.assert_array_equal(np.asarray(dense.part), np.asarray(bits.part))


def test_chunked_bitset_block1_equals_faithful(small_powerlaw):
    from repro.core import ebg_partition

    a = ebg_partition(small_powerlaw, 4)
    b = ebg_partition_chunked(small_powerlaw, 4, block=1, compute_backend="ref")
    np.testing.assert_array_equal(np.asarray(a.part), np.asarray(b.part))


def test_chunked_config_surfaces_backend(small_powerlaw):
    from repro.api import GraphPipeline

    base = GraphPipeline(small_powerlaw).partition("ebg_chunked", parts=4, block=64)
    bits = GraphPipeline(small_powerlaw).partition(
        "ebg_chunked", parts=4, block=64, compute_backend="ref"
    )
    np.testing.assert_array_equal(
        base.result.part_in_input_order(), bits.result.part_in_input_order()
    )
    with pytest.raises(ValueError):
        GraphPipeline(small_powerlaw).partition("ebg_chunked", parts=4, compute_backend="tpu")
    # the unblocked scan does not take the knob — naming it must error
    with pytest.raises(ValueError, match="does not use"):
        GraphPipeline(small_powerlaw).partition("ebg", parts=4, compute_backend="ref")


# ------------------------------------------------------- engine bugfix pins


def test_init_pr_mirrors_start_at_global_init(built_small):
    """init_pr: every present replica (masters AND mirrors) starts at 1/N;
    absent slots and the dump slot are 0 (pins the dead-store fix)."""
    from repro.graph.engine import init_pr

    g, _, sub = built_small
    val = np.asarray(init_pr(sub, g.num_vertices))
    vmask = np.asarray(sub.vmask)
    mirrors = vmask & ~np.asarray(sub.is_master)
    assert mirrors.any()  # the partition does replicate something
    np.testing.assert_allclose(val[:, :-1][mirrors], 1.0 / g.num_vertices)
    np.testing.assert_allclose(val[:, :-1][vmask], 1.0 / g.num_vertices)
    np.testing.assert_allclose(val[:, :-1][~vmask], 0.0)
    np.testing.assert_allclose(val[:, -1], 0.0)


def test_bspstats_max_mean_single_definition():
    """BSPStats.max_mean is the paper's Table-V metric — one definition,
    repro.core.metrics.max_mean_ratio."""
    from repro.core.metrics import max_mean_ratio
    from repro.graph.engine import BSPStats

    msgs = np.array([10, 20, 30, 60], np.int64)
    stats = BSPStats(
        supersteps=1,
        messages_per_worker=msgs,
        messages_per_step=np.array([120]),
        comp_work_per_worker=np.zeros(4, np.int64),
        inner_iters_per_step=np.ones((1, 4), np.int64),
        messages_per_step_worker=msgs[None, :],
    )
    assert stats.max_mean == max_mean_ratio(msgs) == pytest.approx(2.0)
    zero = BSPStats(1, np.zeros(4, np.int64), np.zeros(1, np.int64),
                    np.zeros(4, np.int64), np.ones((1, 4), np.int64),
                    np.zeros((1, 4), np.int64))
    assert zero.max_mean == max_mean_ratio(np.zeros(4)) == 1.0
