"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops


@pytest.mark.parametrize("V,E,block", [(64, 512, 128), (300, 2048, 512), (1000, 4096, 256)])
@pytest.mark.parametrize("op", ["min", "sum"])
def test_segment_reduce_sweep(V, E, block, op):
    rng = np.random.default_rng(V + E)
    num_out = V + 1
    ldst = np.sort(rng.integers(0, V, E)).astype(np.int32)
    lsrc = rng.integers(0, V, E).astype(np.int32)
    w = rng.random(E).astype(np.float32) + 0.1
    val = (rng.random(V + 1) * 10).astype(np.float32)
    fn = ops.segment_min_plus if op == "min" else ops.segment_sum_scaled
    a = fn(jnp.array(lsrc), jnp.array(ldst), jnp.array(w), jnp.array(val), num_out=num_out, impl="ref")
    b = fn(jnp.array(lsrc), jnp.array(ldst), jnp.array(w), jnp.array(val), num_out=num_out, impl="pallas", block_e=block)
    np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=1e-5, atol=1e-6)


def test_segment_reduce_hub_heavy():
    """Power-law pattern: one hub destination owns 90% of the edges."""
    rng = np.random.default_rng(7)
    V, E = 128, 1024
    ldst = np.sort(np.where(rng.random(E) < 0.9, 7, rng.integers(0, V, E))).astype(np.int32)
    lsrc = rng.integers(0, V, E).astype(np.int32)
    w = rng.random(E).astype(np.float32)
    val = (rng.random(V + 1) * 5).astype(np.float32)
    a = ops.segment_min_plus(jnp.array(lsrc), jnp.array(ldst), jnp.array(w), jnp.array(val), num_out=V + 1, impl="ref")
    b = ops.segment_min_plus(jnp.array(lsrc), jnp.array(ldst), jnp.array(w), jnp.array(val), num_out=V + 1, impl="pallas", block_e=256)
    np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=1e-6)


@pytest.mark.parametrize("p,V,E", [(4, 256, 512), (16, 1024, 1024), (32, 4096, 2048)])
def test_ebg_membership_sweep(p, V, E):
    rng = np.random.default_rng(p * V)
    keep = rng.random((p, V)) < 0.25
    kb = ops.pack_keep_bits(jnp.array(keep))
    u = rng.integers(0, V, E).astype(np.int32)
    v = rng.integers(0, V, E).astype(np.int32)
    a = ops.ebg_membership(kb, jnp.array(u), jnp.array(v), impl="ref")
    b = ops.ebg_membership(kb, jnp.array(u), jnp.array(v), impl="pallas", block_e=256)
    np.testing.assert_array_equal(np.asarray(b), np.asarray(a))
    expect = (~keep[:, u]).astype(np.float32) + (~keep[:, v]).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(a), expect)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,D,S,block", [
    (2, 8, 4, 64, 512, 256),
    (1, 4, 4, 32, 1024, 512),
    (3, 12, 2, 64, 512, 128),
])
def test_decode_attention_sweep(B, Hq, Hkv, D, S, block, dtype):
    rng = np.random.default_rng(B * S)
    q = jnp.array(rng.standard_normal((B, Hq, D)), dtype)
    k = jnp.array(rng.standard_normal((B, S, Hkv, D)), dtype)
    v = jnp.array(rng.standard_normal((B, S, Hkv, D)), dtype)
    a = ops.decode_attention(q, k, v, impl="ref")
    b = ops.decode_attention(q, k, v, impl="pallas", block_s=block)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(b, np.float32), np.asarray(a, np.float32), rtol=tol, atol=tol
    )


def test_decode_attention_softcap():
    rng = np.random.default_rng(0)
    q = jnp.array(rng.standard_normal((2, 8, 64)), jnp.float32)
    k = jnp.array(rng.standard_normal((2, 512, 4, 64)), jnp.float32)
    v = jnp.array(rng.standard_normal((2, 512, 4, 64)), jnp.float32)
    a = ops.decode_attention(q, k, v, impl="ref", softcap=30.0)
    b = ops.decode_attention(q, k, v, impl="pallas", softcap=30.0, block_s=256)
    np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=2e-5, atol=2e-5)


def test_pack_keep_bits_roundtrip():
    rng = np.random.default_rng(1)
    keep = rng.random((5, 100)) < 0.5
    kb = np.asarray(ops.pack_keep_bits(jnp.array(keep)))
    got = (kb[:, np.arange(100) >> 5] >> (np.arange(100) & 31)) & 1
    np.testing.assert_array_equal(got.astype(bool), keep)
