"""Hypothesis property tests: system invariants + the paper's Theorems 1/2."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dep: install the 'test' extra")
from hypothesis import given, settings, strategies as st

from repro.core import (
    ebg_partition_np,
    partition_metrics,
    theorem1_edge_bound,
    theorem2_vertex_bound,
)
from repro.core.types import Graph


@st.composite
def graphs(draw):
    V = draw(st.integers(4, 40))
    E = draw(st.integers(4, 120))
    src = draw(st.lists(st.integers(0, V - 1), min_size=E, max_size=E))
    dst = draw(st.lists(st.integers(0, V - 1), min_size=E, max_size=E))
    pairs = [(u, v) for u, v in zip(src, dst) if u != v]
    if not pairs:
        pairs = [(0, 1)]
    return Graph(
        src=np.array([u for u, _ in pairs], np.int32),
        dst=np.array([v for _, v in pairs], np.int32),
        num_vertices=V,
    )


@settings(max_examples=60, deadline=None)
@given(graphs(), st.integers(2, 6), st.floats(0.25, 4.0), st.floats(0.25, 4.0))
def test_theorem_bounds_hold(g, p, alpha, beta):
    """Theorem 1/2 worst-case imbalance bounds hold for every EBG run."""
    res = ebg_partition_np(g, p, alpha=alpha, beta=beta)
    m = partition_metrics(g, res)
    E = g.num_edges
    b1 = theorem1_edge_bound(E, p, alpha, beta)
    assert m.edge_imbalance <= b1 + 1e-9
    sum_vi = int(m.vertices_per_part.sum())
    b2 = theorem2_vertex_bound(sum_vi, g.num_vertices, p, alpha, beta)
    assert m.vertex_imbalance <= b2 + 1e-9


@settings(max_examples=40, deadline=None)
@given(graphs(), st.integers(2, 6))
def test_partition_invariants(g, p):
    res = ebg_partition_np(g, p)
    m = partition_metrics(g, res)
    # every edge assigned once
    assert res.part_in_input_order().shape[0] == g.num_edges
    # replication factor ≥ 1, subgraph vertex sets cover all endpoints
    assert m.replication_factor >= 1.0 - 1e-9
    assert m.edges_per_part.sum() == g.num_edges
    covered = np.unique(np.concatenate([np.asarray(g.src), np.asarray(g.dst)]))
    assert m.vertices_per_part.sum() >= covered.shape[0]


@settings(max_examples=20, deadline=None)
@given(graphs(), st.integers(2, 4))
def test_engine_cc_matches_reference(g, p):
    """BSP CC on any partition == host label propagation."""
    from repro.graph import algorithms as alg
    from repro.graph.build import build_subgraphs

    res = ebg_partition_np(g, p)
    sub = build_subgraphs(g, res, symmetrize=True)
    labels, _ = alg.connected_components(sub, max_supersteps=100)
    glob = alg.scatter_to_global(sub, labels, g.num_vertices)
    ref = alg.cc_reference(g)
    covered = np.unique(np.concatenate([np.asarray(g.src), np.asarray(g.dst)]))
    np.testing.assert_array_equal(glob[covered], ref[covered])
