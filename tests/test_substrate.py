"""Substrate tests: checkpoint/restart fault tolerance, data determinism,
optimizer behaviour, elastic resharding, EBG expert placement."""
import subprocess
import sys
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import ckpt as CKPT
from repro.data.pipeline import DataConfig, batch_at_step, shard_batch_at_step
from repro.optim.adam import AdamWConfig, apply_updates, init_opt_state


def test_checkpoint_roundtrip(tmp_path):
    tree = dict(a=jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                b=dict(c=jnp.ones((5,), jnp.bfloat16), step=jnp.int32(7)))
    CKPT.save(tmp_path, 3, tree)
    assert CKPT.latest_step(tmp_path) == 3
    got = CKPT.restore(tmp_path, 3, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_restart_bitwise_identical(tmp_path):
    """Kill-and-restart: a resumed run reproduces the uninterrupted run."""
    from repro.launch import train as T

    # uninterrupted 30 steps
    losses_full = T.main(["--preset", "tiny", "--steps", "30", "--log-every", "100"])
    # interrupted at 15 + resumed
    ck = str(tmp_path / "ck")
    T.main(["--preset", "tiny", "--steps", "15", "--ckpt-dir", ck, "--ckpt-every", "15",
            "--log-every", "100"])
    losses_resumed = T.main(["--preset", "tiny", "--steps", "30", "--ckpt-dir", ck,
                             "--resume", "--log-every", "100"])
    np.testing.assert_allclose(losses_resumed[-15:], losses_full[-15:], rtol=1e-5)


def test_partial_checkpoint_ignored(tmp_path):
    """A dir without manifest.json (killed mid-write) must be invisible."""
    (tmp_path / "step_00000009").mkdir(parents=True)
    assert CKPT.latest_step(tmp_path) is None
    CKPT.save(tmp_path, 5, dict(x=jnp.ones(3)))
    assert CKPT.latest_step(tmp_path) == 5


def test_data_pipeline_determinism_and_sharding():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=8)
    a = batch_at_step(cfg, 3)
    b = batch_at_step(cfg, 3)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = batch_at_step(cfg, 4)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))
    # shards are disjoint slices of the same deterministic stream
    s0 = shard_batch_at_step(cfg, 3, 0, 2)
    s1 = shard_batch_at_step(cfg, 3, 1, 2)
    assert s0["tokens"].shape == (4, 16)
    assert not np.array_equal(np.asarray(s0["tokens"]), np.asarray(s1["tokens"]))


def test_adamw_converges_quadratic():
    opt = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1, total_steps=300)
    params = dict(w=jnp.array([5.0, -3.0]))
    state = init_opt_state(params, opt)
    for _ in range(150):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, m = apply_updates(params, grads, state, opt)
    assert float(jnp.abs(params["w"]).max()) < 0.3
    assert float(m["grad_norm"]) >= 0


def test_adamw_bf16_state_and_compression():
    opt = AdamWConfig(state_dtype=jnp.bfloat16, compress_grads="bf16",
                      warmup_steps=1, total_steps=10)
    params = dict(w=jnp.ones((4, 4)))
    state = init_opt_state(params, opt)
    assert state["mu"]["w"].dtype == jnp.bfloat16
    grads = dict(w=jnp.full((4, 4), 0.5))
    params2, state2, _ = apply_updates(params, grads, state, opt)
    assert np.isfinite(np.asarray(params2["w"])).all()


def test_elastic_reshard_devices():
    """Gather a sharded tree and re-put to a different layout (1 device CPU
    degenerates to identity but exercises the full code path)."""
    from repro.launch.elastic import reshard
    from repro.launch.mesh import make_mesh_compat
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = make_mesh_compat((1,), ("data",))
    tree = dict(w=jnp.ones((8, 8)))
    sh = dict(w=NamedSharding(mesh, P("data", None)))
    out = reshard(tree, sh)
    assert out["w"].sharding == sh["w"]


def test_multihost_shard_equivalence():
    """Concatenated host shards == the global batch (elastic data path)."""
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=8)
    full = [shard_batch_at_step(cfg, 0, i, 4)["tokens"] for i in range(4)]
    assert sum(x.shape[0] for x in full) == 8
