"""Streaming EdgeScorer core: scan ≡ chunked(B=1) ≡ numpy oracle for every
registered scorer × backend, HDRF/Greedy quality sanity vs hash, custom
scorer registration, and the paper's Theorem 1/2 imbalance bounds on
measured EBV partitions (deterministic — the hypothesis bound sweep in
test_property.py is an optional dep)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    EdgeScorer,
    ebg_partition,
    ebg_partition_np,
    greedy_partition,
    hdrf_partition,
    partition_metrics,
    random_hash_partition,
    register_scorer,
    scorer_names,
    streaming_chunked_partition,
    streaming_partition_np,
    streaming_scan_partition,
    theorem1_edge_bound,
    theorem2_vertex_bound,
)
from repro.core.streaming import _SCORERS, get_scorer
from repro.graph.generate import rmat

BACKENDS = ("xla", "ref", "pallas")
SCORERS = ("ebv", "hdrf", "greedy")


@pytest.fixture(scope="module")
def parity_graph():
    """Small heavy-tailed graph: keeps the pallas-interpret B=1 stream
    (one kernel call per edge) affordable across the scorer sweep."""
    return rmat(128, 640, seed=5)


# ------------------------------------------------------- scorer registry


def test_stock_scorers_registered():
    assert set(SCORERS) <= set(scorer_names())
    assert get_scorer("ebv").balance == "static" and get_scorer("ebv").cv == 1.0
    assert get_scorer("hdrf").degree_term == "hdrf_theta"
    assert get_scorer("hdrf").balance == "range"
    assert not get_scorer("greedy").weighted and get_scorer("greedy").cv == 0.0


def test_scorer_validation_raises():
    with pytest.raises(ValueError, match="balance"):
        EdgeScorer(name="bad", balance="nope")
    with pytest.raises(ValueError, match="degree_term"):
        EdgeScorer(name="bad", degree_term="sqrt")
    with pytest.raises(ValueError, match="tie"):
        EdgeScorer(name="bad", tie="highest")
    with pytest.raises(ValueError, match="ce"):
        EdgeScorer(name="bad", ce=float("nan"))
    with pytest.raises(ValueError, match="already registered"):
        register_scorer(EdgeScorer(name="ebv"))
    with pytest.raises(KeyError, match="unknown scorer"):
        get_scorer("nope")


def test_registry_capability_flags():
    from repro.api import COMPUTE_BACKENDS, benchmark_partitioners, get_partitioner

    for name, scorer in (("ebg", "ebv"), ("ebg_chunked", "ebv"),
                         ("hdrf", "hdrf"), ("greedy", "greedy")):
        assert get_partitioner(name).scorer == scorer
    assert get_partitioner("dbh").scorer is None
    for name in ("hdrf", "greedy"):
        spec = get_partitioner(name)
        assert spec.chunked and spec.jit_compatible
        assert spec.compute_backends == COMPUTE_BACKENDS
        assert name in benchmark_partitioners()


# ------------------------------------------------- scan/chunked/oracle parity


@pytest.mark.parametrize("scorer", SCORERS)
def test_scan_matches_numpy_oracle(parity_graph, scorer):
    for p in (2, 4):
        a = streaming_scan_partition(parity_graph, p, scorer)
        b = streaming_partition_np(parity_graph, p, scorer)
        np.testing.assert_array_equal(np.asarray(a.part), b.part)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("scorer", SCORERS)
def test_chunked_block1_equals_scan_every_backend(parity_graph, scorer, backend):
    """The acceptance triangle: chunked(B=1) ≡ scan ≡ oracle, per scorer ×
    backend (pallas runs under the interpreter off-TPU)."""
    oracle = streaming_partition_np(parity_graph, 4, scorer)
    scan = streaming_scan_partition(parity_graph, 4, scorer)
    chunk = streaming_chunked_partition(
        parity_graph, 4, scorer, block=1, compute_backend=backend
    )
    np.testing.assert_array_equal(np.asarray(scan.part), oracle.part)
    np.testing.assert_array_equal(np.asarray(chunk.part), oracle.part)


@pytest.mark.parametrize("block", [64, 256])
@pytest.mark.parametrize("scorer", SCORERS)
def test_chunked_bitset_matches_dense_every_scorer(parity_graph, scorer, block):
    """ref/pallas packed-bitset blocks assign exactly as the dense xla
    membership table, for every scorer (same block-staleness contract)."""
    dense = streaming_chunked_partition(
        parity_graph, 4, scorer, block=block, compute_backend="xla"
    )
    for backend in ("ref", "pallas"):
        bits = streaming_chunked_partition(
            parity_graph, 4, scorer, block=block, compute_backend=backend
        )
        np.testing.assert_array_equal(np.asarray(dense.part), np.asarray(bits.part))


def test_hdrf_greedy_registered_fns_match_oracle(parity_graph):
    """The registered partitioners (default knobs but block=1) are the
    faithful streams — exact oracle equality on both entry paths."""
    h = hdrf_partition(parity_graph, 4, block=1)
    np.testing.assert_array_equal(
        np.asarray(h.part), streaming_partition_np(parity_graph, 4, "hdrf").part
    )
    g = greedy_partition(parity_graph, 4, block=1)
    np.testing.assert_array_equal(
        np.asarray(g.part), streaming_partition_np(parity_graph, 4, "greedy").part
    )


def test_custom_scorer_runs_on_both_drivers(parity_graph):
    """Registering a new EdgeScorer is all it takes to get the scan driver,
    the chunked driver on every backend, and the numpy oracle."""
    custom = EdgeScorer(
        name="_test_range_vertex",
        balance="range",
        ce=0.5,
        cv=2.0,
        eps=2.0,
        sort_edges=True,
        description="range balance + vertex term (no stock scorer hits this mix)",
    )
    register_scorer(custom)
    try:
        oracle = streaming_partition_np(parity_graph, 4, "_test_range_vertex")
        scan = streaming_scan_partition(parity_graph, 4, custom)
        np.testing.assert_array_equal(np.asarray(scan.part), oracle.part)
        for backend in ("xla", "ref"):
            chunk = streaming_chunked_partition(
                parity_graph, 4, custom, block=1, compute_backend=backend
            )
            np.testing.assert_array_equal(np.asarray(chunk.part), oracle.part)
    finally:
        _SCORERS.pop("_test_range_vertex")


def test_coefficient_overrides_flow_through(parity_graph):
    """Per-call ce/cv/eps overrides reach the score (hdrf lam here), and
    the oracle tracks them exactly."""
    a = hdrf_partition(parity_graph, 4, lam=4.0, block=1)
    b = streaming_partition_np(parity_graph, 4, "hdrf", ce=4.0)
    np.testing.assert_array_equal(np.asarray(a.part), b.part)
    base = hdrf_partition(parity_graph, 4, block=1)
    assert not np.array_equal(np.asarray(a.part), np.asarray(base.part))


# ------------------------------------------------------------ quality sanity


def test_hdrf_replication_beats_hash(tiny_powerlaw):
    """HDRF's raison d'être: fewer replicas than random hashing on
    power-law graphs (paper Table III pattern)."""
    p = 8
    hdrf = partition_metrics(tiny_powerlaw, hdrf_partition(tiny_powerlaw, p))
    hsh = partition_metrics(tiny_powerlaw, random_hash_partition(tiny_powerlaw, p))
    assert hdrf.replication_factor <= hsh.replication_factor
    assert hdrf.edge_imbalance < 1.2


def test_greedy_replication_beats_hash(tiny_powerlaw):
    p = 8
    grd = partition_metrics(tiny_powerlaw, greedy_partition(tiny_powerlaw, p))
    hsh = partition_metrics(tiny_powerlaw, random_hash_partition(tiny_powerlaw, p))
    assert grd.replication_factor <= hsh.replication_factor
    assert grd.edge_imbalance < 1.2


# ------------------------------------------------------- Theorem 1/2 bounds


@pytest.mark.parametrize("p", [2, 4, 8])
def test_theorem_bounds_on_powerlaw(tiny_powerlaw, p):
    """Theorems 1/2: the worst-case edge/vertex imbalance bounds hold for
    measured EBV partitions across p (deterministic counterpart of the
    hypothesis sweep in test_property.py, which needs an optional dep)."""
    alpha = beta = 1.0
    m = partition_metrics(tiny_powerlaw, ebg_partition(tiny_powerlaw, p, alpha=alpha, beta=beta))
    b1 = theorem1_edge_bound(tiny_powerlaw.num_edges, p, alpha, beta)
    assert m.edge_imbalance <= b1 + 1e-9
    sum_vi = int(m.vertices_per_part.sum())
    b2 = theorem2_vertex_bound(sum_vi, tiny_powerlaw.num_vertices, p, alpha, beta)
    assert m.vertex_imbalance <= b2 + 1e-9


@pytest.mark.parametrize("alpha,beta", [(0.5, 2.0), (4.0, 0.25)])
def test_theorem_bounds_track_alpha_beta(parity_graph, alpha, beta):
    """The bounds depend on alpha/beta — they must keep holding away from
    the defaults (numpy oracle: exact same partition, no jit)."""
    p = 4
    m = partition_metrics(
        parity_graph, ebg_partition_np(parity_graph, p, alpha=alpha, beta=beta)
    )
    assert m.edge_imbalance <= theorem1_edge_bound(parity_graph.num_edges, p, alpha, beta) + 1e-9
    sum_vi = int(m.vertices_per_part.sum())
    assert m.vertex_imbalance <= theorem2_vertex_bound(
        sum_vi, parity_graph.num_vertices, p, alpha, beta
    ) + 1e-9


# ---------------------------------------------------- hypothesis properties


@pytest.mark.parametrize("scorer", SCORERS)
def test_property_parity_random_graphs(scorer):
    """Hypothesis sweep: oracle ≡ scan ≡ chunked(B=1, xla) on arbitrary
    graphs (backends get the deterministic sweep above)."""
    pytest.importorskip("hypothesis", reason="optional dep: install the 'test' extra")
    from hypothesis import given, settings, strategies as st

    from repro.core.types import Graph

    @st.composite
    def graphs(draw):
        V = draw(st.integers(4, 32))
        E = draw(st.integers(4, 80))
        src = draw(st.lists(st.integers(0, V - 1), min_size=E, max_size=E))
        dst = draw(st.lists(st.integers(0, V - 1), min_size=E, max_size=E))
        pairs = [(u, v) for u, v in zip(src, dst) if u != v]
        if not pairs:
            pairs = [(0, 1)]
        return Graph(
            src=np.array([u for u, _ in pairs], np.int32),
            dst=np.array([v for _, v in pairs], np.int32),
            num_vertices=V,
        )

    @settings(max_examples=15, deadline=None)
    @given(graphs(), st.integers(2, 5))
    def check(g, p):
        oracle = streaming_partition_np(g, p, scorer)
        scan = streaming_scan_partition(g, p, scorer)
        chunk = streaming_chunked_partition(g, p, scorer, block=1, compute_backend="xla")
        np.testing.assert_array_equal(np.asarray(scan.part), oracle.part)
        np.testing.assert_array_equal(np.asarray(chunk.part), oracle.part)

    check()
