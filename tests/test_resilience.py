"""Chaos suite for the fault-tolerance layer (repro.resilience).

The load-bearing claim: a run that crashes at superstep s and resumes
from its checkpoint directory finishes bit-identical — values AND
BSPStats — to the run that never crashed, for fixpoint and
fixed-iteration programs on both sim drivers. Around it: deterministic
fault draws, retry-then-success serving, named timeout/shed failures,
circuit-breaker degradation parity, AsyncCheckpointer error surfacing,
and streaming-partitioner intake validation.
"""
import numpy as np
import pytest

from repro.api import GraphPipeline
from repro.core.streaming import validate_edge_stream
from repro.core.types import Graph
from repro.graph import engine as eng
from repro.resilience import (
    CircuitBreaker,
    FaultPlan,
    LoadShedError,
    RetryPolicy,
    TransientBackendError,
    WorkerCrashError,
    resume_bsp,
    run_bsp_resilient,
)

from tests.test_drivers import assert_stats_equal

# (program, run_bsp kwargs) — cc/reach need the symmetrized build,
# sssp roots at a source, pr runs its fixed-iteration mode.
CASES = (
    ("cc", dict()),
    ("sssp", dict(source=0)),
    ("pr", dict(max_supersteps=8)),
)


def _sub_for(built_small, name):
    _, sub_sym, sub_dir = built_small
    return sub_sym if name in ("cc", "reach") else sub_dir


def _kw(graph, name, kw):
    out = dict(kw)
    if name == "pr":
        out["num_vertices"] = graph.num_vertices
    return out


# ------------------------------------------------------------ fault plans


def test_fault_plan_draws_replay():
    plan = FaultPlan(seed=7, transient_error_prob=0.5)
    a = [plan.draw("x", i) for i in range(16)]
    b = [FaultPlan(seed=7, transient_error_prob=0.5).draw("x", i) for i in range(16)]
    assert a == b
    assert [plan.draw("y", i) for i in range(16)] != a  # streams are independent


def test_fault_plan_max_transient_ledger():
    plan = FaultPlan(seed=1, transient_error_prob=1.0, max_transient_faults=3)
    fired = [plan.transient_fault(i) for i in range(6)]
    assert fired == [True, True, True, False, False, False]
    # Replaying the same attempt indices gives the same answers.
    assert [plan.transient_fault(i) for i in range(6)] == fired


def test_fault_plan_targeting():
    plan = FaultPlan(seed=2, transient_error_prob=1.0, transient_target_backend="pallas")
    assert plan.transient_fault(0, backend="pallas")
    assert not plan.transient_fault(0, backend="xla")
    plan = FaultPlan(seed=2, transient_error_prob=1.0, transient_target_driver="batch")
    assert plan.transient_fault(0, driver="batch")
    assert not plan.transient_fault(0, driver="host")


def test_fault_plan_validation():
    with pytest.raises(ValueError):
        FaultPlan(transient_error_prob=1.5)
    with pytest.raises(ValueError):
        FaultPlan(crash_at_superstep=-1)
    with pytest.raises(ValueError):
        FaultPlan(straggler_delay_s=-0.1)


# ----------------------------------------------------- checkpoint/resume


@pytest.mark.parametrize("driver", ("fused", "host"))
@pytest.mark.parametrize("name,kw", CASES, ids=[c[0] for c in CASES])
def test_crash_resume_bit_parity(built_small, tmp_path, name, kw, driver):
    """Crash at mid-run superstep s, resume from the checkpoint dir, and
    land bit-identical (values + stats) to the uninterrupted run."""
    graph = built_small[0]
    sub = _sub_for(built_small, name)
    kw = _kw(graph, name, kw)
    base_val, base_stats = eng.run_bsp(sub, name, driver=driver, **kw)
    crash_at = max(1, base_stats.supersteps // 2)
    ckpt_dir = tmp_path / f"{name}_{driver}"
    with pytest.raises(WorkerCrashError):
        eng.run_bsp(
            sub, name, driver=driver, checkpoint_every=1, ckpt_dir=ckpt_dir,
            fault_plan=FaultPlan(seed=3, crash_at_superstep=crash_at), **kw
        )
    val, stats = resume_bsp(sub, ckpt_dir=ckpt_dir)
    np.testing.assert_array_equal(np.asarray(val), np.asarray(base_val))
    assert_stats_equal(stats, base_stats)


@pytest.mark.parametrize("name,kw", CASES, ids=[c[0] for c in CASES])
def test_checkpointed_run_matches_plain(built_small, tmp_path, name, kw):
    """Checkpointing alone (no crash) must not perturb values or stats."""
    graph = built_small[0]
    sub = _sub_for(built_small, name)
    kw = _kw(graph, name, kw)
    base_val, base_stats = eng.run_bsp(sub, name, **kw)
    val, stats = eng.run_bsp(
        sub, name, checkpoint_every=2, ckpt_dir=tmp_path / name, **kw
    )
    np.testing.assert_array_equal(np.asarray(val), np.asarray(base_val))
    assert_stats_equal(stats, base_stats)


def test_resume_crash_resume_chain(built_small, tmp_path):
    """Two successive crashes, two resumes — still bit-identical. PageRank
    runs a fixed 6 supersteps, so both crash points are guaranteed live."""
    graph, _, sub = built_small
    kw = dict(max_supersteps=6, num_vertices=graph.num_vertices)
    base_val, base_stats = eng.run_bsp(sub, "pr", **kw)
    assert base_stats.supersteps == 6
    ckpt = tmp_path / "chain"
    with pytest.raises(WorkerCrashError):
        eng.run_bsp(sub, "pr", checkpoint_every=1, ckpt_dir=ckpt,
                    fault_plan=FaultPlan(crash_at_superstep=2), **kw)
    with pytest.raises(WorkerCrashError):
        resume_bsp(sub, ckpt_dir=ckpt, fault_plan=FaultPlan(crash_at_superstep=4))
    val, stats = resume_bsp(sub, ckpt_dir=ckpt)
    np.testing.assert_array_equal(np.asarray(val), np.asarray(base_val))
    assert_stats_equal(stats, base_stats)


def test_resume_without_checkpoint_raises(built_small, tmp_path):
    _, sub, _ = built_small
    with pytest.raises(FileNotFoundError):
        resume_bsp(sub, ckpt_dir=tmp_path / "nothing_here")


def test_resume_rejects_mismatched_subgraphs(built_small, tmp_path):
    """Resuming against a different partition is an error, not garbage."""
    graph, sub_sym, _ = built_small
    ckpt = tmp_path / "mismatch"
    with pytest.raises(WorkerCrashError):
        eng.run_bsp(sub_sym, "cc", checkpoint_every=1, ckpt_dir=ckpt,
                    fault_plan=FaultPlan(crash_at_superstep=1))
    from repro.core import PARTITIONERS
    from repro.graph.build import build_subgraphs

    other = build_subgraphs(graph, PARTITIONERS["ebg"](graph, 2), symmetrize=True)
    with pytest.raises(ValueError, match="checkpoint"):
        resume_bsp(other, ckpt_dir=ckpt)


def test_checkpoint_args_validated(built_small, tmp_path):
    _, sub, _ = built_small
    with pytest.raises(ValueError, match="checkpoint_every"):
        eng.run_bsp(sub, "cc", checkpoint_every=0, ckpt_dir=tmp_path / "x")
    with pytest.raises(ValueError, match="ckpt_dir"):
        eng.run_bsp(sub, "cc", checkpoint_every=2)
    with pytest.raises(ValueError, match="exchange_period"):
        run_bsp_resilient(sub, "cc", checkpoint_every=3, ckpt_dir=tmp_path / "y",
                          exchange_period=2)


def test_distributed_stepper_crash_hook(small_powerlaw):
    """fault_plan on make_distributed_stepper caps the superstep budget at
    the crash point and raises instead of silently finishing."""
    from repro.core import PARTITIONERS
    from repro.graph.build import build_subgraphs
    from repro.graph.engine import CC, init_cc, make_distributed_stepper, subgraphs_to_arrays
    from repro.launch.mesh import make_mesh_compat

    res = PARTITIONERS["ebg"](small_powerlaw, 1)
    sub = build_subgraphs(small_powerlaw, res, symmetrize=True)
    mesh = make_mesh_compat((1,), ("workers",))
    arrays, statics = subgraphs_to_arrays(sub)
    crashy = make_distributed_stepper(
        mesh, "workers", CC, statics, num_supersteps=10, inner_cap=100,
        fault_plan=FaultPlan(crash_at_superstep=1),
    )
    with pytest.raises(WorkerCrashError, match="superstep 1"):
        crashy(arrays, init_cc(sub))
    # Without a plan, the same config completes past the crash point.
    ok = make_distributed_stepper(
        mesh, "workers", CC, statics, num_supersteps=10, inner_cap=100
    )
    _, _, steps, _, _ = ok(arrays, init_cc(sub))
    assert int(steps) > 1


# --------------------------------------------------- async checkpointer


def test_async_checkpointer_surfaces_thread_errors(tmp_path):
    """Regression: a failed async save must raise on wait()/next save(),
    never be silently treated as durable."""
    from repro.checkpoint.ckpt import AsyncCheckpointer

    blocker = tmp_path / "not_a_dir"
    blocker.write_text("a file where the checkpoint dir should be")
    ckpt = AsyncCheckpointer(blocker)
    ckpt.save(0, {"x": np.zeros((4,), np.float32)})
    with pytest.raises(RuntimeError, match="checkpoint save"):
        ckpt.wait()
    # The error is consumed once surfaced; a save to a good dir recovers.
    ok = AsyncCheckpointer(tmp_path / "good")
    ok.save(0, {"x": np.zeros((4,), np.float32)})
    ok.save(1, {"x": np.ones((4,), np.float32)})
    ok.wait()


def test_async_checkpointer_raises_on_next_save(tmp_path):
    from repro.checkpoint.ckpt import AsyncCheckpointer

    blocker = tmp_path / "still_a_file"
    blocker.write_text("x")
    ckpt = AsyncCheckpointer(blocker)
    ckpt.save(0, {"x": np.zeros((2,), np.float32)})
    with pytest.raises(RuntimeError, match="checkpoint save"):
        ckpt.save(1, {"x": np.zeros((2,), np.float32)})


# ------------------------------------------------ edge intake validation


def test_validate_edge_stream_names_field_and_row():
    src = np.array([0, 1, 2], np.int32)
    with pytest.raises(ValueError, match=r"dst\[1\] = 9"):
        validate_edge_stream(src, np.array([1, 9, 0], np.int32), num_vertices=3)
    with pytest.raises(ValueError, match=r"src\[2\] = -1"):
        validate_edge_stream(np.array([0, 1, -1], np.int32),
                             np.array([1, 2, 0], np.int32), num_vertices=3)
    with pytest.raises(ValueError, match=r"self-loop at edge row 1"):
        validate_edge_stream(np.array([0, 1, 2], np.int32),
                             np.array([1, 1, 0], np.int32), num_vertices=3)
    with pytest.raises(ValueError, match=r"weights\[1\]"):
        validate_edge_stream(src, np.array([1, 2, 0], np.int32), num_vertices=3,
                             weights=np.array([1.0, np.nan, 1.0]))
    with pytest.raises(ValueError, match=r"weights\[0\]"):
        validate_edge_stream(src, np.array([1, 2, 0], np.int32), num_vertices=3,
                             weights=np.array([-2.0, 1.0, 1.0]))
    with pytest.raises(ValueError, match="same shape"):
        validate_edge_stream(src, np.array([1, 2], np.int32), num_vertices=3)
    # Clean stream passes.
    validate_edge_stream(src, np.array([1, 2, 0], np.int32), num_vertices=3,
                         weights=np.array([1.0, 0.5, 2.0]))


@pytest.mark.parametrize("partitioner", ("ebg", "ebg_chunked"))
def test_streaming_partitioners_reject_bad_streams(partitioner):
    from repro.core import PARTITIONERS

    bad_id = Graph(src=np.array([0, 1], np.int32),
                   dst=np.array([1, 5], np.int32), num_vertices=3)
    with pytest.raises(ValueError, match=r"dst\[1\]"):
        PARTITIONERS[partitioner](bad_id, 2)
    loops = Graph(src=np.array([0, 1], np.int32),
                  dst=np.array([1, 1], np.int32), num_vertices=3)
    with pytest.raises(ValueError, match="self-loop"):
        PARTITIONERS[partitioner](loops, 2)


# ------------------------------------------------------ resilient serving


@pytest.fixture(scope="module")
def serve_pipe(built_small):
    graph = built_small[0]
    return GraphPipeline(graph).partition("ebg", parts=4)


def test_serving_retry_then_success_parity(serve_pipe):
    """Two injected transient faults, then success — answers and stats
    bit-identical to a fault-free server."""
    plain = serve_pipe.serve(max_batch=4, max_delay_s=0.001)
    chaos = serve_pipe.serve(
        max_batch=4, max_delay_s=0.001,
        fault_plan=FaultPlan(seed=5, transient_error_prob=1.0, max_transient_faults=2),
        retry=RetryPolicy(max_retries=3),
    )
    for srv in (plain, chaos):
        for s in (0, 3, 7):
            srv.submit("sssp", s)
        srv.drain()
    for qid in range(3):
        a, b = plain.result(qid), chaos.result(qid)
        assert b.ok
        np.testing.assert_array_equal(a.values, b.values)
        assert_stats_equal(a.stats, b.stats)
    counters = chaos.resilience_counters()
    assert counters["retries"] == 2 and counters["faults_injected"] == 2
    assert counters["terminated"] == counters["answered"] == 3


def test_serving_retries_exhausted_named_failure(serve_pipe):
    srv = serve_pipe.serve(
        max_batch=2, max_delay_s=0.001,
        fault_plan=FaultPlan(seed=1, transient_error_prob=1.0),
        retry=RetryPolicy(max_retries=1),
        breaker=CircuitBreaker(threshold=100),  # pin level 0: exhaust, don't degrade
    )
    qid = srv.submit("cc")
    srv.drain()
    r = srv.result(qid)
    assert not r.ok and r.error == "retries_exhausted" and r.retries == 1
    assert srv.resilience_counters()["terminated"] == 1


def test_serving_deadline_expiry_named_timeout(serve_pipe):
    """A straggler delay pushes past the per-query deadline — the query
    terminates with the named timeout failure, not an answer."""
    srv = serve_pipe.serve(
        max_batch=4, max_delay_s=0.001, deadline_s=0.002,
        fault_plan=FaultPlan(seed=9, straggler_prob=1.0, straggler_delay_s=0.05),
    )
    qid = srv.submit("cc", at=0.0)
    srv.drain()
    r = srv.result(qid)
    assert not r.ok and r.error == "deadline_exceeded"
    assert r.latency_s <= 0.06


def test_serving_load_shed_bounded_queue(serve_pipe):
    srv = serve_pipe.serve(max_batch=8, max_delay_s=10.0, max_queue=2)
    qids = [srv.submit("cc") for _ in range(4)]
    for qid in qids[:2]:
        with pytest.raises(KeyError):
            srv.result(qid)  # still queued, not lost
    for qid in qids[2:]:
        r = srv.result(qid)
        assert not r.ok and r.error == "load_shed"
    assert len(srv.queue) == 2
    srv.drain()
    assert all(srv.result(q).ok for q in qids[:2])
    c = srv.resilience_counters()
    assert c["load_shed"] == 2 and c["terminated"] == 4


def test_queue_push_raises_load_shed():
    from repro.serve.queue import AdmissionQueue, Query

    q = AdmissionQueue(max_batch=4, max_queue=1)
    q.push(Query(qid=0, program="cc", source=None, t_arrival=0.0))
    with pytest.raises(LoadShedError, match="reject-newest"):
        q.push(Query(qid=1, program="cc", source=None, t_arrival=0.0))


def test_serving_breaker_degrades_backend_with_parity(serve_pipe):
    """Persistent faults targeting the pallas batch path walk the breaker
    down to xla — transparently, with bit-identical answers."""
    plain = serve_pipe.serve(max_batch=2, max_delay_s=0.001)
    srv = serve_pipe.serve(
        max_batch=2, max_delay_s=0.001, compute_backend="pallas",
        fault_plan=FaultPlan(seed=4, transient_error_prob=1.0,
                             transient_target_backend="pallas"),
        retry=RetryPolicy(max_retries=4),
        breaker=CircuitBreaker(threshold=1, max_level=2),
    )
    for s in (0, 3):
        plain.submit("sssp", s)
        srv.submit("sssp", s)
    plain.drain()
    srv.drain()
    for qid in range(2):
        a, b = plain.result(qid), srv.result(qid)
        assert b.ok
        np.testing.assert_array_equal(a.values, b.values)
        assert_stats_equal(a.stats, b.stats)
    c = srv.resilience_counters()
    assert c["breaker_level"] >= 1 and c["degraded_batches"] >= 1
    assert ("degrade", 0, 1) in srv.breaker.transitions


def test_serving_breaker_degrades_to_host_driver_with_parity(serve_pipe):
    """Faults targeting the batch driver (any backend) degrade all the
    way to the per-query host path — still bit-identical."""
    plain = serve_pipe.serve(max_batch=2, max_delay_s=0.001)
    srv = serve_pipe.serve(
        max_batch=2, max_delay_s=0.001, compute_backend="xla",
        fault_plan=FaultPlan(seed=6, transient_error_prob=1.0,
                             transient_target_driver="batch"),
        retry=RetryPolicy(max_retries=4),
        breaker=CircuitBreaker(threshold=1, max_level=1),
    )
    for s in (0, 5):
        plain.submit("bfs", s)
        srv.submit("bfs", s)
    plain.drain()
    srv.drain()
    for qid in range(2):
        a, b = plain.result(qid), srv.result(qid)
        assert b.ok
        np.testing.assert_array_equal(a.values, b.values)
        assert_stats_equal(a.stats, b.stats)
    assert srv.levels[srv.breaker.level] == ("xla", "host")


def test_serving_breaker_probe_recovery(serve_pipe):
    """After the faults stop, the probe re-tries the healthy level and the
    breaker promotes back to level 0."""
    srv = serve_pipe.serve(
        max_batch=2, max_delay_s=0.001,
        fault_plan=FaultPlan(seed=8, transient_error_prob=1.0, max_transient_faults=3),
        retry=RetryPolicy(max_retries=10),
        breaker=CircuitBreaker(threshold=2, probe_after=1, max_level=1),
    )
    for s in (0, 1, 2, 3, 4, 5):
        srv.submit("sssp", s)
        srv.drain()
    assert srv.breaker.level == 0
    assert ("degrade", 0, 1) in srv.breaker.transitions
    assert ("recover", 1, 0) in srv.breaker.transitions
    assert all(srv.result(q).ok for q in range(6))


def test_serving_malformed_batch_retries(serve_pipe):
    srv = serve_pipe.serve(
        max_batch=2, max_delay_s=0.001,
        fault_plan=FaultPlan(seed=12, malformed_batch_prob=1.0, ),
        retry=RetryPolicy(max_retries=0),
        breaker=CircuitBreaker(threshold=100),
    )
    qid = srv.submit("cc")
    srv.drain()
    r = srv.result(qid)
    assert not r.ok and r.error == "retries_exhausted"
    assert srv.resilience_counters()["malformed_batches"] == 1


def test_serving_chaos_trace_every_query_terminates(serve_pipe):
    """The acceptance-criteria trace: injected faults + stragglers over a
    real trace, zero unhandled exceptions, every query answered within
    the retry budget or terminated with a named failure."""
    from repro.serve.trace import synthetic_trace

    graph = serve_pipe.graph
    trace = synthetic_trace(graph, 48, rate_qps=4000.0,
                            mix=(("cc", 0.3), ("sssp", 0.7)), seed=7)
    srv = serve_pipe.serve(
        max_batch=4, max_delay_s=0.002,
        fault_plan=FaultPlan(seed=11, transient_error_prob=0.3,
                             straggler_prob=0.2, straggler_delay_s=0.005),
        retry=RetryPolicy(max_retries=4), max_queue=64, deadline_s=10.0,
    )
    report = srv.run_trace(trace)
    c = report.resilience
    assert c["terminated"] == 48
    assert c["answered"] + c["failed"] == 48
    for qid in range(48):
        r = srv.result(qid)
        if not r.ok:
            assert r.error in ("deadline_exceeded", "retries_exhausted", "load_shed")
            assert r.retries <= 4
    assert report.row()["resilience"]["terminated"] == 48


def test_serving_chaos_replay_is_deterministic(serve_pipe):
    """Same FaultPlan seed → identical fault schedule and counters."""
    def run():
        srv = serve_pipe.serve(
            max_batch=2, max_delay_s=0.001,
            fault_plan=FaultPlan(seed=21, transient_error_prob=0.5),
            retry=RetryPolicy(max_retries=6),
            breaker=CircuitBreaker(threshold=3),
        )
        for s in (0, 1, 2, 3):
            srv.submit("sssp", s)
            srv.drain()
        c = srv.resilience_counters()
        return c["faults_injected"], c["retries"], srv.breaker.transitions

    assert run() == run()


def test_pipeline_serve_exposes_failure_type():
    from repro.serve import QueryFailure  # re-export surface

    f = QueryFailure(qid=0, program="cc", source=None, error="load_shed",
                     t_arrival=0.0, t_done=0.0)
    assert not f.ok and f.latency_s == 0.0
