"""repro.analysis checker suite: every checker must flag its seeded
violation fixture and pass the clean twin; suppression comments and the
baseline must filter findings; and the repo itself must analyze clean
(the CI gate's contract)."""
import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import (
    analyze_paths,
    analyze_sources,
    apply_baseline,
    load_baseline,
    write_baseline,
)
from repro.analysis.cli import main as cli_main

REPO_ROOT = Path(__file__).resolve().parent.parent


def run(source, select=None, path="src/repro/mod.py", extra=None):
    sources = {path: textwrap.dedent(source)}
    for p, s in (extra or {}).items():
        sources[p] = textwrap.dedent(s)
    return analyze_sources(sources, select=select)


def codes(findings):
    return [f.code for f in findings]


# ------------------------------------------------------------------- HS01


JIT_SYNC_BAD = """
    import jax
    import numpy as np

    @jax.jit
    def step(x):
        return np.asarray(x) + 1
"""

LOOP_SYNC_BAD = """
    import jax
    from jax import lax

    def drive(v):
        def body(c):
            return c + c.item()
        return lax.while_loop(lambda c: c.sum() < 3, body, v)
"""

SYNC_CLEAN = """
    import jax
    import numpy as np

    @jax.jit
    def step(x):
        return x + 1

    def host_side(x):
        return np.asarray(step(x))
"""

CAST_STATIC_CLEAN = """
    import functools
    import jax

    @functools.partial(jax.jit, static_argnames=("n",))
    def step(x, n):
        return x * float(n) + float(x.shape[0])
"""


def test_hs01_flags_np_asarray_in_jit():
    fs = run(JIT_SYNC_BAD, select=["HS01"])
    assert codes(fs) == ["HS01"]
    assert "np.asarray" in fs[0].message or "numpy" in fs[0].message


def test_hs01_flags_item_in_while_loop_body():
    assert codes(run(LOOP_SYNC_BAD, select=["HS01"])) == ["HS01"]


def test_hs01_clean_twin_passes():
    assert run(SYNC_CLEAN, select=["HS01"]) == []
    assert run(CAST_STATIC_CLEAN, select=["HS01"]) == []


# ------------------------------------------------------------------- XD01


XD_PRELUDE = """
    import jax.numpy as jnp

    INF_I32 = jnp.int32(2**31 - 1)
    INF_F32 = jnp.float32(3.0e38)

    def _remap(val):
        return jnp.where(val == INF_I32, INF_F32, val.astype(jnp.float32))

    def _check_ids(val):
        if int(val.max()) >= 1 << 24:
            raise ValueError("ids exceed the f32-exact domain")
"""

XD_BAD_DIRECT = XD_PRELUDE + """
    def run_kernel(val):
        return _remap(val)
"""

XD_BAD_CLOSURE = XD_PRELUDE + """
    def make_stepper(statics):
        def stepper(v):
            return _remap(v)
        return stepper
"""

XD_CLEAN_GUARDED = XD_PRELUDE + """
    def run_kernel(val):
        _check_ids(val)
        return _remap(val)

    def make_stepper(statics):
        def stepper(v):
            return _remap(v)
        def runner(v):
            _check_ids(v)
            return stepper(v)
        return runner
"""


def test_xd01_flags_unguarded_entry():
    fs = run(XD_BAD_DIRECT, select=["XD01"])
    assert codes(fs) == ["XD01"]
    assert fs[0].anchor == "run_kernel"


def test_xd01_flags_unguarded_closure():
    fs = run(XD_BAD_CLOSURE, select=["XD01"])
    assert codes(fs) == ["XD01"]
    assert fs[0].anchor == "make_stepper"


def test_xd01_guarded_twin_passes():
    assert run(XD_CLEAN_GUARDED, select=["XD01"]) == []


def test_xd01_would_have_caught_the_old_distributed_stepper():
    """The pre-fix engine (no guard in make_distributed_stepper) is the
    checker's raison d'etre: rebuilding that shape must flag. The stepper
    guards BOTH addressing modes — the flat gid guard and the two-level
    value-boundary guard — so both calls must be neutralized before the
    checker fires (either alone keeps the function guarded)."""
    engine = (REPO_ROOT / "src/repro/graph/engine.py").read_text()
    assert analyze_sources({"src/repro/graph/engine.py": engine}, select=["XD01"]) == []
    broken = engine.replace("check_int32_kernel_gid(prog, arrays[\"gid\"], compute_backend)", "pass")
    assert analyze_sources({"src/repro/graph/engine.py": broken}, select=["XD01"]) == []
    broken = broken.replace("check_int32_kernel_values(prog, bound, compute_backend)", "pass")
    fs = analyze_sources({"src/repro/graph/engine.py": broken}, select=["XD01"])
    assert codes(fs) == ["XD01"]
    assert fs[0].anchor == "make_distributed_stepper"


# ------------------------------------------------------------------- KP01


KP_REF_STUB = """
    def thing_ref(x, scale):
        return x * scale
"""

KP_CLEAN = """
    from repro.kernels import ref
    from repro.kernels.thing import thing_pallas

    def _resolve_impl(impl, interpret):
        return impl or "ref", bool(interpret)

    def thing(x, scale, *, impl=None, block_e=128, interpret=None):
        impl, interpret = _resolve_impl(impl, interpret)
        if impl == "ref":
            return ref.thing_ref(x, scale)
        pad = (-x.shape[0]) % block_e
        return thing_pallas(x, scale, block_e=block_e, interpret=interpret)
"""

KP_NO_PALLAS = """
    from repro.kernels import ref

    def _resolve_impl(impl, interpret):
        return impl or "ref", bool(interpret)

    def thing(x, scale, *, impl=None, interpret=None):
        impl, interpret = _resolve_impl(impl, interpret)
        return ref.thing_ref(x, scale)
"""

KP_DRIFTED_REF = """
    from repro.kernels import ref
    from repro.kernels.thing import thing_pallas

    def _resolve_impl(impl, interpret):
        return impl or "ref", bool(interpret)

    def thing(x, *, impl=None, interpret=None):
        impl, interpret = _resolve_impl(impl, interpret)
        if impl == "ref":
            return ref.thing_ref(x)
        return thing_pallas(x, interpret=interpret)
"""

KP_NO_INTERPRET = """
    from repro.kernels.thing import thing_pallas
    from repro.kernels import ref

    def _resolve_impl(impl, interpret):
        return impl or "ref", bool(interpret)

    def thing(x, *, impl=None, interpret=None):
        impl, interpret = _resolve_impl(impl, interpret)
        if impl == "ref":
            return ref.thing_ref(x, 1.0)
        return thing_pallas(x)
"""

KP_NO_PADDING = """
    from repro.kernels import ref
    from repro.kernels.thing import thing_pallas

    def _resolve_impl(impl, interpret):
        return impl or "ref", bool(interpret)

    def thing(x, *, impl=None, block_e=128, interpret=None):
        impl, interpret = _resolve_impl(impl, interpret)
        if impl == "ref":
            return ref.thing_ref(x, 1.0)
        return thing_pallas(x, interpret=interpret)
"""

KP_EXTRA = {"src/repro/kernels/ref.py": KP_REF_STUB}


def test_kp01_clean_pair_passes():
    assert run(KP_CLEAN, select=["KP01"], path="src/repro/kernels/ops.py", extra=KP_EXTRA) == []


def test_kp01_flags_missing_pallas_branch():
    fs = run(KP_NO_PALLAS, select=["KP01"], path="src/repro/kernels/ops.py", extra=KP_EXTRA)
    assert codes(fs) == ["KP01"] and "pallas" in fs[0].message


def test_kp01_flags_ref_signature_drift():
    fs = run(KP_DRIFTED_REF, select=["KP01"], path="src/repro/kernels/ops.py", extra=KP_EXTRA)
    assert codes(fs) == ["KP01"] and "scale" in fs[0].message


def test_kp01_flags_missing_interpret_forwarding():
    fs = run(KP_NO_INTERPRET, select=["KP01"], path="src/repro/kernels/ops.py", extra=KP_EXTRA)
    assert codes(fs) == ["KP01"] and "interpret" in fs[0].message


def test_kp01_flags_unpadded_block_param():
    fs = run(KP_NO_PADDING, select=["KP01"], path="src/repro/kernels/ops.py", extra=KP_EXTRA)
    assert codes(fs) == ["KP01"] and "block_e" in fs[0].message


# -------------------------------------------- KP01 x the megakernel entry


KP_BSP_REF_STUB = """
    def bsp_superstep_ref(lsrc, ldst, weight, val, num_out, *,
                          combine="min", inner_cap=1, out_degree=None):
        return val, None
"""

KP_BSP_CLEAN = """
    from repro.kernels import ref
    from repro.kernels.bsp_superstep import bsp_superstep_pallas

    def _resolve_impl(impl, interpret):
        return impl or "ref", bool(interpret)

    def bsp_superstep(lsrc, ldst, weight, val, *, num_out, combine="min",
                      inner_cap=1, out_degree=None,
                      impl=None, block_e=512, interpret=None):
        impl, interpret = _resolve_impl(impl, interpret)
        if impl == "ref":
            return ref.bsp_superstep_ref(
                lsrc, ldst, weight, val, num_out,
                combine=combine, inner_cap=inner_cap, out_degree=out_degree,
            )
        pad = (-lsrc.shape[1]) % block_e
        return bsp_superstep_pallas(
            lsrc, ldst, weight, val, out_degree,
            num_out=num_out, combine=combine, inner_cap=inner_cap,
            block_e=block_e, interpret=interpret,
        )
"""

KP_BSP_UNPADDED = """
    from repro.kernels import ref
    from repro.kernels.bsp_superstep import bsp_superstep_pallas

    def _resolve_impl(impl, interpret):
        return impl or "ref", bool(interpret)

    def bsp_superstep(lsrc, ldst, weight, val, *, num_out, combine="min",
                      inner_cap=1, out_degree=None,
                      impl=None, block_e=512, interpret=None):
        impl, interpret = _resolve_impl(impl, interpret)
        if impl == "ref":
            return ref.bsp_superstep_ref(
                lsrc, ldst, weight, val, num_out,
                combine=combine, inner_cap=inner_cap, out_degree=out_degree,
            )
        return bsp_superstep_pallas(
            lsrc, ldst, weight, val, out_degree,
            num_out=num_out, combine=combine, inner_cap=inner_cap,
            interpret=interpret,
        )
"""

KP_BSP_DRIFTED_REF = """
    from repro.kernels import ref
    from repro.kernels.bsp_superstep import bsp_superstep_pallas

    def _resolve_impl(impl, interpret):
        return impl or "ref", bool(interpret)

    def bsp_superstep(lsrc, ldst, weight, val, *, num_out, combine="min",
                      inner_cap=1, out_degree=None,
                      impl=None, block_e=512, interpret=None):
        impl, interpret = _resolve_impl(impl, interpret)
        if impl == "ref":
            return ref.bsp_superstep_ref(
                lsrc, ldst, weight, val,
                combine=combine, inner_cap=inner_cap, out_degree=out_degree,
            )
        pad = (-lsrc.shape[1]) % block_e
        return bsp_superstep_pallas(
            lsrc, ldst, weight, val, out_degree,
            num_out=num_out, combine=combine, inner_cap=inner_cap,
            block_e=block_e, interpret=interpret,
        )
"""

KP_BSP_EXTRA = {"src/repro/kernels/ref.py": KP_BSP_REF_STUB}


def test_kp01_bsp_clean_twin_passes():
    assert run(KP_BSP_CLEAN, select=["KP01"], path="src/repro/kernels/ops.py", extra=KP_BSP_EXTRA) == []


def test_kp01_flags_bsp_entry_without_block_padding():
    fs = run(KP_BSP_UNPADDED, select=["KP01"], path="src/repro/kernels/ops.py", extra=KP_BSP_EXTRA)
    assert codes(fs) == ["KP01"]
    assert fs[0].anchor == "bsp_superstep" and "block_e" in fs[0].message


def test_kp01_flags_bsp_ref_signature_drift():
    fs = run(KP_BSP_DRIFTED_REF, select=["KP01"], path="src/repro/kernels/ops.py", extra=KP_BSP_EXTRA)
    assert codes(fs) == ["KP01"]
    assert fs[0].anchor == "bsp_superstep" and "num_out" in fs[0].message


def test_kp01_would_have_caught_a_padless_megakernel_entry():
    """The committed `ops.bsp_superstep` analyzes clean; stripping its
    batched block padding AND the block_e forwarding must flag — the exact
    regression the checker exists to stop."""
    ops_src = (REPO_ROOT / "src/repro/kernels/ops.py").read_text()
    ref_src = (REPO_ROOT / "src/repro/kernels/ref.py").read_text()
    srcs = {"src/repro/kernels/ops.py": ops_src, "src/repro/kernels/ref.py": ref_src}
    assert analyze_sources(srcs, select=["KP01"]) == []
    broken = ops_src.replace(
        "    p, E = lsrc.shape\n"
        "    block_e = max(min(block_e, E), 1)\n"
        "    pad = (-E) % block_e\n",
        "    p, E = lsrc.shape\n    pad = 0\n",
    ).replace(
        "inner_cap=inner_cap,\n        block_e=block_e, interpret=interpret,",
        "inner_cap=inner_cap, interpret=interpret,",
    )
    assert broken != ops_src
    fs = analyze_sources({**srcs, "src/repro/kernels/ops.py": broken}, select=["KP01"])
    assert codes(fs) == ["KP01"]
    assert fs[0].anchor == "bsp_superstep" and "block_e" in fs[0].message


# ------------------------------------------------------------- RC01 / RC02


RC_PARTITIONER_BAD = """
    from repro.api.registry import register_partitioner

    @register_partitioner("demo", compute_backends=("xla", "ref", "pallas"))
    def demo_partition(graph, p):
        return None

    @register_partitioner("demo2", chunked=True)
    def demo2_partition(graph, p):
        return None
"""

RC_PARTITIONER_CLEAN = """
    from repro.api.registry import register_partitioner

    @register_partitioner("demo", compute_backends=("xla", "ref", "pallas"), chunked=True)
    def demo_partition(graph, p, *, block=64, compute_backend="xla"):
        return None
"""

RC_PROGRAM_BAD = """
    from repro.graph.engine import VertexProgram, register_program

    SUMFIX = register_program(VertexProgram(name="sumfix", dtype="int32", combine="sum"))
    TYPO = register_program(VertexProgram(name="typo", dtype="int16"))
    DUP = register_program(VertexProgram(name="sumfix", dtype="int32"))
"""

RC_PROGRAM_CLEAN = """
    from repro.graph.engine import VertexProgram, register_program

    OK = register_program(VertexProgram(
        name="ok", dtype="float32", combine="sum", local="sweep", apply="pagerank",
    ))
"""

RC02_BAD = """
    import dataclasses

    @dataclasses.dataclass(frozen=True)
    class Config:
        blocks: list = []

        def __post_init__(self):
            object.__setattr__(self, "blocks", list(self.blocks))
"""

RC02_CLEAN = """
    import dataclasses

    @dataclasses.dataclass(frozen=True)
    class Config:
        blocks: tuple = ()

        def __post_init__(self):
            if not all(b > 0 for b in self.blocks):
                raise ValueError("blocks must be positive")
"""


def test_rc01_flags_capability_mismatches():
    fs = run(RC_PARTITIONER_BAD, select=["RC01"])
    assert codes(fs) == ["RC01", "RC01"]
    assert "compute_backend" in fs[0].message and "block" in fs[1].message


def test_rc01_partitioner_clean_twin_passes():
    assert run(RC_PARTITIONER_CLEAN, select=["RC01"]) == []


def test_rc01_flags_program_field_violations():
    msgs = " | ".join(f.message for f in run(RC_PROGRAM_BAD, select=["RC01"]))
    assert "combine='sum' requires local='sweep'" in msgs
    assert "int16" in msgs
    assert "already registered" in msgs


def test_rc01_program_clean_twin_passes():
    assert run(RC_PROGRAM_CLEAN, select=["RC01"]) == []


def test_rc02_flags_mutable_default_and_setattr():
    fs = run(RC02_BAD, select=["RC02"])
    assert codes(fs) == ["RC02", "RC02"]


def test_rc02_clean_twin_passes():
    assert run(RC02_CLEAN, select=["RC02"]) == []


# ------------------------------------------------------------------- DA01


DA_BAD = """
    import jax

    def _step(x, y):
        return x + y

    step = jax.jit(_step, donate_argnums=(0,))

    def drive(x, y):
        out = step(x, y)
        return out + x
"""

DA_CLEAN = """
    import functools

    import jax

    @functools.partial(jax.jit, donate_argnums=(1,))
    def _fused(sub, val):
        return val + 1

    def drive(sub, val):
        val = _fused(sub, val)
        return val
"""


def test_da01_flags_read_after_donation():
    fs = run(DA_BAD, select=["DA01"])
    assert codes(fs) == ["DA01"]
    assert "`x` was donated" in fs[0].message


def test_da01_rebinding_carry_passes():
    assert run(DA_CLEAN, select=["DA01"]) == []


# ----------------------------------------------------------------- hygiene


def test_ui01_flags_unused_import_and_honors_noqa():
    bad = """
        import os
        import sys

        print(sys.argv)
    """
    fs = run(bad, select=["UI01"])
    assert codes(fs) == ["UI01"] and fs[0].anchor == "os"
    assert run(bad.replace("import os", "import os  # noqa"), select=["UI01"]) == []


def test_ds01_flags_dead_store():
    bad = """
        def f(x):
            unused = x * 2
            return x
    """
    fs = run(bad, select=["DS01"])
    assert codes(fs) == ["DS01"]
    assert run(bad.replace("return x", "return unused"), select=["DS01"]) == []


def test_md01_flags_mutable_default():
    assert codes(run("def f(x, acc=[]):\n    return acc\n", select=["MD01"])) == ["MD01"]
    assert run("def f(x, acc=()):\n    return acc\n", select=["MD01"]) == []


# -------------------------------------------------- suppressions, baseline


def test_line_suppression_by_code():
    src = JIT_SYNC_BAD.replace(
        "return np.asarray(x) + 1", "return np.asarray(x) + 1  # repro: ignore[HS01]"
    )
    assert run(src, select=["HS01"]) == []
    wrong = JIT_SYNC_BAD.replace(
        "return np.asarray(x) + 1", "return np.asarray(x) + 1  # repro: ignore[XD01]"
    )
    assert codes(run(wrong, select=["HS01"])) == ["HS01"]


def test_bare_line_suppression_covers_all_codes():
    src = JIT_SYNC_BAD.replace(
        "return np.asarray(x) + 1", "return np.asarray(x) + 1  # repro: ignore"
    )
    assert run(src, select=["HS01"]) == []


def test_file_suppression():
    src = "# repro: ignore-file[HS01]\n" + textwrap.dedent(JIT_SYNC_BAD)
    assert analyze_sources({"src/repro/mod.py": src}, select=["HS01"]) == []


def test_baseline_roundtrip(tmp_path):
    fs = run(JIT_SYNC_BAD, select=["HS01"])
    assert fs
    path = tmp_path / "baseline.json"
    write_baseline(fs, path)
    baseline = load_baseline(path)
    assert apply_baseline(fs, baseline) == []
    assert load_baseline(tmp_path / "missing.json") == set()


def test_fingerprint_is_line_number_free():
    fs1 = run(JIT_SYNC_BAD, select=["HS01"])
    fs2 = run("\n\n" + textwrap.dedent(JIT_SYNC_BAD), select=["HS01"])
    assert fs1[0].line != fs2[0].line
    assert fs1[0].fingerprint == fs2[0].fingerprint


# ------------------------------------------------------------------- EH01


EH_BAD_PASS = """
    def load(path):
        try:
            return open(path).read()
        except Exception:
            pass
"""

EH_BAD_BARE = """
    def load(path):
        try:
            return open(path).read()
        except:
            ...
"""

EH_BAD_TUPLE = """
    def load(path):
        try:
            return open(path).read()
        except (ValueError, BaseException):
            pass
"""

EH_CLEAN_SPECIFIC = """
    def load(path):
        try:
            return open(path).read()
        except FileNotFoundError:
            pass
"""

EH_CLEAN_HANDLED = """
    import logging

    def load(path):
        try:
            return open(path).read()
        except Exception as e:
            logging.warning("load failed: %s", e)
            return None
"""


def test_eh01_flags_swallowed_broad_handlers():
    for src in (EH_BAD_PASS, EH_BAD_BARE, EH_BAD_TUPLE):
        fs = run(src, select=["EH01"])
        assert codes(fs) == ["EH01"], src
        assert fs[0].severity == "warning"
        assert "swallows" in fs[0].message


def test_eh01_allows_specific_or_handled():
    assert run(EH_CLEAN_SPECIFIC, select=["EH01"]) == []
    assert run(EH_CLEAN_HANDLED, select=["EH01"]) == []


def test_eh01_honors_noqa():
    src = EH_BAD_PASS.replace("except Exception:", "except Exception:  # noqa")
    assert run(src, select=["EH01"]) == []


# ---------------------------------------------------------------- CLI gate


def test_cli_fail_on_findings_exit_codes(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text("import jax\n\n@jax.jit\ndef f(x):\n    return float(x)\n")
    assert cli_main([str(bad), "--fail-on-findings", "--baseline", str(tmp_path / "b.json")]) == 1
    report = tmp_path / "report.json"
    assert cli_main([str(bad), "--json", str(report), "--baseline", str(tmp_path / "b.json")]) == 0
    payload = json.loads(report.read_text())
    assert [f["code"] for f in payload["findings"]] == ["HS01"]
    clean = tmp_path / "clean.py"
    clean.write_text("def f(x):\n    return x\n")
    assert cli_main([str(clean), "--fail-on-findings"]) == 0
    capsys.readouterr()


def test_cli_baseline_accepts_known_findings(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text("import jax\n\n@jax.jit\ndef f(x):\n    return float(x)\n")
    baseline = tmp_path / "baseline.json"
    assert cli_main([str(bad), "--baseline", str(baseline), "--write-baseline"]) == 0
    assert cli_main([str(bad), "--baseline", str(baseline), "--fail-on-findings"]) == 0
    capsys.readouterr()


# ------------------------------------------------------------ repo is clean


def test_repo_analyzes_clean_with_empty_baseline():
    """The CI gate's contract: the committed baseline is EMPTY and the
    whole package still analyzes clean — findings get fixed, not filed."""
    baseline_path = REPO_ROOT / "analysis_baseline.json"
    assert load_baseline(baseline_path) == set()
    findings = analyze_paths([REPO_ROOT / "src" / "repro"], root=REPO_ROOT)
    assert findings == [], "\n".join(f.render() for f in findings)
