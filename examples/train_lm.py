"""End-to-end training driver example: train a small LM for a few hundred
steps with checkpoint/restart (deliverable (b) end-to-end driver).

  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--preset", default="tiny")
    args = ap.parse_args()
    losses = train.main([
        "--preset", args.preset,
        "--steps", str(args.steps),
        "--ckpt-dir", "/tmp/repro_train_example",
        "--ckpt-every", "100",
        "--resume",
    ])
    drop = losses[0] - sum(losses[-10:]) / 10
    print(f"loss drop over run: {drop:.3f} (must be > 0)")
    assert drop > 0


if __name__ == "__main__":
    main()
