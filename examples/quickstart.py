"""Quickstart: EBG-partition a power-law graph, run subgraph-centric CC,
and compare the communication profile against DBH.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import dbh_partition, ebg_partition, partition_metrics
from repro.graph import algorithms as alg
from repro.graph.build import build_subgraphs
from repro.graph.generate import make_graph


def main():
    g = make_graph("tiny_powerlaw")
    print(f"graph: |V|={g.num_vertices} |E|={g.num_edges}")

    for name, partitioner in [("EBG", ebg_partition), ("DBH", dbh_partition)]:
        res = partitioner(g, 8)
        m = partition_metrics(g, res)
        sub = build_subgraphs(g, res, symmetrize=True)
        labels, stats = alg.connected_components(sub)
        ncc = np.unique(alg.scatter_to_global(sub, labels, g.num_vertices)).shape[0]
        print(
            f"{name}: replication={m.replication_factor:.2f} "
            f"edge_imb={m.edge_imbalance:.2f} vertex_imb={m.vertex_imbalance:.2f} | "
            f"CC supersteps={stats.supersteps} messages={stats.total_messages} "
            f"max/mean={stats.max_mean:.3f}"
        )
    print("EBG cuts fewer vertices -> fewer messages, same balance. (paper §V)")


if __name__ == "__main__":
    main()
