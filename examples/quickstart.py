"""Quickstart for the `repro.api` facade.

One `GraphPipeline` session owns the whole subgraph-centric lifecycle:

    pipeline = GraphPipeline(graph)                # bind a graph
    view = pipeline.partition("ebg", parts=8)      # pick a registered partitioner
    run = view.run("cc")                           # build + BSP engine + stats

Stages are lazy and cached per partition view — `view.metrics`,
`view.result`, and repeated `run` calls never recompute a stage. The
partitioner names ("ebg", "dbh", ...) come from the `repro.api`
registry; per-algorithm knobs are frozen config dataclasses
(`EBGConfig(alpha, beta, ...)`, `HashConfig(seed)`, ...), passed either
as `config=` or as keyword overrides:

    pipeline.partition("ebg", parts=8, alpha=2.0).run("sssp")

Here we EBG-partition a power-law graph, run subgraph-centric connected
components, and compare the communication profile against DBH, as in
paper §V:

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import GraphPipeline, list_partitioners
from repro.graph.generate import make_graph


def main():
    g = make_graph("tiny_powerlaw")
    print(f"graph: |V|={g.num_vertices} |E|={g.num_edges}")
    print("registered partitioners:", ", ".join(s.name for s in list_partitioners()))

    pipeline = GraphPipeline(g)
    for name in ("ebg", "dbh"):
        run = pipeline.partition(name, parts=8).run("cc")
        m = run.metrics
        print(
            f"{name.upper()}: replication={m.replication_factor:.2f} "
            f"edge_imb={m.edge_imbalance:.2f} vertex_imb={m.vertex_imbalance:.2f} | "
            f"CC components={run.num_components()} supersteps={run.stats.supersteps} "
            f"messages={run.stats.total_messages} max/mean={run.stats.max_mean:.3f}"
        )
    print("EBG cuts fewer vertices -> fewer messages, same balance. (paper §V)")


if __name__ == "__main__":
    main()
