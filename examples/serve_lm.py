"""Batched-serving example: prefill + greedy decode on an assigned arch
(reduced config) — exercises KV caches, GQA decode, the serve_step path.

  PYTHONPATH=src python examples/serve_lm.py --arch qwen3_4b
"""
import argparse

from repro.launch import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args()
    serve.main(["--arch", args.arch, "--tokens", str(args.tokens), "--batch", "4"])


if __name__ == "__main__":
    main()
