"""Beyond-paper example: EBG as a MoE expert-placement algorithm.

The token→expert routing graph of a trained MoE is power-law (hot experts).
Placing experts on EP devices is exactly the paper's problem: minimize
cross-device traffic (replication ≙ re-dispatched tokens) while balancing
per-device load (edge/vertex balance ≙ expert FLOPs balance). We build the
expert co-activation graph from routing statistics, partition it with EBG
vs random hash, and compare the predicted all-to-all imbalance.

  PYTHONPATH=src python examples/expert_placement.py
"""
import numpy as np

from repro.core.placement import ebg_expert_placement, placement_report


def main():
    rng = np.random.default_rng(0)
    E, devices, T = 64, 8, 200_000
    # zipf-ish routing: a few hot experts (as observed in real MoEs)
    popularity = 1.0 / (1 + np.arange(E)) ** 0.9
    popularity /= popularity.sum()
    pairs = rng.choice(E, size=(T, 2), p=popularity)  # top-2 co-activations

    perm_ebg = ebg_expert_placement(pairs, E, devices)
    perm_rand = np.argsort(rng.random(E))

    for name, perm in [("EBG placement", perm_ebg), ("random placement", perm_rand)]:
        rep = placement_report(pairs, perm, E, devices)
        print(f"{name}: load max/mean={rep['load_max_mean']:.3f} "
              f"cross-device pair traffic={rep['cross_frac']:.1%}")
    print("EBG placement balances hot experts AND co-locates co-activated pairs.")


if __name__ == "__main__":
    main()
