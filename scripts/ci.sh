#!/usr/bin/env bash
# Tier-1 verification: the repo's canonical test command.
#
#   scripts/ci.sh            # full tier-1 run + backend-parity suite
#   scripts/ci.sh -k api     # extra pytest args pass through (parity suite skipped)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
# Static-analysis gate (tracer safety, kernel contracts, registry
# consistency — see docs/api.md "Static analysis"): runs in BOTH
# invocation modes so a host-sync leak or impl-pair drift fails CI even
# when pytest args filter the relevant suites out. The no-arg run also
# emits ANALYSIS_report.json next to BENCH_pipeline.json.
if [ "$#" -gt 0 ]; then
  python -m repro.analysis --fail-on-findings
else
  python -m repro.analysis --fail-on-findings --json ANALYSIS_report.json
fi
python -m pytest -x -q "$@"
if [ "$#" -gt 0 ]; then
  # Extra args may have filtered out the backend-parity, VertexProgram,
  # streaming-scorer, and serving suites (xla vs ref vs pallas-interpret
  # engine, chunked bitset + EdgeScorer scan/chunked/oracle parity,
  # BFS/reach oracles, distributed PageRank, batched-BSP/server parity) —
  # always run them, so an engine, partitioner, or serving regression
  # fails loudly in every invocation mode. The no-arg run above already
  # includes them.
  python -m pytest -q tests/test_backends.py tests/test_programs.py tests/test_streaming.py tests/test_serve.py
else
  # Benchmark smoke: partition -> build -> engine at p=32, emitting
  # BENCH_pipeline.json (partition/build walls, Table-III quality row per
  # streaming EdgeScorer, per-program supersteps/s and messages for every
  # registered VertexProgram, host-vs-fused driver comparison,
  # distributed-PageRank section, and the schema-4 serving section:
  # batched-vs-sequential throughput + trace replay through the
  # GraphQueryServer) so the perf trajectory is tracked.
  python -m benchmarks.pipeline_smoke
fi
# Serving smoke trace: a tiny end-to-end replay through the admission
# queue + executable cache, in BOTH invocation modes — a broken server
# loop fails CI even when pytest args filter the serving suite out.
python -m repro.launch.graph_serve --vertices 1024 --edges 8000 --parts 4 --queries 32 --rate 4000
