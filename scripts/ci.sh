#!/usr/bin/env bash
# Tier-1 verification: the repo's canonical test command.
#
#   scripts/ci.sh            # full tier-1 run + backend-parity suite
#   scripts/ci.sh -k api     # extra pytest args pass through (parity suite skipped)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
python -m pytest -x -q "$@"
if [ "$#" -gt 0 ]; then
  # Extra args may have filtered out the backend-parity suite (xla vs ref
  # vs pallas-interpret engine + chunked EBG bitset) — always run it, so a
  # backend regression fails loudly in every invocation mode. The no-arg
  # run above already includes it.
  python -m pytest -q tests/test_backends.py
else
  # Benchmark smoke: partition -> build -> engine at p=32, emitting
  # BENCH_pipeline.json (partition/build walls, supersteps/s, messages,
  # host-vs-fused driver comparison) so the perf trajectory is tracked.
  python -m benchmarks.pipeline_smoke
fi
