#!/usr/bin/env bash
# Tier-1 verification: the repo's canonical test command.
#
#   scripts/ci.sh            # full tier-1 run
#   scripts/ci.sh -k api     # extra pytest args pass through
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
