#!/usr/bin/env bash
# Tier-1 verification: the repo's canonical test command.
#
#   scripts/ci.sh            # full tier-1 run + backend-parity suite
#   scripts/ci.sh -k api     # extra pytest args pass through (parity suite skipped)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
python -m pytest -x -q "$@"
if [ "$#" -gt 0 ]; then
  # Extra args may have filtered out the backend-parity, VertexProgram,
  # and streaming-scorer suites (xla vs ref vs pallas-interpret engine,
  # chunked bitset + EdgeScorer scan/chunked/oracle parity, BFS/reach
  # oracles, distributed PageRank) — always run them, so an engine or
  # partitioner regression fails loudly in every invocation mode. The
  # no-arg run above already includes them.
  python -m pytest -q tests/test_backends.py tests/test_programs.py tests/test_streaming.py
else
  # Benchmark smoke: partition -> build -> engine at p=32, emitting
  # BENCH_pipeline.json (partition/build walls, Table-III quality row per
  # streaming EdgeScorer, per-program supersteps/s and messages for every
  # registered VertexProgram, host-vs-fused driver comparison,
  # distributed-PageRank section) so the perf trajectory is tracked.
  python -m benchmarks.pipeline_smoke
fi
