#!/usr/bin/env bash
# Tier-1 verification: the repo's canonical test command.
#
#   scripts/ci.sh            # full tier-1 run + backend-parity suite
#   scripts/ci.sh -k api     # extra pytest args pass through (parity suite skipped)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
# Static-analysis gate (tracer safety, kernel contracts, registry
# consistency — see docs/api.md "Static analysis"): runs in BOTH
# invocation modes so a host-sync leak or impl-pair drift fails CI even
# when pytest args filter the relevant suites out. The no-arg run also
# emits ANALYSIS_report.json next to BENCH_pipeline.json.
if [ "$#" -gt 0 ]; then
  python -m repro.analysis --fail-on-findings
else
  python -m repro.analysis --fail-on-findings --json ANALYSIS_report.json
fi
python -m pytest -x -q "$@"
if [ "$#" -gt 0 ]; then
  # Extra args may have filtered out the backend-parity, VertexProgram,
  # streaming-scorer, serving, and resilience suites (xla vs ref vs
  # pallas-interpret engine, chunked bitset + EdgeScorer
  # scan/chunked/oracle parity, BFS/reach oracles, distributed PageRank,
  # batched-BSP/server parity, crash/resume bit-parity + chaos serving) —
  # always run them, so an engine, partitioner, serving, or
  # fault-tolerance regression fails loudly in every invocation mode.
  # The no-arg run above already includes them.
  python -m pytest -q tests/test_backends.py tests/test_programs.py tests/test_streaming.py tests/test_serve.py tests/test_resilience.py
else
  # Benchmark smoke: partition -> build -> engine at p=32, emitting
  # BENCH_pipeline.json (partition/build walls, Table-III quality row per
  # streaming EdgeScorer, per-program supersteps/s and messages for every
  # registered VertexProgram, host-vs-fused driver comparison,
  # distributed-PageRank section, the serving section: batched-vs-
  # sequential throughput + trace replay through the GraphQueryServer,
  # the resilience section: crash/resume bit-parity with
  # resume_matches_uninterrupted asserted + a chaos serving trace with
  # retry/shed counters, and the schema-6 megakernel section: per-program
  # xla vs Pallas-superstep walls + window-commit partition wall) so the
  # perf trajectory is tracked.
  python -m benchmarks.pipeline_smoke
  # Hold the contracts in the emitted artifact itself: schema 7, the
  # megakernel section with every parity flag true (bit-identical
  # xla/pallas engine results and window-commit == scan assignments), and
  # the scale section (out-of-core pipeline twin: >= 4 shards, two-level
  # addressing, bit-parity with the in-memory pipeline, per-stage RSS).
  python - <<'PY'
import json
d = json.load(open("BENCH_pipeline.json"))
assert d["schema"] == 7, d["schema"]
mk = d["megakernel"]
assert mk["parity_all"] is True, mk["programs"]
assert all(row["parity"] is True for row in mk["programs"].values()), mk["programs"]
assert mk["window_commit"]["matches_scan"] is True, mk["window_commit"]
sc = d["scale"]
assert sc["matches_in_memory"] is True, sc
assert sc["graph"]["num_shards"] >= 4, sc["graph"]
assert sc["addressing"] == "two_level", sc
assert {"rmat_to_store", "partition", "build", "cc"} <= set(sc["stages"]), sc["stages"]
assert all("peak_rss_mb" in st for st in sc["stages"].values()), sc["stages"]
print("megakernel + scale sections OK: schema 7, parity flags all true")
PY
  # Downscaled out-of-core smoke: 2^16 vertices streamed from >= 4
  # shards; run_scale()'s parity twin asserts out-of-core == in-memory
  # (partition assignments AND CC labels, bit-for-bit).
  python - <<'PY'
from benchmarks.scale_pipeline import run_scale
row = run_scale()
assert row["matches_in_memory"] is True and row["graph"]["num_shards"] >= 4, row
print("out-of-core smoke OK: oc == in-memory on", row["graph"]["num_shards"], "shards")
PY
fi
# Serving smoke trace: a tiny end-to-end replay through the admission
# queue + executable cache, in BOTH invocation modes — a broken server
# loop fails CI even when pytest args filter the serving suite out.
python -m repro.launch.graph_serve --vertices 1024 --edges 8000 --parts 4 --queries 32 --rate 4000
# Chaos smoke: the same trace with deterministic injected transient
# faults and stragglers through the retry/backoff path. The driver
# asserts every query terminates (answered within the retry budget or a
# named timeout/shed failure) with zero unhandled exceptions.
python -m repro.launch.graph_serve --vertices 1024 --edges 8000 --parts 4 --queries 32 --rate 4000 \
  --fault-seed 11 --transient-prob 0.2 --straggler-prob 0.15 --straggler-delay-ms 5 --max-retries 4
