"""CI benchmark smoke: one partition → build → run pipeline at p=32.

Emits machine-readable `BENCH_pipeline.json` at the repo root so the perf
trajectory is tracked from PR 3 onward: partition wall, build wall
(vectorized vs legacy builder), a partition-quality section (replication
factor and edge/vertex imbalance per registered streaming EdgeScorer —
the paper's Table-III comparison regenerated on every CI run), and for
EVERY registered engine program (CC, SSSP, BFS, reachability, PageRank —
all through the one generic `VertexProgram` driver) the host- vs
fused-driver wall, supersteps/s, dispatch counts, and message stats, plus
a distributed-PageRank section (sim-vs-dist value match, messages,
supersteps) run on a forced 8-device host mesh in a subprocess, and a
serving section: batched-vs-sequential throughput at B=8
through the new `repro.serve` tier (asserted >= 2x), plus a synthetic
power-law trace replayed through the `GraphQueryServer` admission queue
(p50/p99 queue latency, padding waste, executable-cache hit rate; the
cache is asserted to compile at most once per (program, bucket)), and a
resilience section: crash/resume bit-parity
(`resume_matches_uninterrupted` asserted) plus a chaos serving trace with
injected transient faults (retry/shed counters; every query asserted to
terminate answered-or-named-failure), and a megakernel section (schema 6):
per-program xla-fused vs Pallas-superstep-megakernel walls with asserted
bit-parity (interpreter walls on a CPU host; the compiled path lights up
on accelerators) plus the window-commit partition wall vs the faithful
scan (`matches_scan` asserted) and the frozen chunked commit, and a
scale section (schema 7): the out-of-core pipeline — sharded rmat ->
external degree-sum order -> streamed partition -> streamed two-level
build -> CC — on a downscaled twin with per-stage wall + peak-RSS
metering and `matches_in_memory` (bit-parity against the fully
in-memory pipeline) asserted; `python -m benchmarks.scale_pipeline
--full` runs the same pipeline at 2^25 vertices / 2^27 edges. The main
partition/build stages also record the peak-RSS high-water mark.

Two speedup figures per engine program:
  - wall_speedup: measured host/fused wall ratio. On a CPU host, dispatch
    is cheap and per-superstep compute dominates, so this hovers near 1;
    on accelerators the per-step host round-trip is the cost the fused
    driver deletes.
  - dispatch_reduction: host dispatches per run (== supersteps) vs the
    fused driver's single dispatch — the structural, hardware-independent
    improvement (asserted >= 2x).

Usage: python -m benchmarks.pipeline_smoke [repeats]
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

import numpy as np

from repro.api import GraphPipeline, list_partitioners
from repro.core.streaming import streaming_chunked_partition, streaming_scan_partition
from repro.graph.build import build_subgraphs, build_subgraphs_legacy
from repro.graph.generate import rmat

P = 32
OUT = Path(__file__).resolve().parents[1] / "BENCH_pipeline.json"
SRC = Path(__file__).resolve().parents[1] / "src"

# Every registered program, with its engine kwargs. PageRank runs its
# fixed-iteration mode; the rest run to fixpoint.
PROGRAMS = (("cc", {}), ("sssp", {}), ("bfs", {}), ("reach", {}), ("pr", {"num_iters": 20}))

_DIST_PR_CODE = """
import json
import numpy as np
from repro.api import GraphPipeline
from repro.graph.generate import rmat
from repro.launch.mesh import make_host_mesh

g = rmat(1 << 12, 40_000, seed=7, a=0.65, b=0.15, c=0.15)
pipe = GraphPipeline(g).partition("ebg_chunked", parts=8)
mesh = make_host_mesh(8)
sim = pipe.run("pr", num_iters=10)
import time
t0 = time.perf_counter()
dist = pipe.run("pr", mode="dist", mesh=mesh, num_iters=10)
wall = time.perf_counter() - t0
print(json.dumps({
    "p": 8,
    "supersteps": dist.stats.supersteps,
    "messages_total": dist.stats.total_messages,
    "messages_max_mean": round(float(dist.stats.max_mean), 3),
    "matches_sim": bool(np.array_equal(sim.values, dist.values)),
    "wall_s": round(wall, 4),
}))
"""


def _partition_quality_section(graph, main_pipe) -> dict:
    """Table-III row per registered streaming EdgeScorer: one chunked
    partitioner per scorer at the smoke p, through
    `repro.core.metrics.partition_metrics`. The main pipeline IS the ebv
    row — its cached partition/metrics are reused, not recomputed. Walls
    are NOT emitted here (the ebv partition is already cached and the
    others would pay jit compile): `partition.wall_s` is the tracked
    partition-perf number; this section tracks quality only."""
    rows = {}
    for spec in list_partitioners():
        if spec.scorer is None or not spec.chunked:
            continue
        pipe = main_pipe if spec.name == main_pipe.partitioner.name else (
            GraphPipeline(graph).partition(spec.name, parts=P)
        )
        rows[spec.scorer] = {"partitioner": spec.name, **pipe.metrics.row()}
    return rows


def _med(fn, repeats: int) -> float:
    walls = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        walls.append(time.perf_counter() - t0)
    return float(np.median(walls))


def _best(fn, repeats: int) -> float:
    """Min-of-repeats: the standard microbenchmark estimator for walls
    whose noise is one-sided (GC pauses, scheduler preemption only ever
    ADD time). The engine host-vs-fused ratios sit near 1 on a CPU host,
    where median-of-3 jitter used to flip speedups below 1.0."""
    walls = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        walls.append(time.perf_counter() - t0)
    return float(np.min(walls))


def _dist_pagerank_section() -> dict:
    """Distributed PageRank stats on an 8-device host mesh. XLA locks the
    device count at first init, so this runs in a subprocess with its own
    XLA_FLAGS (exactly how the system tests do it)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(SRC)
    out = subprocess.run(
        [sys.executable, "-c", _DIST_PR_CODE],
        capture_output=True, text=True, env=env, timeout=560,
    )
    if out.returncode != 0:
        return {"error": (out.stderr or out.stdout).strip()[-500:]}
    return json.loads(out.stdout.strip().splitlines()[-1])


def _serving_section(repeats: int) -> dict:
    """The serving tier at smoke scale: one batched B=8 dispatch vs 8
    sequential single-query runs (same facade, same fused driver), then a
    synthetic power-law trace through the admission queue. Runs on the
    serve-smoke graph (4K vertices, p=8) — the per-query regime where a
    production server lives, not the one-big-job regime above."""
    from repro.serve.trace import synthetic_trace

    B = 8
    graph = rmat(1 << 12, 40_000, seed=11, a=0.65, b=0.15, c=0.15)
    pipe = GraphPipeline(graph).partition("ebg_chunked", parts=8)
    cov = graph.covered_vertices()
    srcs = [int(v) for v in cov[np.argsort(-graph.degrees()[cov])[:B]]]

    batch_run = pipe.run_batch("bfs", srcs)  # warmup doubles as the parity run
    singles = [pipe.run("bfs", source=s) for s in srcs]
    for i in range(B):  # the serving tier's core claim, held in CI too
        assert np.array_equal(batch_run.values[i], singles[i].values), i
        assert batch_run.stats[i].supersteps == singles[i].stats.supersteps, i
    seq_wall = _med(lambda: [pipe.run("bfs", source=s) for s in srcs], repeats)
    batch_wall = _med(lambda: pipe.run_batch("bfs", srcs), repeats)
    speedup = seq_wall / batch_wall

    server = pipe.serve(max_batch=B, max_delay_s=0.005)
    trace = synthetic_trace(graph, 96, rate_qps=4000.0, seed=3)
    report = server.run_trace(trace)  # run_trace pre-warms every (program, bucket)
    trace_row = report.row()

    assert speedup >= 2.0, (seq_wall, batch_wall)
    assert trace_row["cache"]["compiles_per_key_max"] <= 1, trace_row["cache"]
    assert trace_row["queries"] == 96, trace_row
    return {
        "graph": {"family": "serve_smoke", "num_vertices": graph.num_vertices,
                  "num_edges": graph.num_edges, "p": 8},
        "batch": {
            "program": "bfs",
            "B": B,
            "seq_wall_s": round(seq_wall, 4),
            "batch_wall_s": round(batch_wall, 4),
            "throughput_speedup": round(speedup, 2),
            "supersteps_per_query": batch_run.supersteps_per_query.tolist(),
        },
        "trace": trace_row,
    }


def _megakernel_section(repeats: int) -> dict:
    """Tentpole before/after (schema 6): the xla fused driver vs the Pallas
    superstep megakernel (`compute_backend="pallas"` routes the whole local
    stage through `ops.bsp_superstep`) for every registered program, plus
    the speculative window-commit partition wall vs the faithful scan and
    the frozen chunked commit.

    Off-TPU the megakernel runs under the Pallas INTERPRETER, so the pallas
    walls here track the parity cost on a CPU host, not accelerator
    speedup — the compiled path lights up on TPU. What CI holds the line on
    is the parity flags: values and BSPStats bit-identical to the xla path
    per program, and window-commit assignments identical to the scan.
    Runs on a smaller graph than the main engine section (interpreter
    walls, not device walls)."""
    block_e = 256
    graph = rmat(1 << 11, 12_000, seed=9, a=0.65, b=0.15, c=0.15)
    pipe = GraphPipeline(graph).partition("ebg_chunked", parts=8)
    programs: dict = {}
    for prog, kw in PROGRAMS:
        runs, wall = {}, {}
        for backend in ("xla", "pallas"):
            pipe.run(prog, compute_backend=backend, block_e=block_e, **kw)  # compile
            runs[backend] = pipe.run(prog, compute_backend=backend, block_e=block_e, **kw)
            wall[backend] = _best(
                lambda b=backend: pipe.run(prog, compute_backend=b, block_e=block_e, **kw),
                repeats,
            )
        x, k = runs["xla"], runs["pallas"]
        parity = (
            bool(np.array_equal(x.values, k.values))
            and x.stats.supersteps == k.stats.supersteps
            and bool(np.array_equal(x.stats.messages_per_step_worker,
                                    k.stats.messages_per_step_worker))
            and bool(np.array_equal(x.stats.inner_iters_per_step,
                                    k.stats.inner_iters_per_step))
        )
        programs[prog] = {
            "supersteps": x.stats.supersteps,
            "xla_wall_s": round(wall["xla"], 4),
            "pallas_wall_s": round(wall["pallas"], 4),
            "parity": parity,
        }

    scan = streaming_scan_partition(graph, 8, "ebv")
    win = streaming_chunked_partition(graph, 8, "ebv", block=block_e, commit="window")
    walls = {
        "scan_wall_s": _best(lambda: streaming_scan_partition(graph, 8, "ebv"), repeats),
        "frozen_wall_s": _best(
            lambda: streaming_chunked_partition(graph, 8, "ebv", block=block_e, commit="frozen"),
            repeats,
        ),
        "window_wall_s": _best(
            lambda: streaming_chunked_partition(graph, 8, "ebv", block=block_e, commit="window"),
            repeats,
        ),
    }
    window = {
        "scorer": "ebv",
        "block": block_e,
        **{k: round(v, 4) for k, v in walls.items()},
        "window_speedup_vs_scan": round(walls["scan_wall_s"] / walls["window_wall_s"], 2),
        "matches_scan": bool(np.array_equal(win.part, scan.part)),
    }
    return {
        "graph": {"family": "megakernel_smoke", "num_vertices": graph.num_vertices,
                  "num_edges": graph.num_edges, "p": 8},
        "block_e": block_e,
        "programs": programs,
        "parity_all": all(row["parity"] for row in programs.values()),
        "window_commit": window,
    }


def _resilience_section() -> dict:
    """Chaos smoke (schema 5): the fault-tolerance claims held in CI.

    1. Crash/resume bit-parity: run CC with checkpointing and a seeded
       worker crash, resume from the checkpoint directory, and assert
       values AND BSPStats are bit-identical to the uninterrupted run
       (`resume_matches_uninterrupted`).
    2. Chaos serving: a short trace through `run_graph_serve` with
       injected transient faults and stragglers — every query must
       terminate (answered within the retry budget or failed with a
       named reason), zero unhandled exceptions.
    """
    import shutil
    import tempfile

    from repro.launch.graph_serve import run_graph_serve
    from repro.resilience import FaultPlan, WorkerCrashError, resume_bsp

    graph = rmat(1 << 12, 40_000, seed=13, a=0.65, b=0.15, c=0.15)
    pipe = GraphPipeline(graph).partition("ebg_chunked", parts=8)
    base = pipe.run("cc")
    crash_step = max(1, base.stats.supersteps // 2)
    ckpt_dir = tempfile.mkdtemp(prefix="bench_resilience_")
    try:
        t0 = time.perf_counter()
        try:
            pipe.run(
                "cc", checkpoint_every=1, ckpt_dir=ckpt_dir,
                fault_plan=FaultPlan(seed=5, crash_at_superstep=crash_step),
            )
            crashed = False
        except WorkerCrashError:
            crashed = True
        # CC builds the symmetrized subgraphs; resume against the SAME build
        # (the resume metadata fingerprints the SubgraphSet dims).
        vals, stats = resume_bsp(base.subgraphs, ckpt_dir=ckpt_dir)
        resume_wall = time.perf_counter() - t0
        matches = (
            bool(np.array_equal(np.asarray(vals)[:, :-1], base.values))
            and stats.supersteps == base.stats.supersteps
            and np.array_equal(stats.messages_per_step_worker,
                               base.stats.messages_per_step_worker)
            and np.array_equal(stats.inner_iters_per_step, base.stats.inner_iters_per_step)
        )
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)

    chaos = run_graph_serve(
        num_vertices=1 << 11, num_edges=16_000, parts=4, queries=48,
        rate_qps=4000.0, max_batch=8, seed=3,
        fault_seed=11, transient_prob=0.2, straggler_prob=0.15,
        straggler_delay_s=0.005, max_retries=4,
    )
    res = chaos["resilience"]
    assert res["terminated"] == 48, res  # every query accounted for
    assert res["answered"] + res["failed"] == 48, res
    # seed=11 is chosen so the deterministic draws actually fire: the
    # trace must exercise the retry path, not just pass fault-free.
    assert res["faults_injected"] > 0 and res["retries"] > 0, res
    return {
        "crash_resume": {
            "program": "cc",
            "crash_at_superstep": crash_step,
            "crashed": crashed,
            "resume_matches_uninterrupted": matches,
            "wall_s": round(resume_wall, 4),
        },
        "chaos_serving": {
            "queries": 48,
            "transient_prob": 0.2,
            "straggler_prob": 0.15,
            **res,
        },
    }


def main(repeats: int = 3, out_path: Path = OUT) -> dict:
    # twitter_like family at smoke scale: heavy-tailed rmat, p=32 workers.
    graph = rmat(1 << 14, 200_000, seed=7, a=0.65, b=0.15, c=0.15)
    pipe = GraphPipeline(graph).partition("ebg_chunked", parts=P)

    from benchmarks.scale_pipeline import peak_rss_mb, run_scale

    t0 = time.perf_counter()
    result = pipe.result
    partition_s = time.perf_counter() - t0
    partition_rss = peak_rss_mb()

    build_s = _med(lambda: build_subgraphs(graph, result, symmetrize=True), repeats)
    build_legacy_s = _med(lambda: build_subgraphs_legacy(graph, result, symmetrize=True), repeats)
    build_rss = peak_rss_mb()

    quality = _partition_quality_section(graph, pipe)

    engine: dict = {}
    totals = {"host": 0.0, "fused": 0.0, "dispatches_host": 0, "dispatches_fused": 0}
    for prog, kw in PROGRAMS:
        pipe.prepare(prog)
        pipe.run(prog, driver="host", **kw)  # compile outside the timers
        run = pipe.run(prog, driver="fused", **kw)  # warmup doubles as the stats run
        wall = {d: _best(lambda d=d: pipe.run(prog, driver=d, **kw), repeats) for d in ("host", "fused")}
        steps = run.stats.supersteps
        engine[prog] = {
            "supersteps": steps,
            "messages_total": run.stats.total_messages,
            "messages_max_mean": round(float(run.stats.max_mean), 3),
            "host": {
                "wall_s": round(wall["host"], 4),
                "supersteps_per_s": round(steps / wall["host"], 1),
                "dispatches": steps,
            },
            "fused": {
                "wall_s": round(wall["fused"], 4),
                "supersteps_per_s": round(steps / wall["fused"], 1),
                "dispatches": 1,
            },
            "wall_speedup": round(wall["host"] / wall["fused"], 2),
            "dispatch_reduction": steps,
        }
        totals["host"] += wall["host"]
        totals["fused"] += wall["fused"]
        totals["dispatches_host"] += steps
        totals["dispatches_fused"] += 1

    dist_pr = _dist_pagerank_section()
    serving = _serving_section(repeats)
    resilience = _resilience_section()
    megakernel = _megakernel_section(repeats)
    scale = run_scale()

    data = {
        "schema": 7,
        "graph": {"family": "twitter_like_smoke", "num_vertices": graph.num_vertices,
                  "num_edges": graph.num_edges, "p": P},
        "partition": {"partitioner": "ebg_chunked", "wall_s": round(partition_s, 3),
                      "peak_rss_mb": partition_rss},
        "partition_quality": quality,
        "build": {
            "wall_s": round(build_s, 3),
            "legacy_wall_s": round(build_legacy_s, 3),
            "speedup_vs_legacy": round(build_legacy_s / build_s, 2),
            "peak_rss_mb": build_rss,
        },
        "engine": {
            **engine,
            "total": {
                "host_wall_s": round(totals["host"], 4),
                "fused_wall_s": round(totals["fused"], 4),
                "wall_speedup": round(totals["host"] / totals["fused"], 2),
                "dispatch_reduction": round(totals["dispatches_host"] / totals["dispatches_fused"], 1),
            },
        },
        "dist": {"pr": dist_pr},
        "serving": serving,
        "resilience": resilience,
        "megakernel": megakernel,
        "scale": scale,
    }
    # The structural claims CI holds the line on: the fused driver turns
    # one-dispatch-per-superstep into one dispatch per run, distributed
    # PageRank (new with the VertexProgram engine) matches simulation, and
    # every registered streaming scorer produced a well-formed quality row
    # (the per-scorer replication/imbalance numbers themselves are the
    # tracked trajectory, not an asserted threshold).
    assert data["engine"]["total"]["dispatch_reduction"] >= 2.0, data["engine"]["total"]
    assert dist_pr.get("matches_sim", False), dist_pr
    assert set(quality) >= {"ebv", "hdrf", "greedy"}, quality
    for row in quality.values():
        assert row["replication_factor"] >= 1.0 and row["edge_imbalance"] >= 1.0, row
    # Fault-tolerance claims (schema 5): crash + resume is bit-identical
    # to the uninterrupted run, and the chaos trace lost nothing.
    assert resilience["crash_resume"]["crashed"], resilience["crash_resume"]
    assert resilience["crash_resume"]["resume_matches_uninterrupted"], resilience["crash_resume"]
    # Megakernel claims (schema 6): the Pallas superstep path is
    # bit-identical to xla for every program, window commits reproduce the
    # scan exactly, and the fused driver does not LOSE wall time vs host —
    # including reach, whose min-of-repeats wall used to flip below 1.0
    # under median-of-3 jitter.
    assert megakernel["parity_all"], megakernel["programs"]
    assert megakernel["window_commit"]["matches_scan"], megakernel["window_commit"]
    assert engine["reach"]["wall_speedup"] >= 1.0, engine["reach"]
    # Scale claims (schema 7): the out-of-core downscaled twin is
    # bit-identical to the in-memory pipeline, came from a real multi-shard
    # store, and ran under two-level addressing.
    assert scale["matches_in_memory"], scale
    assert scale["graph"]["num_shards"] >= 4, scale["graph"]
    assert scale["addressing"] == "two_level", scale

    out_path.write_text(json.dumps(data, indent=2) + "\n")
    e = data["engine"]["total"]
    progs = "/".join(name for name, _ in PROGRAMS)
    reps = " ".join(f"{k}={row['replication_factor']}" for k, row in quality.items())
    print(
        f"BENCH_pipeline [{progs}]: partition {partition_s:.2f}s | build {build_s:.3f}s "
        f"({data['build']['speedup_vs_legacy']}x vs legacy) | rep[{reps}] | "
        f"engine host {e['host_wall_s']:.3f}s "
        f"-> fused {e['fused_wall_s']:.3f}s ({e['wall_speedup']}x wall, "
        f"{e['dispatch_reduction']}x fewer dispatches) | dist pr msgs "
        f"{dist_pr.get('messages_total')} | serve B=8 "
        f"{serving['batch']['throughput_speedup']}x, cache hit "
        f"{serving['trace']['cache']['hit_rate']} | resume parity "
        f"{resilience['crash_resume']['resume_matches_uninterrupted']}, chaos retries "
        f"{resilience['chaos_serving']['retries']} | megakernel parity "
        f"{megakernel['parity_all']}, window "
        f"{megakernel['window_commit']['window_speedup_vs_scan']}x vs scan | scale "
        f"oc-parity {scale['matches_in_memory']}, rf {scale['replication_factor']}, "
        f"peak rss {scale['peak_rss_mb']}MB -> {out_path.name}"
    )
    return data


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 3)
