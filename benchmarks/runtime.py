"""Paper Figures 3 & 4: end-to-end BSP runtime of CC / PR / SSSP per
partitioner on power-law and road-like graphs.

One CPU simulates all p workers, so wall-clock of the batched engine is NOT
parallel runtime. We report the paper's quantity with a calibrated BSP cost
model over measured per-worker work:

  T = Σ_k [ max_i(comp_i^k) + max_i(msg_i^k)·t_msg ]

comp_i^k = measured edge-relaxations (inner iterations × |E_i|) × t_edge,
with t_edge calibrated from the actual wall time of the batched compute.
This preserves exactly what the paper measures — the imbalance penalty
(stragglers) and the message volume — while staying hardware-honest.

`GraphPipeline.prepare` warms the partition/build caches so the timed
section measures only the engine.
"""
from __future__ import annotations

import time

from benchmarks.common import GRAPHS, PARTS, get_pipeline, load_graph, release_builds

T_MSG = 2.0e-7  # s per message (≈5M msgs/s/link, MPI-class small messages)


def simulated_runtime(stats, edges_per_worker, t_edge: float) -> float:
    """BSP parallel-time model from per-worker per-superstep work counts."""
    iters = stats.inner_iters_per_step  # [steps, p]
    comp = iters * edges_per_worker[None, :] * t_edge
    # The drivers record the real [steps, p] message matrix on-device, so
    # the per-step communication straggler is exact — no proportional-spread
    # approximation of per-worker totals.
    msg_per_step = stats.messages_per_step_worker  # [steps, p]
    per_step = comp.max(axis=1) + (msg_per_step * T_MSG).max(axis=1)
    return float(per_step.sum())


# Per-program engine kwargs — any registered VertexProgram name (cc, sssp,
# bfs, reach, pr, or a custom registration) is a valid `algos` entry; the
# facade resolves sources and build layouts per program.
ALGO_KW = {"pr": dict(num_iters=10)}


def run(scale: float = 1.0, algos=("cc", "pr", "sssp"), partitioners=PARTS,
        compute_backend="xla", warmup=False):
    out = {}
    for key in GRAPHS:
        _, p = load_graph(key, scale)
        for algo in algos:
            if key == "road_like" and algo == "pr":
                continue  # paper Fig.4 shows CC/SSSP only on USARoad
            row = {}
            for name in partitioners:
                pipe = get_pipeline(key, scale, name, p).prepare(algo)
                kw = dict(compute_backend=compute_backend, **ALGO_KW.get(algo, {}))
                run_once = lambda: pipe.run(algo, **kw)
                if warmup:
                    # Compile outside the timer with the EXACT call the
                    # timer makes: the fused driver's executable is keyed on
                    # max_supersteps/num_iters (they size the on-device stat
                    # buffers), so a reduced-step warmup would compile a
                    # different program and leave the compile in the wall.
                    run_once()
                t0 = time.time()
                r = run_once()
                wall = time.time() - t0
                edges = r.edges_per_worker
                total_work = float((r.stats.inner_iters_per_step * edges[None, :]).sum())
                t_edge = wall / max(total_work, 1.0)  # calibrate to this host
                sim = simulated_runtime(r.stats, edges, t_edge)
                row[name] = dict(sim_runtime_s=round(sim, 4), wall_s=round(wall, 2),
                                 supersteps=r.stats.supersteps)
            out[(key, algo)] = row
            cells = "  ".join(f"{n}:{row[n]['sim_runtime_s']:.3f}s" for n in partitioners)
            print(f"{algo.upper():4} {key:18} p={p:<3} {cells}")
        release_builds(key, scale)
    return out


def validate(results):
    """Fig.3 claim: EBG fastest (or tied) on power-law; Fig.4: NE/METIS
    competitive on road graphs."""
    wins = 0
    cases = 0
    for (key, algo), row in results.items():
        if key == "road_like" or "ebg" not in row:
            continue
        cases += 1
        best = min(row, key=lambda n: row[n]["sim_runtime_s"])
        if best == "ebg":
            wins += 1
        else:
            margin = row["ebg"]["sim_runtime_s"] / row[best]["sim_runtime_s"]
            if margin < 1.1:
                wins += 1  # within 10% of the winner
    print(f"\nEBG best-or-close on power-law: {wins}/{cases}")
    return wins, cases


def main(scale: float = 1.0, partitioners=PARTS, compute_backend="xla", warmup=False):
    res = run(scale, partitioners=partitioners, compute_backend=compute_backend, warmup=warmup)
    validate(res)
    return res


if __name__ == "__main__":
    main()
