"""Shared benchmark plumbing: graph set, pipeline cache, CSV output.

All sections drive `repro.api.GraphPipeline`; the partitioner list is
derived from the registry (capability flag `benchmark_default`), not
hand-maintained.
"""
from __future__ import annotations

import time

import numpy as np

from repro.api import GraphPipeline, benchmark_partitioners
from repro.graph.generate import make_graph

# Benchmark-scale analogues of the paper's datasets (Table I mapping in
# DESIGN.md). Sizes keep the full suite CPU-friendly; pass --full for 4x.
GRAPHS = {
    "livejournal_like": dict(name="livejournal_like", workers=12),
    "twitter_like": dict(name="twitter_like", workers=32),
    "road_like": dict(name="road_like", workers=12),
}

PARTS = list(benchmark_partitioners())


_GRAPH_CACHE: dict = {}
_PIPE_CACHE: dict = {}


def load_graph(key: str, scale: float = 1.0):
    spec = GRAPHS[key]
    ck = (key, scale)
    if ck in _GRAPH_CACHE:
        return _GRAPH_CACHE[ck], spec["workers"]
    kw = {}
    if scale != 1.0:
        from repro.graph.generate import REGISTRY

        base = REGISTRY[spec["name"]][1]
        if key == "road_like":
            kw = dict(side=max(32, int(base["side"] * scale ** 0.5)))
        else:
            import math

            v = max(4096, 2 ** round(math.log2(base["num_vertices"] * scale)))
            kw = dict(num_vertices=v, num_edges=int(base["num_edges"] * scale))
    g = make_graph(spec["name"], **kw)
    _GRAPH_CACHE[ck] = g
    return g, spec["workers"]


def get_pipeline(key: str, scale: float, name: str, p: int) -> GraphPipeline:
    """One pipeline per (graph, partitioner, parts), cached across benchmark
    modules — partition results, builds, and metrics are all reused."""
    ck = (key, scale, name, p)
    if ck not in _PIPE_CACHE:
        g, _ = load_graph(key, scale)
        _PIPE_CACHE[ck] = GraphPipeline(g).partition(name, parts=p)
    return _PIPE_CACHE[ck]


def release_builds(key: str | None = None, scale: float | None = None):
    """Drop cached SubgraphSets (partitions/metrics stay cached), optionally
    only for one (graph, scale). Sections call this after finishing a
    graph's row so peak RSS is one row's builds, not the whole suite's —
    builds are cheap to redo relative to partitioning."""
    for (k, s, _, _), pipe in _PIPE_CACHE.items():
        if (key is None or k == key) and (scale is None or s == scale):
            pipe.clear_builds()


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, time.time() - t0


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
