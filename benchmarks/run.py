"""Benchmark entry point — one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--scale 0.25] [--quick]

Prints ``name,us_per_call,derived`` CSV lines at the end for harnesses.
"""
from __future__ import annotations

import argparse
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.25,
                    help="graph size multiplier vs DESIGN.md defaults")
    ap.add_argument("--quick", action="store_true", help="partition metrics only")
    ap.add_argument("--skip-roofline", action="store_true")
    # Names are validated against the repro.api registry after parsing, so
    # `--help` / usage errors stay import-cheap (no jax load).
    ap.add_argument("--partitioners", nargs="+", metavar="NAME", default=None,
                    help="registry subset, e.g. ebg hdrf greedy dbh "
                         "(default: every benchmark_default partitioner, which "
                         "includes the streaming-scorer baselines hdrf/greedy)")
    ap.add_argument("--compute-backends", nargs="+", metavar="BACKEND", default=["xla"],
                    help="engine hot-path impls to run (xla | ref | pallas); more than "
                         "one A/Bs the runtime section per backend and records the speedup")
    args = ap.parse_args(argv)

    from repro.api import COMPUTE_BACKENDS, benchmark_partitioners, partitioner_names

    known = partitioner_names()
    parts = list(benchmark_partitioners()) if args.partitioners is None else args.partitioners
    unknown = [n for n in parts if n not in known]
    if unknown:
        ap.error(f"unknown partitioner(s) {unknown}; registered: {list(known)}")
    backends = list(dict.fromkeys(args.compute_backends))  # dedup, keep order
    bad = [b for b in backends if b not in COMPUTE_BACKENDS]
    if bad:
        ap.error(f"unknown compute backend(s) {bad}; valid: {list(COMPUTE_BACKENDS)}")

    from benchmarks import breakdown, messages, partition_tables, runtime, roofline

    csv: list[tuple[str, float, str]] = []

    t0 = time.time()
    res3 = partition_tables.main(args.scale, partitioners=parts)
    csv.append(("table1_table3_partition_metrics", (time.time() - t0) * 1e6,
                f"ebg_rep={res3['livejournal_like'].get('ebg', {}).get('replication_factor', 'n/a')}"))

    if not args.quick:
        t0 = time.time()
        res45 = messages.main(args.scale, partitioners=parts)
        ebg = res45["livejournal_like"].get("ebg", {})
        csv.append(("table4_table5_messages", (time.time() - t0) * 1e6,
                    f"ebg_msgs={ebg.get('total_messages', 'n/a')};maxmean={ebg.get('max_mean', 'n/a')}"))

        rt_by_backend = {}
        for backend in backends:
            t0 = time.time()
            # A/B runs warm up each backend first so wall_s (and the speedup
            # lines below) compare hot-path execution, not jit compiles.
            resrt = runtime.main(args.scale, partitioners=parts, compute_backend=backend,
                                 warmup=len(backends) > 1)
            rt_by_backend[backend] = resrt
            best = resrt[("livejournal_like", "cc")].get("ebg", {}).get("sim_runtime_s", "n/a")
            tag = "fig3_fig4_runtime" if backend == "xla" else f"fig3_fig4_runtime_{backend}"
            csv.append((tag, (time.time() - t0) * 1e6, f"ebg_cc={best}s"))
        # A/B: record wall-clock speedup of each backend vs the first one.
        base = backends[0]
        for other in backends[1:]:
            for (key, algo), row_b in rt_by_backend[base].items():
                row_o = rt_by_backend[other].get((key, algo), {})
                if "ebg" not in row_b or "ebg" not in row_o:
                    continue
                wall_b = max(row_b["ebg"]["wall_s"], 1e-3)
                wall_o = max(row_o["ebg"]["wall_s"], 1e-3)
                csv.append((f"backend_ab_{base}_vs_{other}[{key}/{algo}]", 0.0,
                            f"ebg_wall_speedup={wall_b / wall_o:.2f}x"))

        t0 = time.time()
        res2 = breakdown.main(min(args.scale, 0.25), partitioners=parts)
        csv.append(("table2_fig5_breakdown", (time.time() - t0) * 1e6,
                    f"ebg_exec={res2.get('ebg', {}).get('exec_time', float('nan')):.3f}s"))

    if not args.skip_roofline:
        try:
            rows = roofline.main()
            csv.append(("roofline_table", 0.0, f"cells={len(rows)}"))
        except Exception as e:  # dry-run output not present yet
            print(f"(roofline skipped: {e})")

    print("\nname,us_per_call,derived")
    for name, us, derived in csv:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
