"""Benchmark entry point — one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--scale 0.25] [--quick]

Prints ``name,us_per_call,derived`` CSV lines at the end for harnesses.
"""
from __future__ import annotations

import argparse
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.25,
                    help="graph size multiplier vs DESIGN.md defaults")
    ap.add_argument("--quick", action="store_true", help="partition metrics only")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args(argv)

    from benchmarks import breakdown, messages, partition_tables, runtime, roofline

    csv: list[tuple[str, float, str]] = []

    t0 = time.time()
    res3 = partition_tables.main(args.scale)
    csv.append(("table1_table3_partition_metrics", (time.time() - t0) * 1e6,
                f"ebg_rep={res3['livejournal_like']['ebg']['replication_factor']}"))

    if not args.quick:
        t0 = time.time()
        res45 = messages.main(args.scale)
        ebg = res45["livejournal_like"]["ebg"]
        csv.append(("table4_table5_messages", (time.time() - t0) * 1e6,
                    f"ebg_msgs={ebg['total_messages']};maxmean={ebg['max_mean']}"))

        t0 = time.time()
        resrt = runtime.main(args.scale)
        best = resrt[("livejournal_like", "cc")]["ebg"]["sim_runtime_s"]
        csv.append(("fig3_fig4_runtime", (time.time() - t0) * 1e6, f"ebg_cc={best}s"))

        t0 = time.time()
        res2 = breakdown.main(min(args.scale, 0.25))
        csv.append(("table2_fig5_breakdown", (time.time() - t0) * 1e6,
                    f"ebg_exec={res2['ebg']['exec_time']:.3f}s"))

    if not args.skip_roofline:
        try:
            rows = roofline.main()
            csv.append(("roofline_table", 0.0, f"cells={len(rows)}"))
        except Exception as e:  # dry-run output not present yet
            print(f"(roofline skipped: {e})")

    print("\nname,us_per_call,derived")
    for name, us, derived in csv:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
