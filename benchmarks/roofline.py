"""§Roofline report: read experiments/dryrun JSONs → markdown tables.

Per (arch × shape × mesh): the three roofline terms (seconds), dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS usefulness ratio, HBM/device, and the
roofline fraction (model-flops time at peak / bound term) used as the
perf score.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.launch.roofline import PEAK_FLOPS


def load_records(dirpath="experiments/dryrun"):
    recs = []
    for p in sorted(Path(dirpath).glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def effective_terms(rec) -> dict:
    """Bound terms with the compute term floored at MODEL_FLOPS/peak —
    XLA's CPU cost model undercounts decode matvecs, and a program can
    never beat its own useful-FLOPs time."""
    mf = rec.get("model_flops_per_device") or 0.0
    compute = max(rec["compute_s"], mf / PEAK_FLOPS)
    terms = dict(compute_s=compute, memory_s=rec["memory_s"],
                 collective_s=rec["collective_s"])
    bot = max(terms, key=terms.get)
    terms["bottleneck"] = bot.replace("_s", "")
    terms["bound_s"] = terms[bot]
    return terms


def roofline_fraction(rec) -> float | None:
    """model-useful compute time / achieved bound time (≤1; higher = closer
    to roofline). This is the §Perf score."""
    if not rec.get("model_flops_per_device"):
        return None
    t = effective_terms(rec)
    ideal = rec["model_flops_per_device"] / PEAK_FLOPS
    return ideal / t["bound_s"] if t["bound_s"] else None


def table(recs, plan="baseline", mesh=None):
    rows = []
    for r in recs:
        if r.get("plan", "baseline") != plan:
            continue
        if mesh and r["mesh"] != mesh:
            continue
        frac = roofline_fraction(r)
        t = effective_terms(r)
        rows.append(
            dict(
                cell=f"{r['arch']}×{r['shape']}",
                mesh=r["mesh"],
                compute_s=t["compute_s"],
                memory_s=t["memory_s"],
                collective_s=t["collective_s"],
                bottleneck=t["bottleneck"],
                hbm_gib=round(r.get("per_device_hbm_total", 0) / 2**30, 1),
                useful=round(min(r.get("useful_flops_frac") or 0, 1.0), 3),
                roofline_frac=round(frac, 4) if frac else None,
            )
        )
    return rows


def render_md(rows) -> str:
    hdr = ("| cell | mesh | compute s | memory s | collective s | bottleneck "
           "| HBM GiB/dev | useful | roofline frac |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['cell']} | {r['mesh']} | {r['compute_s']:.4g} | {r['memory_s']:.4g} "
            f"| {r['collective_s']:.4g} | {r['bottleneck']} | {r['hbm_gib']} "
            f"| {r['useful']} | {r['roofline_frac']} |"
        )
    return "\n".join(lines)


def main(dirpath="experiments/dryrun"):
    recs = load_records(dirpath)
    rows = table(recs, mesh="16x16")
    print(render_md(rows))
    worst = [r for r in rows if r["roofline_frac"]]
    worst.sort(key=lambda r: r["roofline_frac"])
    if worst:
        print("\nworst roofline fractions:")
        for r in worst[:5]:
            print(f"  {r['cell']}: {r['roofline_frac']} ({r['bottleneck']})")
        coll = [r for r in rows if r["bottleneck"] == "collective"]
        coll.sort(key=lambda r: -r["collective_s"])
        print("most collective-bound:")
        for r in coll[:5]:
            print(f"  {r['cell']}: collective {r['collective_s']:.3f}s")
    return rows


if __name__ == "__main__":
    main()
