"""§Perf hillclimb driver + log renderer.

Runs the named plans on the three selected cells (one dryrun subprocess
per plan — each needs a fresh 512-device jax), collects the records, and
renders the hypothesis→change→before→after log for EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import os
import resource
import subprocess
import sys
from pathlib import Path

CELLS = [
    # (arch, shape, [plans in hillclimb order], why chosen)
    ("jamba_1_5_large", "train_4k", ["vp", "ep+vp", "ep+vp+sp"],
     "most collective-bound baseline"),
    ("kimi_k2", "train_4k", ["vp", "vp+cap1", "ep+cap1"],
     "paper-representative: 384-expert EP == power-law placement"),
    ("llama3_2_3b", "decode_32k", ["don", "don+repl"],
     "worst roofline fraction (memory-bound decode)"),
]

HYPOTHESES = {
    "vp": "the naive loss take_along_axis all-gathers full [B,S,V] logits "
          "across the vocab shards; a one-hot contraction keeps the gather "
          "local → collective term should collapse (napkin: logits "
          "all-gather ≈ B·S·V·4B·15/16 per chip ≫ everything else)",
    "ep+vp": "REFUTED vp alone: the collective is NOT the logits gather — "
             "it is GSPMD lowering the MoE dispatch scatter as an all-reduce "
             "of the full [E,C,d] buffer (~70 GB/op). Manual shard_map EP: "
             "tokens are model-replicated, each expert shard gathers its "
             "tokens LOCALLY, combine = one [T_loc,d] psum (≈1000x fewer B)",
    "ep+vp+sp": "with collectives fixed, memory dominates; sequence-parallel "
                "activations shard the S dim over `model` between layers → "
                "activation bytes drop up to 16x",
    "ep+cap1": "same shard_map EP dispatch + capacity 1.0; kimi's 1815s "
               "collective was ~entirely the dispatch all-reduce "
               "(napkin: 61 layers x ~70 GB x ring ≈ 90 TB/chip)",
    "vp+cap1": "capacity 1.25→1.0 cuts the [E,C,d] dispatch buffer and its "
               "collectives by 20% on top of vp",
    "vp+cap1+bf16g": "bf16 gradient all-reduce halves the DP-gradient "
                     "share of the collective term",
    "don": "donating the KV cache aliases the dynamic-update-slice "
           "in-place → halves cache bytes (no copy of the full cache)",
    "don+repl": "weights replicated over DP axes for serving: no per-step "
                "FSDP weight all-gathers (weights fit trivially at 3B)",
}


def run_plan(arch: str, shape: str, plan: str, out: str = "experiments/dryrun") -> float:
    """Run one dryrun plan in a subprocess; returns the RUSAGE_CHILDREN
    high-water RSS (MB) after it exits — each plan needs a fresh jax, so
    the children high-water mark is the honest per-stage peak the parent's
    own ru_maxrss can't see."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", "pod", "--plan", plan, "--out", out]
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=3000, env=env)
    print(r.stdout[-400:])
    assert r.returncode == 0, r.stderr[-2000:]
    peak_mb = resource.getrusage(resource.RUSAGE_CHILDREN).ru_maxrss / 1024.0
    print(f"  children peak rss {peak_mb:.0f} MB")
    return peak_mb


def load(arch, shape, plan, out="experiments/dryrun"):
    p = Path(out) / f"{arch}__{shape}__sp__{plan}.json"
    return json.loads(p.read_text()) if p.exists() else None


def render_log(out="experiments/dryrun") -> str:
    from benchmarks.roofline import effective_terms, roofline_fraction

    lines = []
    for arch, shape, plans, why in CELLS:
        lines.append(f"\n### {arch} × {shape}  ({why})\n")
        lines.append("| plan | hypothesis | compute s | memory s | collective s "
                     "| bound | roofline frac | verdict |")
        lines.append("|---|---|---|---|---|---|---|---|")
        prev = None
        for plan in ["baseline"] + plans:
            r = load(arch, shape, plan, out)
            if r is None:
                continue
            t = effective_terms(r)
            frac = roofline_fraction(r)
            hyp = "paper-faithful baseline" if plan == "baseline" else HYPOTHESES.get(plan, "")
            verdict = ""
            if prev is not None and frac is not None and prev is not None:
                verdict = ("**confirmed**" if t["bound_s"] < prev * 0.95
                           else ("refuted" if t["bound_s"] > prev * 1.05 else "neutral"))
            lines.append(
                f"| {plan} | {hyp[:80]} | {t['compute_s']:.4g} | {t['memory_s']:.4g} "
                f"| {t['collective_s']:.4g} | {t['bound_s']:.4g} "
                f"| {frac:.4f} | {verdict} |"
            )
            prev = t["bound_s"]
    return "\n".join(lines)


def main():
    for arch, shape, plans, _ in CELLS:
        for plan in plans:
            print(f"=== {arch} × {shape} plan={plan}")
            run_plan(arch, shape, plan)
    print(render_log())


if __name__ == "__main__":
    main()
