"""Paper Tables I & III + §V-A partition overhead.

Table I:   statistics of the benchmark graphs (V, E, avg degree, eta).
Table III: edge/vertex imbalance factors + replication factor per
           partitioner per graph (via cached `GraphPipeline`s).
Overhead:  wall-clock partition time per algorithm.
"""
from __future__ import annotations

import time

from benchmarks.common import GRAPHS, PARTS, get_pipeline, load_graph
from repro.graph.generate import estimate_eta


def table1(scale: float = 1.0):
    print("\n== Table I: graph statistics ==")
    print(f"{'graph':18} {'|V|':>10} {'|E|':>10} {'avg deg':>8} {'eta':>6}")
    rows = {}
    for key in GRAPHS:
        g, _ = load_graph(key, scale)
        eta = estimate_eta(g)
        print(f"{key:18} {g.num_vertices:>10} {g.num_edges:>10} "
              f"{g.num_edges/g.num_vertices:>8.2f} {eta:>6.2f}")
        rows[key] = dict(V=g.num_vertices, E=g.num_edges, eta=round(eta, 2))
    return rows


def table3(scale: float = 1.0, partitioners=PARTS):
    print("\n== Table III: partition metrics (edge-imb/vertex-imb | rep factor) ==")
    out = {}
    for key in GRAPHS:
        _, p = load_graph(key, scale)
        row = {}
        for name in partitioners:
            pipe = get_pipeline(key, scale, name, p)
            t0 = time.time()
            pipe.result  # force the (cached) partition stage
            dt = time.time() - t0
            row[name] = dict(**pipe.metrics.row(), partition_s=round(dt, 2))
        out[key] = row
        cells = "  ".join(
            f"{n}:{row[n]['edge_imbalance']:.2f}/{row[n]['vertex_imbalance']:.2f}|{row[n]['replication_factor']:.2f}"
            for n in partitioners
        )
        print(f"{key:18} p={p:<3} {cells}")
    return out


def overhead_table(results):
    print("\n== Partition overhead (seconds) ==")
    for gkey, row in results.items():
        cells = "  ".join(f"{n}:{row[n]['partition_s']:.2f}" for n in row)
        print(f"{gkey:18} {cells}")


def main(scale: float = 1.0, partitioners=PARTS):
    table1(scale)
    res = table3(scale, partitioners=partitioners)
    overhead_table(res)
    return res


if __name__ == "__main__":
    main()
