"""Fill EXPERIMENTS.md's §Roofline table and §Perf log from artifacts."""
from __future__ import annotations

from pathlib import Path

from benchmarks.perf_log import render_log
from benchmarks.roofline import load_records, render_md, table


def main():
    path = Path("EXPERIMENTS.md")
    text = path.read_text()
    recs = load_records()
    rows = table(recs, mesh="16x16")
    text = text.replace("<!-- ROOFLINE_TABLE -->", render_md(rows))
    text = text.replace("<!-- PERF_LOG -->", render_log())
    path.write_text(text)
    print("EXPERIMENTS.md updated:", len(rows), "roofline rows")


if __name__ == "__main__":
    main()
