"""Paper Table II + Fig. 5: comp/comm/ΔC breakdown of CC with 4 workers.

Real per-worker wall-clock: each worker's local fixpoint runs as its OWN
jit call, timed separately per superstep (p=4, as in the paper). comm is
modeled from measured message counts; ΔC^k = max_i - min_i of the measured
per-worker superstep time; ΔC = Σ_k ΔC^k.

Partition → build goes through `GraphPipeline`; the per-superstep loop
below is the instrumented engine itself (it times workers individually,
which the batched `run` facade deliberately does not).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import PARTS, load_graph
from repro.api import GraphPipeline
from repro.graph.engine import CC, _jit_superstep_sim, init_cc

T_MSG = 2.0e-7


def tree_slice(sub, i: int):
    """Worker-i view of a SubgraphSet (leading batch dim kept at 1)."""
    return jax.tree.map(lambda a: a[i : i + 1], sub)


def per_worker_breakdown(pipe: GraphPipeline, max_supersteps=100):
    sub = pipe.build(symmetrize=True).subgraphs
    p = sub.num_parts
    # per-worker single-subgraph views (batch dim of 1) — timed separately
    subs = [tree_slice(sub, i) for i in range(p)]
    val = init_cc(sub)

    # warm-up: compile the per-worker and batched kernels outside the timers
    for i in range(p):
        _jit_superstep_sim(CC, subs[i], val[i : i + 1], 10_000, False, val[i : i + 1])[0].block_until_ready()
    _jit_superstep_sim(CC, sub, val, 1, True, val)

    comp = np.zeros(p)
    comm = np.zeros(p)
    delta_c = 0.0
    steps = 0
    for k in range(max_supersteps):
        before = val
        step_t = np.zeros(p)
        # compute stage: per-worker, timed individually.
        new_rows = []
        for i in range(p):
            vi = val[i : i + 1]
            t0 = time.time()
            out, _, _, _ = _jit_superstep_sim(CC, subs[i], vi, 10_000, False, vi)
            out.block_until_ready()
            dt = time.time() - t0
            step_t[i] += dt
            comp[i] += dt
            new_rows.append(out)
        val = jnp.concatenate(new_rows, axis=0)
        # communication stage: batched exchange; per-worker cost modeled
        # from its measured message count.
        val, msgs, _, _ = _jit_superstep_sim(CC, sub, val, 1, True, before)
        m = np.asarray(msgs, np.float64)
        comm += m * T_MSG
        step_t += m * T_MSG
        delta_c += step_t.max() - step_t.min()
        steps += 1
        if not bool(jnp.any(val != before)):
            break
    total = comp.max() + comm.max() + delta_c
    return dict(
        comp=float(comp.mean()),
        comm=float(comm.mean()),
        delta_c=float(delta_c),
        exec_time=float(total),
        supersteps=steps,
        per_worker_comp=comp.round(3).tolist(),
    )


def main(scale: float = 1.0, partitioners=None):
    partitioners = PARTS if partitioners is None else partitioners
    g, _ = load_graph("livejournal_like", scale)
    base = GraphPipeline(g)
    print("\n== Table II: breakdown of CC with 4 workers (seconds) ==")
    print(f"{'':7} {'comp':>8} {'comm':>8} {'ΔC':>8} {'exec':>8} {'steps':>6}")
    out = {}
    for name in partitioners:
        row = per_worker_breakdown(base.partition(name, parts=4))
        out[name] = row
        print(f"{name:7} {row['comp']:>8.3f} {row['comm']:>8.4f} "
              f"{row['delta_c']:>8.3f} {row['exec_time']:>8.3f} {row['supersteps']:>6}")
    # Fig.5-style: per-worker comp profile
    print("\nper-worker comp (s):")
    for name, row in out.items():
        print(f"  {name:7} {row['per_worker_comp']}")
    return out


if __name__ == "__main__":
    main()
