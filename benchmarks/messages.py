"""Paper Tables IV & V: total BSP messages and max/mean message balance
for CC across partitioners, plus the replication-factor correlation.

Each cell is one `GraphPipeline.run` — the pipeline picks the build the
program needs (CC symmetrizes) and the SSSP source (highest-degree
covered vertex), and caches partition/build/metrics across sections.
"""
from __future__ import annotations

from benchmarks.common import GRAPHS, PARTS, get_pipeline, load_graph, release_builds


def run(scale: float = 1.0, partitioners=PARTS, algo: str = "cc"):
    print(f"\n== Tables IV & V: {algo.upper()} messages (total | max/mean) ==")
    out = {}
    for key in GRAPHS:
        g, p = load_graph(key, scale)
        row = {}
        for name in partitioners:
            pipe = get_pipeline(key, scale, name, p)
            r = pipe.run(algo, num_iters=10) if algo == "pr" else pipe.run(algo)
            m = pipe.metrics
            row[name] = dict(
                total_messages=r.stats.total_messages,
                max_mean=round(r.stats.max_mean, 3),
                replication_factor=round(m.replication_factor, 2),
                edge_imbalance=round(m.edge_imbalance, 2),
                vertex_imbalance=round(m.vertex_imbalance, 2),
                supersteps=r.stats.supersteps,
            )
        out[key] = row
        release_builds(key, scale)
        cells = "  ".join(
            f"{n}:{row[n]['total_messages']:.2e}|{row[n]['max_mean']:.2f}"
            for n in partitioners
        )
        print(f"{key:18} p={p:<3} {cells}")
    return out


def validate_claims(results):
    """Paper §V headline numbers (trend validation on synthetic graphs)."""
    print("\n== Claim validation (power-law graphs) ==")
    ok = True
    compared = 0
    for key, row in results.items():
        if key == "road_like":
            continue
        if not all(n in row for n in ("ebg", "dbh", "cvc")):
            continue  # partial --partitioners selection: nothing to compare
        compared += 1
        ebg, dbh, cvc = row["ebg"], row["dbh"], row["cvc"]
        msg_red = 1 - ebg["total_messages"] / min(dbh["total_messages"], cvc["total_messages"])
        rep_red = 1 - ebg["replication_factor"] / min(dbh["replication_factor"], cvc["replication_factor"])
        balanced = ebg["max_mean"] < 1.15
        ne_mm = row.get("ne", {}).get("max_mean", None)
        metis_mm = row.get("metis", {}).get("max_mean", None)
        print(f"{key}: EBG msg reduction vs min(DBH,CVC) = {msg_red:.1%} "
              f"(paper: 24.3%), rep reduction = {rep_red:.1%} (paper: 32.3%), "
              f"EBG max/mean = {ebg['max_mean']:.3f}"
              + (f", NE max/mean = {ne_mm}" if ne_mm else "")
              + (f", METIS max/mean = {metis_mm}" if metis_mm else ""))
        ok &= msg_red > 0 and rep_red > 0 and balanced
    if not compared:
        print("claims (directional): skipped (partial --partitioners selection)")
        return None
    print("claims (directional):", "PASS" if ok else "FAIL")
    return ok


def main(scale: float = 1.0, partitioners=PARTS):
    res = run(scale, partitioners=partitioners)
    validate_claims(res)
    return res


if __name__ == "__main__":
    main()
