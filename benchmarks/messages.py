"""Paper Tables IV & V: total BSP messages and max/mean message balance
for CC across partitioners, plus the replication-factor correlation.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import GRAPHS, PARTS, get_partition, load_graph
from repro.core import PARTITIONERS, partition_metrics
from repro.graph import algorithms as alg
from repro.graph.build import build_subgraphs


def run(scale: float = 1.0, partitioners=PARTS, algo: str = "cc"):
    print(f"\n== Tables IV & V: {algo.upper()} messages (total | max/mean) ==")
    out = {}
    for key in GRAPHS:
        g, p = load_graph(key, scale)
        row = {}
        for name in partitioners:
            res = get_partition(key, scale, name, p)
            m = partition_metrics(g, res)
            sub = build_subgraphs(g, res, symmetrize=(algo == "cc"))
            if algo == "cc":
                _, stats = alg.connected_components(sub)
            elif algo == "pr":
                _, stats = alg.pagerank(sub, g.num_vertices, num_iters=10)
            else:
                cov = np.unique(np.concatenate([np.asarray(g.src), np.asarray(g.dst)]))
                src_v = int(cov[np.argmax(g.degrees()[cov])])
                _, stats = alg.sssp(sub, src_v)
            row[name] = dict(
                total_messages=stats.total_messages,
                max_mean=round(stats.max_mean, 3),
                replication_factor=round(m.replication_factor, 2),
                edge_imbalance=round(m.edge_imbalance, 2),
                vertex_imbalance=round(m.vertex_imbalance, 2),
                supersteps=stats.supersteps,
            )
        out[key] = row
        cells = "  ".join(
            f"{n}:{row[n]['total_messages']:.2e}|{row[n]['max_mean']:.2f}"
            for n in partitioners
        )
        print(f"{key:18} p={p:<3} {cells}")
    return out


def validate_claims(results):
    """Paper §V headline numbers (trend validation on synthetic graphs)."""
    print("\n== Claim validation (power-law graphs) ==")
    ok = True
    for key, row in results.items():
        if key == "road_like":
            continue
        ebg, dbh, cvc = row["ebg"], row["dbh"], row["cvc"]
        msg_red = 1 - ebg["total_messages"] / min(dbh["total_messages"], cvc["total_messages"])
        rep_red = 1 - ebg["replication_factor"] / min(dbh["replication_factor"], cvc["replication_factor"])
        balanced = ebg["max_mean"] < 1.15
        ne_mm = row.get("ne", {}).get("max_mean", None)
        metis_mm = row.get("metis", {}).get("max_mean", None)
        print(f"{key}: EBG msg reduction vs min(DBH,CVC) = {msg_red:.1%} "
              f"(paper: 24.3%), rep reduction = {rep_red:.1%} (paper: 32.3%), "
              f"EBG max/mean = {ebg['max_mean']:.3f}"
              + (f", NE max/mean = {ne_mm}" if ne_mm else "")
              + (f", METIS max/mean = {metis_mm}" if metis_mm else ""))
        ok &= msg_red > 0 and rep_red > 0 and balanced
    print("claims (directional):", "PASS" if ok else "FAIL")
    return ok


def main(scale: float = 1.0):
    res = run(scale)
    validate_claims(res)
    return res


if __name__ == "__main__":
    main()
