"""Out-of-core scale pipeline: shards -> external order -> partition ->
streamed build -> CC, with wall + RSS metered per stage.

Default (CI smoke) runs a downscaled twin — 2^16 vertices / 2^18 edges
from >= 4 shards at p=8 — and ALSO runs the fully in-memory pipeline on
the loaded graph, asserting the out-of-core path is bit-identical
(partition assignments and CC labels). That parity bit is what the
`scale` section of BENCH_pipeline.json holds the line on in CI.

REPRO_SCALE=full (or --full) runs the real thing: rmat 2^25 vertices /
2^27 edges, generated shard-by-shard and partitioned/built/run without
ever materializing the int64 edge list. There the parity twin is skipped
(that is the point) and instead the EDGE-PIPELINE peak RSS (generate ->
degrees -> partition -> build) is asserted below the in-memory-pipeline
footprint — the bytes `streaming_chunked_partition` + `build_subgraphs`
would materialize just to hold the edges: the int64 (src, dst) list
(2*8*E), the symmetrized (src, dst, part) triple `_prepare_edges`
concatenates (3*8*2E), and `_elect_masters`' endpoint/key concats over
the symmetrized list (2*2*8*2E) = 128*E bytes. The CC stage after that
pays the engine's (p, p, max_msg) message-buffer arena — a property of
the SubgraphSet both pipelines hand the engine, identical either way,
so it is reported (end-to-end `peak_rss_mb`) but outside the assert.

Per-stage accounting: `ru_maxrss` is a process-lifetime high-water mark
(it never goes down), so each stage records BOTH the running peak after
the stage and the instantaneous /proc VmRSS at the stage boundary — the
VmRSS series is what shows which stage actually owns the peak.

Usage: python -m benchmarks.scale_pipeline [--full]
"""
from __future__ import annotations

import contextlib
import json
import os
import resource
import sys
import tempfile
import time
from pathlib import Path

import numpy as np


def vm_rss_mb() -> float | None:
    """Instantaneous resident set from /proc (Linux); None elsewhere."""
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmRSS:"):
                    return round(int(line.split()[1]) / 1024.0, 1)
    except OSError:
        pass
    return None


def peak_rss_mb(who: int = resource.RUSAGE_SELF) -> float:
    """High-water resident set (Linux ru_maxrss is in KiB)."""
    return round(resource.getrusage(who).ru_maxrss / 1024.0, 1)


class StageMeter:
    """Wall clock + RSS per pipeline stage (see module docstring)."""

    def __init__(self) -> None:
        self.stages: dict[str, dict] = {}

    @contextlib.contextmanager
    def stage(self, name: str):
        t0 = time.perf_counter()
        yield
        self.stages[name] = {
            "wall_s": round(time.perf_counter() - t0, 3),
            "peak_rss_mb": peak_rss_mb(),
            "rss_after_mb": vm_rss_mb(),
        }


def run_scale(
    *,
    num_vertices: int = 1 << 16,
    num_edges: int = 1 << 18,
    parts: int = 8,
    shard_edges: int = 1 << 16,
    block: int = 4096,
    scorer: str = "ebv",
    workdir: str | None = None,
    parity_twin: bool = True,
    assert_rss_below_footprint: bool = False,
) -> dict:
    from repro.core import outofcore as oc
    from repro.data import edgeshards as es
    from repro.graph import engine as eng
    from repro.graph.build import build_subgraphs
    from repro.graph.build_stream import build_subgraphs_stream

    tmp = Path(workdir) if workdir else Path(tempfile.mkdtemp(prefix="scale_pipe_"))
    tmp.mkdir(parents=True, exist_ok=True)
    meter = StageMeter()

    with meter.stage("rmat_to_store"):
        store = es.rmat_to_store(
            tmp / "store", num_vertices, num_edges,
            seed=7, a=0.65, b=0.15, c=0.15,
            shard_edges=shard_edges, workdir=tmp / "gen",
        )
    assert store.num_shards >= 4, store.num_shards

    with meter.stage("degrees"):
        degrees = es.degrees_from_shards(store)

    with meter.stage("partition"):
        r_oc = oc.partition_store(
            store, parts, scorer, block=block, degrees=degrees,
            order_workdir=tmp / "order",
        )

    with meter.stage("build"):
        sub = build_subgraphs_stream(
            lambda: r_oc.edge_part_stream(block), store.num_vertices, parts,
            symmetrize=True,
        )

    with meter.stage("cc"):
        val, stats = eng.run_bsp(sub, "cc")
        np.asarray(val)  # block until done

    cc_wall = meter.stages["cc"]["wall_s"]
    row: dict = {
        "graph": {
            "family": "rmat_scale",
            "num_vertices": store.num_vertices,
            "num_edges": store.num_edges,
            "num_shards": store.num_shards,
            "shard_edges": shard_edges,
            "p": parts,
        },
        "scorer": scorer,
        "block": block,
        "stages": meter.stages,
        "replication_factor": round(r_oc.replication_factor, 3),
        "cc_supersteps": stats.supersteps,
        "cc_supersteps_per_s": round(stats.supersteps / max(cc_wall, 1e-9), 2),
        "addressing": sub.addressing,
        "peak_rss_mb": peak_rss_mb(),
    }

    # The bytes the in-memory pipeline materializes just to HOLD the edges
    # on the way to the same build: the int64 (src, dst) list (16E), the
    # symmetrized (src, dst, part) triple `_prepare_edges` concatenates
    # (48E), and `_elect_masters`' endpoint/key concats over the
    # symmetrized list (2 * 2E int64 each = 64E) — 128E total, NOT
    # counting np.unique's sort scratch or the padded per-worker tensors
    # both pipelines share.
    footprint_mb = round(128 * store.num_edges / (1 << 20), 1)
    row["in_memory_edge_footprint_mb"] = footprint_mb
    # The line is asserted on the EDGE-PIPELINE stages — everything up to
    # and including the streamed build, i.e. the work this pipeline does
    # differently. The CC stage then pays the engine's (p, p, max_msg)
    # message-buffer arena, which is a property of the SubgraphSet both
    # pipelines hand the engine — identical either way, and reported
    # separately as the end-to-end `peak_rss_mb`.
    edge_peak = max(meter.stages[s]["peak_rss_mb"]
                    for s in ("rmat_to_store", "degrees", "partition", "build"))
    row["edge_pipeline_peak_rss_mb"] = edge_peak
    if assert_rss_below_footprint:
        # Only meaningful at full scale — on the CI smoke graph the line
        # (32 MB at 2^18 edges) is below any JAX process baseline.
        row["rss_below_in_memory_footprint"] = bool(edge_peak < footprint_mb)
        if not row["rss_below_in_memory_footprint"]:
            # Emit the stage data before failing — a dead assert must not
            # eat the per-stage walls/RSS that explain WHY it tripped.
            print(json.dumps(row, indent=2))
            raise AssertionError(
                f"edge-pipeline peak RSS {edge_peak} MB >= in-memory edge "
                f"working set {footprint_mb} MB"
            )

    if parity_twin:
        from repro.core.streaming import streaming_chunked_partition

        with meter.stage("parity_twin"):
            g = es.load_graph(store)
            r_mem = streaming_chunked_partition(g, parts, scorer, block=block)
            sub_mem = build_subgraphs(g, r_mem, symmetrize=True)
            val_mem, stats_mem = eng.run_bsp(sub_mem, "cc")
        parity = (
            bool(np.array_equal(np.asarray(r_mem.part), np.asarray(r_oc.result.part)))
            and bool(np.array_equal(np.asarray(val), np.asarray(val_mem)))
            and stats.supersteps == stats_mem.supersteps
        )
        row["matches_in_memory"] = parity
        assert parity, "out-of-core pipeline diverged from the in-memory oracle"
    return row


def main() -> dict:
    full = "--full" in sys.argv or os.environ.get("REPRO_SCALE") == "full"
    if full:
        row = run_scale(
            num_vertices=1 << 25, num_edges=1 << 27, parts=8,
            shard_edges=1 << 22, block=1 << 20,
            parity_twin=False, assert_rss_below_footprint=True,
        )
    else:
        row = run_scale()
    print(json.dumps(row, indent=2))
    return row


if __name__ == "__main__":
    main()
