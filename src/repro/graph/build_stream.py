"""Streamed two-pass subgraph builder for the out-of-core pipeline.

`build_subgraphs` consumes a materialized int64 edge list; this builder
consumes a RE-ITERABLE stream of (src, dst, part) blocks (e.g.
`OutOfCoreResult.edge_part_stream`) and never holds the global edge list:

  pass 1  O(p·V) incidence counts (uint32) + global out-degrees — enough
          to elect masters (max incidence count, tie → lowest part: the
          exact `_elect_masters` lexsort order, realized as an argmax),
          lay out the per-worker sorted local vertex spaces, and size the
          padded tensors;
  pass 2  stage each block's edges into per-worker stream-ordered int32
          staging rows (local ids via one searchsorted against the fused
          (part, vertex) key), then per-worker stable argsorts produce
          the dst-/src-sorted views — the same (part, local-id, stream
          position) order as the in-memory vectorized builder's fused
          global sort, so the output is bit-identical to
          `build_subgraphs` on the same partition (tests pin this).

Exchange tables come from the SAME `_exchange_tables` helper the
in-memory builder uses — parity there is shared code, not a re-derivation.

Peak memory: p·V·4 bytes of counts + the padded per-worker tensors the
engine needs anyway + 2 int32 staging arrays; the int64 edge list itself
never materializes (at p=8, V=2^25, E=2^27 that is ~1 GB of counts
versus ~2 GB for the in-memory edge list + its sort permutations).
"""
from __future__ import annotations

from typing import Callable, Iterator, Tuple

import jax.numpy as jnp
import numpy as np

from repro.graph.build import SubgraphSet, _exchange_tables, check_addressing

EdgeBlockStream = Callable[[], Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]]


def build_subgraphs_stream(
    stream_factory: EdgeBlockStream,
    num_vertices: int,
    num_parts: int,
    *,
    symmetrize: bool = False,
    pad_multiple: int = 8,
    addressing: str = "two_level",
) -> SubgraphSet:
    """Build the padded SubgraphSet from a re-iterable (src, dst, part)
    block stream. `stream_factory()` is called once per pass (twice, or
    three times with `symmetrize=True` — the reversed edges replay the
    stream rather than buffering it). Unit edge weights (the engine's
    weighted programs derive weights from `out_degree`, not these)."""
    check_addressing(addressing)
    p = int(num_parts)
    N = int(num_vertices)
    if N > np.iinfo(np.int32).max:
        raise ValueError(
            f"subgraph gid table is int32: num_vertices={N} >= 2^31 is past the "
            "engine ceiling (two-level addressing lifts the 2^24 KERNEL bound, "
            "not the global-id width)"
        )

    # ---- pass 1: incidence counts, out-degrees, per-part edge counts.
    counts = np.zeros((p, N), np.uint32)
    out_deg_global = np.zeros(N, np.int64)
    ne = np.zeros(p, np.int64)
    for s, d, pt in stream_factory():
        s = np.asarray(s, np.int64)
        d = np.asarray(d, np.int64)
        pt = np.asarray(pt, np.int64)
        np.add.at(counts, (pt, s), 1)
        np.add.at(counts, (pt, d), 1)
        out_deg_global += np.bincount(s, minlength=N)
        if symmetrize:
            out_deg_global += np.bincount(d, minlength=N)
        ne += np.bincount(pt, minlength=p)
    if symmetrize:
        # Forward + reversed double every (part, vertex) incidence count
        # uniformly, so the un-symmetrized counts elect identical masters.
        ne *= 2

    # Master election: max incidence count, tie → lowest part (argmax
    # returns the first maximum — exactly `_elect_masters`' lexsort pick).
    covered = counts.max(axis=0) > 0
    master_part = np.where(covered, counts.argmax(axis=0), -1).astype(np.int64)

    # ---- per-part sorted local vertex spaces (ascending global ids).
    verts = [np.flatnonzero(counts[i]).astype(np.int64) for i in range(p)]
    nv = np.array([v.shape[0] for v in verts], np.int64)
    v_off = np.zeros(p + 1, np.int64)
    np.cumsum(nv, out=v_off[1:])
    vv = np.concatenate(verts) if verts else np.zeros(0, np.int64)
    vp = np.repeat(np.arange(p, dtype=np.int64), nv)
    vcol = np.arange(vv.shape[0], dtype=np.int64) - v_off[vp]
    vkeys = vp * N + vv  # strictly increasing (part-major, vertex-minor)

    max_v = int(-(-max(int(nv.max()) if nv.size else 1, 1) // pad_multiple) * pad_multiple)
    max_e = int(-(-max(int(ne.max()) if ne.size else 1, 1) // pad_multiple) * pad_multiple)

    gid = np.full((p, max_v), -1, np.int32)
    vmask = np.zeros((p, max_v), bool)
    is_master = np.zeros((p, max_v), bool)
    out_degree = np.zeros((p, max_v), np.float32)
    odg32 = out_deg_global.astype(np.float32)
    gid[vp, vcol] = vv
    vmask[vp, vcol] = True
    is_master[vp, vcol] = master_part[vv] == vp
    out_degree[vp, vcol] = odg32[vv]

    # ---- pass 2: stage per-part edges in stream order, then sort locally.
    ls_stage = np.zeros((p, max_e), np.int32)
    ld_stage = np.zeros((p, max_e), np.int32)
    cur = np.zeros(p, np.int64)

    def _stage(s, d, pt):
        nonlocal cur
        ls = (np.searchsorted(vkeys, pt * N + s) - v_off[pt]).astype(np.int32)
        ld = (np.searchsorted(vkeys, pt * N + d) - v_off[pt]).astype(np.int32)
        # Per-part append positions: cursor + within-block rank of this part.
        bc = np.bincount(pt, minlength=p).astype(np.int64)
        boff = np.zeros(p + 1, np.int64)
        np.cumsum(bc, out=boff[1:])
        o = np.argsort(pt, kind="stable")
        rank = np.empty(pt.shape[0], np.int64)
        rank[o] = np.arange(pt.shape[0], dtype=np.int64) - boff[pt[o]]
        col = cur[pt] + rank
        ls_stage[pt, col] = ls
        ld_stage[pt, col] = ld
        cur += bc

    for s, d, pt in stream_factory():
        _stage(np.asarray(s, np.int64), np.asarray(d, np.int64), np.asarray(pt, np.int64))
    if symmetrize:
        # The in-memory builder symmetrizes by concatenating the reversed
        # list AFTER the forward list; replaying the stream reversed-edge
        # second reproduces that stream order exactly.
        for s, d, pt in stream_factory():
            _stage(np.asarray(d, np.int64), np.asarray(s, np.int64), np.asarray(pt, np.int64))
    assert np.array_equal(cur, ne), "stream changed length between passes"
    del counts  # p*V*4 bytes — not needed past election/vertex layout

    # Assemble the padded tensors one at a time, converting each to a
    # device array and freeing the host copy immediately — peak here is
    # ONE extra (p, max_e) host array, not a full host+device double
    # image of all eight edge tensors (which at 2^27 edges is the
    # difference between ~1 GB and ~8 GB of avoidable high-water).
    def _edge_tensor(fill, dtype, per_part):
        arr = np.full((p, max_e), fill, dtype)
        for i in range(p):
            n = int(ne[i])
            arr[i, :n] = per_part(i, n)
        out = jnp.asarray(arr)
        del arr
        return out

    tensors = {}
    # dst-sorted main view, then src-sorted exchange view; only ONE set of
    # per-part sort permutations is alive at a time (int32: ne[i] < 2^31).
    orders = [np.argsort(ld_stage[i, : int(ne[i])], kind="stable").astype(np.int32)
              for i in range(p)]
    tensors["lsrc"] = _edge_tensor(0, np.int32, lambda i, n: ls_stage[i, :n][orders[i]])
    tensors["ldst"] = _edge_tensor(max_v, np.int32, lambda i, n: ld_stage[i, :n][orders[i]])
    orders = [np.argsort(ls_stage[i, : int(ne[i])], kind="stable").astype(np.int32)
              for i in range(p)]
    tensors["lsrc_s"] = _edge_tensor(max_v, np.int32, lambda i, n: ls_stage[i, :n][orders[i]])
    tensors["ldst_s"] = _edge_tensor(0, np.int32, lambda i, n: ld_stage[i, :n][orders[i]])
    del ls_stage, ld_stage, orders
    for nm, fill in (("weight", 1.0), ("weight_s", 1.0)):
        tensors[nm] = _edge_tensor(0.0, np.float32, lambda i, n, f=fill: f)
    for nm in ("edge_mask", "edge_mask_s"):
        tensors[nm] = _edge_tensor(False, bool, lambda i, n: True)

    send_idx, recv_idx, msg_mask, recv_mask, max_msg = _exchange_tables(
        vp, vcol, vv, vkeys, v_off, master_part,
        p=p, N=N, max_v=max_v, pad_multiple=pad_multiple,
    )

    return SubgraphSet(
        **tensors,
        gid=jnp.asarray(gid),
        vmask=jnp.asarray(vmask),
        is_master=jnp.asarray(is_master),
        out_degree=jnp.asarray(out_degree),
        send_idx=jnp.asarray(send_idx),
        recv_idx=jnp.asarray(recv_idx),
        msg_mask=jnp.asarray(msg_mask),
        recv_mask=jnp.asarray(recv_mask),
        num_parts=p,
        max_v=max_v,
        max_e=max_e,
        max_msg=max_msg,
        addressing=addressing,
    )
