"""Partition result → padded subgraph structures for the BSP engine.

The subgraph-centric model binds one subgraph to one worker (device). We
build, host-side, the dense padded tensors the SPMD engine consumes:

  - per-subgraph local edge lists in BOTH destination-sorted and
    source-sorted order (dst-sorted drives forward relaxation via segmented
    reductions; src-sorted drives the reverse direction for undirected
    algorithms). TPU adaptation: sort-once + segment-reduce replaces the
    random scatter a CPU/GPU framework would use.
  - master/mirror tables: every replicated vertex has one master subgraph
    (the covering subgraph with most incident edges); all other replicas are
    mirrors. Mirror→master reduction and master→mirror broadcast use the
    same (send_idx, recv_idx) pair tables, exchanged with a fixed-topology
    all_to_all.

All leading axes are the worker axis `p`, shardable 1:1 onto mesh devices.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import Graph, PartitionResult


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SubgraphSet:
    # Edges, destination-sorted (for segment-reduce into dst).
    lsrc: jax.Array  # [p, max_e] int32 local src ids (pad: 0)
    ldst: jax.Array  # [p, max_e] int32 local dst ids (pad: max_v → dump row)
    weight: jax.Array  # [p, max_e] f32 (pad: 0)
    edge_mask: jax.Array  # [p, max_e] bool
    # Same edges, source-sorted (for the reverse direction).
    lsrc_s: jax.Array  # [p, max_e] int32 (pad: max_v)
    ldst_s: jax.Array  # [p, max_e] int32 (pad: 0)
    weight_s: jax.Array  # [p, max_e] f32
    edge_mask_s: jax.Array  # [p, max_e] bool
    # Vertices.
    gid: jax.Array  # [p, max_v] int32 global id (pad: -1)
    vmask: jax.Array  # [p, max_v] bool
    is_master: jax.Array  # [p, max_v] bool
    out_degree: jax.Array  # [p, max_v] f32 GLOBAL out-degree (for PageRank)
    # Exchange tables; send_idx[i, j, m] (local id at sender i, master at j)
    # pairs recv_idx[j, i, m] (local id at receiver j).
    send_idx: jax.Array  # [p, p, max_msg] int32 (pad: 0)
    recv_idx: jax.Array  # [p, p, max_msg] int32 (pad: max_v)
    msg_mask: jax.Array  # [p, p, max_msg] bool, sender-rowed: [i, j, m]
    recv_mask: jax.Array  # [p, p, max_msg] bool, receiver-rowed: [j, i, m]
    num_parts: int = dataclasses.field(metadata=dict(static=True))
    max_v: int = dataclasses.field(metadata=dict(static=True))
    max_e: int = dataclasses.field(metadata=dict(static=True))
    max_msg: int = dataclasses.field(metadata=dict(static=True))

    @property
    def num_local_vertices(self) -> jax.Array:
        return self.vmask.sum(axis=1)


def _pad_to(x: np.ndarray, n: int, fill) -> np.ndarray:
    out = np.full((n,) + x.shape[1:], fill, dtype=x.dtype)
    out[: x.shape[0]] = x
    return out


def build_subgraphs(
    graph: Graph,
    result: PartitionResult,
    *,
    weights: np.ndarray | None = None,
    symmetrize: bool = False,
    pad_multiple: int = 8,
) -> SubgraphSet:
    src = np.asarray(graph.src, dtype=np.int64)
    dst = np.asarray(graph.dst, dtype=np.int64)
    part = result.part_in_input_order().astype(np.int64)
    p = result.num_parts
    if weights is None:
        weights = np.ones(src.shape[0], dtype=np.float32)
    if symmetrize:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        part = np.concatenate([part, part])
        weights = np.concatenate([weights, weights])

    # ---- master election: covering part with most incident edge endpoints.
    ends = np.concatenate([src, dst])
    pp = np.concatenate([part, part])
    key = ends * p + pp
    uk, cnt = np.unique(key, return_counts=True)
    v_of = uk // p
    p_of = (uk % p).astype(np.int64)
    # Per covered vertex: part with max count, tie → lowest part id.
    sel = np.lexsort((p_of, -cnt, v_of))
    v_sorted = v_of[sel]
    first = np.ones(v_sorted.shape[0], dtype=bool)
    first[1:] = v_sorted[1:] != v_sorted[:-1]
    master_part = np.full(graph.num_vertices, -1, dtype=np.int64)
    master_part[v_sorted[first]] = p_of[sel][first]

    out_deg_global = np.bincount(src, minlength=graph.num_vertices).astype(np.float32)

    # ---- per-part local vertex spaces (sorted global ids).
    verts: list[np.ndarray] = []
    for i in range(p):
        verts.append(v_of[p_of == i])  # already unique & sorted within part
    nv = np.array([v.shape[0] for v in verts])
    ne = np.bincount(part, minlength=p)
    max_v = int(-(-max(int(nv.max()), 1) // pad_multiple) * pad_multiple)
    max_e = int(-(-max(int(ne.max()), 1) // pad_multiple) * pad_multiple)

    gid = np.full((p, max_v), -1, np.int32)
    vmask = np.zeros((p, max_v), bool)
    is_master = np.zeros((p, max_v), bool)
    out_degree = np.zeros((p, max_v), np.float32)
    for i in range(p):
        n = nv[i]
        gid[i, :n] = verts[i]
        vmask[i, :n] = True
        is_master[i, :n] = master_part[verts[i]] == i
        out_degree[i, :n] = out_deg_global[verts[i]]

    # ---- local edges (both sort orders).
    lsrc = np.zeros((p, max_e), np.int32)
    ldst = np.full((p, max_e), max_v, np.int32)
    weight_arr = np.zeros((p, max_e), np.float32)
    edge_mask = np.zeros((p, max_e), bool)
    lsrc_s = np.full((p, max_e), max_v, np.int32)
    ldst_s = np.zeros((p, max_e), np.int32)
    weight_s = np.zeros((p, max_e), np.float32)
    edge_mask_s = np.zeros((p, max_e), bool)
    for i in range(p):
        eids = np.flatnonzero(part == i)
        ls = np.searchsorted(verts[i], src[eids]).astype(np.int32)
        ld = np.searchsorted(verts[i], dst[eids]).astype(np.int32)
        w = weights[eids]
        o = np.argsort(ld, kind="stable")
        n = eids.shape[0]
        lsrc[i, :n], ldst[i, :n], weight_arr[i, :n] = ls[o], ld[o], w[o]
        edge_mask[i, :n] = True
        o2 = np.argsort(ls, kind="stable")
        lsrc_s[i, :n], ldst_s[i, :n], weight_s[i, :n] = ls[o2], ld[o2], w[o2]
        edge_mask_s[i, :n] = True

    # ---- mirror↔master exchange tables.
    links: dict[tuple[int, int], list[tuple[int, int]]] = {}
    for i in range(p):
        mp = master_part[verts[i]]
        mirrors = np.flatnonzero(mp != i)
        for lv in mirrors:
            j = int(mp[lv])
            lm = int(np.searchsorted(verts[j], verts[i][lv]))
            links.setdefault((i, j), []).append((int(lv), lm))
    max_msg = max(max((len(v) for v in links.values()), default=1), 1)
    max_msg = int(-(-max_msg // pad_multiple) * pad_multiple)
    send_idx = np.zeros((p, p, max_msg), np.int32)
    recv_idx = np.full((p, p, max_msg), max_v, np.int32)
    msg_mask = np.zeros((p, p, max_msg), bool)
    recv_mask = np.zeros((p, p, max_msg), bool)
    for (i, j), lst in links.items():
        lst.sort()
        n = len(lst)
        send_idx[i, j, :n] = [a for a, _ in lst]
        recv_idx[j, i, :n] = [b for _, b in lst]
        msg_mask[i, j, :n] = True
        recv_mask[j, i, :n] = True

    return SubgraphSet(
        lsrc=jnp.asarray(lsrc),
        ldst=jnp.asarray(ldst),
        weight=jnp.asarray(weight_arr),
        edge_mask=jnp.asarray(edge_mask),
        lsrc_s=jnp.asarray(lsrc_s),
        ldst_s=jnp.asarray(ldst_s),
        weight_s=jnp.asarray(weight_s),
        edge_mask_s=jnp.asarray(edge_mask_s),
        gid=jnp.asarray(gid),
        vmask=jnp.asarray(vmask),
        is_master=jnp.asarray(is_master),
        out_degree=jnp.asarray(out_degree),
        send_idx=jnp.asarray(send_idx),
        recv_idx=jnp.asarray(recv_idx),
        msg_mask=jnp.asarray(msg_mask),
        recv_mask=jnp.asarray(recv_mask),
        num_parts=p,
        max_v=max_v,
        max_e=max_e,
        max_msg=max_msg,
    )
