"""Partition result → padded subgraph structures for the BSP engine.

The subgraph-centric model binds one subgraph to one worker (device). We
build, host-side, the dense padded tensors the SPMD engine consumes:

  - per-subgraph local edge lists in BOTH destination-sorted and
    source-sorted order (dst-sorted drives forward relaxation via segmented
    reductions; src-sorted drives the reverse direction for undirected
    algorithms). TPU adaptation: sort-once + segment-reduce replaces the
    random scatter a CPU/GPU framework would use.
  - master/mirror tables: every replicated vertex has one master subgraph
    (the covering subgraph with most incident edges); all other replicas are
    mirrors. Mirror→master reduction and master→mirror broadcast use the
    same (send_idx, recv_idx) pair tables, exchanged with a fixed-topology
    all_to_all.

All leading axes are the worker axis `p`, shardable 1:1 onto mesh devices.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import Graph, PartitionResult


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SubgraphSet:
    # Edges, destination-sorted (for segment-reduce into dst).
    lsrc: jax.Array  # [p, max_e] int32 local src ids (pad: 0)
    ldst: jax.Array  # [p, max_e] int32 local dst ids (pad: max_v → dump row)
    weight: jax.Array  # [p, max_e] f32 (pad: 0)
    edge_mask: jax.Array  # [p, max_e] bool
    # Same edges, source-sorted (for the reverse direction).
    lsrc_s: jax.Array  # [p, max_e] int32 (pad: max_v)
    ldst_s: jax.Array  # [p, max_e] int32 (pad: 0)
    weight_s: jax.Array  # [p, max_e] f32
    edge_mask_s: jax.Array  # [p, max_e] bool
    # Vertices.
    gid: jax.Array  # [p, max_v] int32 global id (pad: -1)
    vmask: jax.Array  # [p, max_v] bool
    is_master: jax.Array  # [p, max_v] bool
    out_degree: jax.Array  # [p, max_v] f32 GLOBAL out-degree (for PageRank)
    # Exchange tables; send_idx[i, j, m] (local id at sender i, master at j)
    # pairs recv_idx[j, i, m] (local id at receiver j).
    send_idx: jax.Array  # [p, p, max_msg] int32 (pad: 0)
    recv_idx: jax.Array  # [p, p, max_msg] int32 (pad: max_v)
    msg_mask: jax.Array  # [p, p, max_msg] bool, sender-rowed: [i, j, m]
    recv_mask: jax.Array  # [p, p, max_msg] bool, receiver-rowed: [j, i, m]
    num_parts: int = dataclasses.field(metadata=dict(static=True))
    max_v: int = dataclasses.field(metadata=dict(static=True))
    max_e: int = dataclasses.field(metadata=dict(static=True))
    max_msg: int = dataclasses.field(metadata=dict(static=True))
    # Addressing contract for the kernel boundary (ADDRESSING_MODES):
    #   "two_level"  kernels index the (worker, local-id) space; global ids
    #                live only in `gid`/`local_to_global` and the engine's
    #                exactness guard checks per-worker VALUE maxima, so
    #                graphs with >= 2^24 vertices stay exact on ref/pallas.
    #   "flat"       legacy contract: `gid` doubles as the kernel-visible
    #                label domain (CC labels ARE global ids), so the engine
    #                guard must reject global ids >= 2^24 on f32 backends.
    addressing: str = dataclasses.field(default="two_level", metadata=dict(static=True))

    @property
    def num_local_vertices(self) -> jax.Array:
        return self.vmask.sum(axis=1)

    @property
    def local_to_global(self) -> np.ndarray:
        """Per-worker local-id → global-id map, int64 host-side: row i maps
        worker i's local ids to global vertex ids (pad slots: -1). The
        device-resident `gid` stays int32 (jax's no-x64 default would
        silently canonicalize int64 anyway, and V < 2^31 is the engine
        ceiling); this property is the declared int64 view for everything
        ABOVE the kernel boundary."""
        return np.asarray(self.gid, np.int64)


ADDRESSING_MODES = ("two_level", "flat")


def check_addressing(mode) -> str:
    if mode not in ADDRESSING_MODES:
        raise ValueError(f"addressing must be one of {ADDRESSING_MODES}, got {mode!r}")
    return mode


def _pad_to(x: np.ndarray, n: int, fill) -> np.ndarray:
    out = np.full((n,) + x.shape[1:], fill, dtype=x.dtype)
    out[: x.shape[0]] = x
    return out


def _prepare_edges(graph: Graph, result: PartitionResult, weights, symmetrize):
    src = np.asarray(graph.src, dtype=np.int64)
    dst = np.asarray(graph.dst, dtype=np.int64)
    part = result.part_in_input_order().astype(np.int64)
    p = result.num_parts
    if weights is None:
        weights = np.ones(src.shape[0], dtype=np.float32)
    if symmetrize:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        part = np.concatenate([part, part])
        weights = np.concatenate([weights, weights])
    return src, dst, part, weights, p


def _elect_masters(src, dst, part, p, num_vertices):
    """Master part per covered vertex + the unique (part, vertex) incidence
    pairs (v_of, p_of) the local vertex spaces are built from, plus the
    inverse map `inv` (endpoint occurrence -> unique-pair index; the first E
    entries are src endpoints, the last E dst endpoints)."""
    ends = np.concatenate([src, dst])
    pp = np.concatenate([part, part])
    key = ends * p + pp
    uk, inv, cnt = np.unique(key, return_inverse=True, return_counts=True)
    v_of = uk // p
    p_of = (uk % p).astype(np.int64)
    # Per covered vertex: part with max count, tie → lowest part id.
    sel = np.lexsort((p_of, -cnt, v_of))
    v_sorted = v_of[sel]
    first = np.ones(v_sorted.shape[0], dtype=bool)
    first[1:] = v_sorted[1:] != v_sorted[:-1]
    master_part = np.full(num_vertices, -1, dtype=np.int64)
    master_part[v_sorted[first]] = p_of[sel][first]
    return master_part, v_of, p_of, inv


def _exchange_tables(vp, vcol, vv, vkeys, v_off, master_part, *, p, N, max_v, pad_multiple):
    """Mirror↔master exchange tables from the grouped local vertex space
    (vp: owning part per unique (part, vertex) pair, nondecreasing; vcol:
    local id; vv: global id; vkeys/v_off: the strictly increasing fused
    lookup key and per-part offsets). Shared verbatim by the in-memory
    vectorized builder and the two-pass streamed builder — exchange-table
    parity between them is by construction."""
    mp_all = master_part[vv]
    is_mir = mp_all != vp
    mi = vp[is_mir]  # sender (mirror-holding) part i
    mj = mp_all[is_mir]  # receiver (master) part j
    lv = vcol[is_mir]  # local id at sender
    lm = np.searchsorted(vkeys, mj * N + vv[is_mir]) - v_off[mj]  # local id at master
    # Group by (i, j); within a pair, entries ascend by sender-local id —
    # the legacy lst.sort() order (lv is unique per sender).
    stride = np.int64(max_v + 1)
    mo = np.argsort((mi * p + mj) * stride + lv, kind="stable")
    gi, gj, glv, glm = mi[mo], mj[mo], lv[mo], lm[mo]
    pairkey = gi * p + gj
    cnts = np.bincount(pairkey, minlength=p * p).astype(np.int64)
    max_msg = max(int(cnts.max()) if cnts.size else 1, 1)
    max_msg = int(-(-max_msg // pad_multiple) * pad_multiple)
    pair_off = np.zeros(p * p + 1, np.int64)
    np.cumsum(cnts, out=pair_off[1:])
    m_idx = np.arange(gi.shape[0], dtype=np.int64) - pair_off[pairkey]

    send_idx = np.zeros((p, p, max_msg), np.int32)
    recv_idx = np.full((p, p, max_msg), max_v, np.int32)
    msg_mask = np.zeros((p, p, max_msg), bool)
    recv_mask = np.zeros((p, p, max_msg), bool)
    send_idx[gi, gj, m_idx] = glv
    recv_idx[gj, gi, m_idx] = glm
    msg_mask[gi, gj, m_idx] = True
    recv_mask[gj, gi, m_idx] = True
    return send_idx, recv_idx, msg_mask, recv_mask, max_msg


def build_subgraphs(
    graph: Graph,
    result: PartitionResult,
    *,
    weights: np.ndarray | None = None,
    symmetrize: bool = False,
    pad_multiple: int = 8,
    addressing: str = "two_level",
) -> SubgraphSet:
    """Vectorized builder: no per-part Python loops.

    Bit-for-bit equal to `build_subgraphs_legacy` (tests/test_build.py);
    every per-part loop is replaced by a grouped lexsort + offset-subtract,
    and the dict-of-lists exchange-table pass by one lexsort over the
    mirror set. O(E log E) numpy, edge-list streaming — the partitioner's
    output no longer dominates end-to-end wall-clock via builder glue.

    Emits two-level (worker, local-id) addressing by default: kernels see
    int32 local ids bounded by max_v (far below 2^24), global ids live in
    the int64 `local_to_global` view. `addressing="flat"` restores the
    legacy contract where kernel label domains span global ids.
    """
    check_addressing(addressing)
    src, dst, part, weights, p = _prepare_edges(graph, result, weights, symmetrize)
    N = graph.num_vertices
    if N > np.iinfo(np.int32).max:
        raise ValueError(
            f"subgraph gid table is int32: num_vertices={N} >= 2^31 is past the "
            "engine ceiling (two-level addressing lifts the 2^24 KERNEL bound, "
            "not the global-id width)"
        )
    E = src.shape[0]
    master_part, v_of, p_of, inv = _elect_masters(src, dst, part, p, N)

    out_deg_global = np.bincount(src, minlength=N).astype(np.float32)

    # ---- per-part local vertex spaces (sorted global ids), vectorized.
    # (p_of, v_of) pairs are unique; group by part keeping vertex order.
    # One fused int64 key sorts ~2x faster than a two-key lexsort.
    vsel = np.argsort(p_of * N + v_of, kind="stable")
    vp = p_of[vsel]  # owning part, nondecreasing
    vv = v_of[vsel]  # vertex ids, ascending within each part
    nv = np.bincount(p_of, minlength=p).astype(np.int64)
    v_off = np.zeros(p + 1, np.int64)
    np.cumsum(nv, out=v_off[1:])
    vcol = np.arange(vv.shape[0], dtype=np.int64) - v_off[vp]  # local vertex id
    # Strictly increasing (part, vertex) key: local id of vertex x in part q
    # is searchsorted(vkeys, q*N + x) - v_off[q].
    vkeys = vp * N + vv
    # Local id by unique-pair index — turns every edge-endpoint lookup into
    # one O(E) gather through `inv` instead of an O(E log K) searchsorted.
    lid_of_pair = np.empty(vv.shape[0], np.int64)
    lid_of_pair[vsel] = vcol

    ne = np.bincount(part, minlength=p).astype(np.int64)
    max_v = int(-(-max(int(nv.max()) if nv.size else 1, 1) // pad_multiple) * pad_multiple)
    max_e = int(-(-max(int(ne.max()) if ne.size else 1, 1) // pad_multiple) * pad_multiple)

    gid = np.full((p, max_v), -1, np.int32)
    vmask = np.zeros((p, max_v), bool)
    is_master = np.zeros((p, max_v), bool)
    out_degree = np.zeros((p, max_v), np.float32)
    gid[vp, vcol] = vv
    vmask[vp, vcol] = True
    is_master[vp, vcol] = master_part[vv] == vp
    out_degree[vp, vcol] = out_deg_global[vv]

    # ---- local edges (both sort orders), vectorized.
    ls = lid_of_pair[inv[:E]].astype(np.int32)
    ld = lid_of_pair[inv[E:]].astype(np.int32)
    e_off = np.zeros(p + 1, np.int64)
    np.cumsum(ne, out=e_off[1:])

    lsrc = np.zeros((p, max_e), np.int32)
    ldst = np.full((p, max_e), max_v, np.int32)
    weight_arr = np.zeros((p, max_e), np.float32)
    edge_mask = np.zeros((p, max_e), bool)
    lsrc_s = np.full((p, max_e), max_v, np.int32)
    ldst_s = np.zeros((p, max_e), np.int32)
    weight_s = np.zeros((p, max_e), np.float32)
    edge_mask_s = np.zeros((p, max_e), bool)

    # Stable sort on a fused (part, local-id) key: part-major, local-id
    # minor, original order on ties — exactly the legacy per-part stable
    # argsort. max_v + 1 bounds every local id, so the key never collides.
    stride = np.int64(max_v + 1)
    o = np.argsort(part * stride + ld, kind="stable")
    row = part[o]
    col = np.arange(E, dtype=np.int64) - e_off[row]
    lsrc[row, col] = ls[o]
    ldst[row, col] = ld[o]
    weight_arr[row, col] = weights[o]
    edge_mask[row, col] = True

    o2 = np.argsort(part * stride + ls, kind="stable")
    row2 = part[o2]
    col2 = np.arange(E, dtype=np.int64) - e_off[row2]
    lsrc_s[row2, col2] = ls[o2]
    ldst_s[row2, col2] = ld[o2]
    weight_s[row2, col2] = weights[o2]
    edge_mask_s[row2, col2] = True

    # ---- mirror↔master exchange tables, vectorized over the mirror set.
    send_idx, recv_idx, msg_mask, recv_mask, max_msg = _exchange_tables(
        vp, vcol, vv, vkeys, v_off, master_part,
        p=p, N=N, max_v=max_v, pad_multiple=pad_multiple,
    )

    return SubgraphSet(
        lsrc=jnp.asarray(lsrc),
        ldst=jnp.asarray(ldst),
        weight=jnp.asarray(weight_arr),
        edge_mask=jnp.asarray(edge_mask),
        lsrc_s=jnp.asarray(lsrc_s),
        ldst_s=jnp.asarray(ldst_s),
        weight_s=jnp.asarray(weight_s),
        edge_mask_s=jnp.asarray(edge_mask_s),
        gid=jnp.asarray(gid),
        vmask=jnp.asarray(vmask),
        is_master=jnp.asarray(is_master),
        out_degree=jnp.asarray(out_degree),
        send_idx=jnp.asarray(send_idx),
        recv_idx=jnp.asarray(recv_idx),
        msg_mask=jnp.asarray(msg_mask),
        recv_mask=jnp.asarray(recv_mask),
        num_parts=p,
        max_v=max_v,
        max_e=max_e,
        max_msg=max_msg,
        addressing=addressing,
    )


def build_subgraphs_legacy(
    graph: Graph,
    result: PartitionResult,
    *,
    weights: np.ndarray | None = None,
    symmetrize: bool = False,
    pad_multiple: int = 8,
) -> SubgraphSet:
    """Reference builder with per-part Python loops (the original
    implementation). Kept as the golden oracle for `build_subgraphs` —
    tests assert the vectorized builder reproduces it bit-for-bit."""
    src, dst, part, weights, p = _prepare_edges(graph, result, weights, symmetrize)
    master_part, v_of, p_of, _ = _elect_masters(src, dst, part, p, graph.num_vertices)

    out_deg_global = np.bincount(src, minlength=graph.num_vertices).astype(np.float32)

    # ---- per-part local vertex spaces (sorted global ids).
    verts: list[np.ndarray] = []
    for i in range(p):
        verts.append(v_of[p_of == i])  # already unique & sorted within part
    nv = np.array([v.shape[0] for v in verts])
    ne = np.bincount(part, minlength=p)
    max_v = int(-(-max(int(nv.max()), 1) // pad_multiple) * pad_multiple)
    max_e = int(-(-max(int(ne.max()), 1) // pad_multiple) * pad_multiple)

    gid = np.full((p, max_v), -1, np.int32)
    vmask = np.zeros((p, max_v), bool)
    is_master = np.zeros((p, max_v), bool)
    out_degree = np.zeros((p, max_v), np.float32)
    for i in range(p):
        n = nv[i]
        gid[i, :n] = verts[i]
        vmask[i, :n] = True
        is_master[i, :n] = master_part[verts[i]] == i
        out_degree[i, :n] = out_deg_global[verts[i]]

    # ---- local edges (both sort orders).
    lsrc = np.zeros((p, max_e), np.int32)
    ldst = np.full((p, max_e), max_v, np.int32)
    weight_arr = np.zeros((p, max_e), np.float32)
    edge_mask = np.zeros((p, max_e), bool)
    lsrc_s = np.full((p, max_e), max_v, np.int32)
    ldst_s = np.zeros((p, max_e), np.int32)
    weight_s = np.zeros((p, max_e), np.float32)
    edge_mask_s = np.zeros((p, max_e), bool)
    for i in range(p):
        eids = np.flatnonzero(part == i)
        ls = np.searchsorted(verts[i], src[eids]).astype(np.int32)
        ld = np.searchsorted(verts[i], dst[eids]).astype(np.int32)
        w = weights[eids]
        o = np.argsort(ld, kind="stable")
        n = eids.shape[0]
        lsrc[i, :n], ldst[i, :n], weight_arr[i, :n] = ls[o], ld[o], w[o]
        edge_mask[i, :n] = True
        o2 = np.argsort(ls, kind="stable")
        lsrc_s[i, :n], ldst_s[i, :n], weight_s[i, :n] = ls[o2], ld[o2], w[o2]
        edge_mask_s[i, :n] = True

    # ---- mirror↔master exchange tables.
    links: dict[tuple[int, int], list[tuple[int, int]]] = {}
    for i in range(p):
        mp = master_part[verts[i]]
        mirrors = np.flatnonzero(mp != i)
        for lv in mirrors:
            j = int(mp[lv])
            lm = int(np.searchsorted(verts[j], verts[i][lv]))
            links.setdefault((i, j), []).append((int(lv), lm))
    max_msg = max(max((len(v) for v in links.values()), default=1), 1)
    max_msg = int(-(-max_msg // pad_multiple) * pad_multiple)
    send_idx = np.zeros((p, p, max_msg), np.int32)
    recv_idx = np.full((p, p, max_msg), max_v, np.int32)
    msg_mask = np.zeros((p, p, max_msg), bool)
    recv_mask = np.zeros((p, p, max_msg), bool)
    for (i, j), lst in links.items():
        lst.sort()
        n = len(lst)
        send_idx[i, j, :n] = [a for a, _ in lst]
        recv_idx[j, i, :n] = [b for _, b in lst]
        msg_mask[i, j, :n] = True
        recv_mask[j, i, :n] = True

    return SubgraphSet(
        lsrc=jnp.asarray(lsrc),
        ldst=jnp.asarray(ldst),
        weight=jnp.asarray(weight_arr),
        edge_mask=jnp.asarray(edge_mask),
        lsrc_s=jnp.asarray(lsrc_s),
        ldst_s=jnp.asarray(ldst_s),
        weight_s=jnp.asarray(weight_s),
        edge_mask_s=jnp.asarray(edge_mask_s),
        gid=jnp.asarray(gid),
        vmask=jnp.asarray(vmask),
        is_master=jnp.asarray(is_master),
        out_degree=jnp.asarray(out_degree),
        send_idx=jnp.asarray(send_idx),
        recv_idx=jnp.asarray(recv_idx),
        msg_mask=jnp.asarray(msg_mask),
        recv_mask=jnp.asarray(recv_mask),
        num_parts=p,
        max_v=max_v,
        max_e=max_e,
        max_msg=max_msg,
    )
