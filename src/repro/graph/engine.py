"""Subgraph-centric bulk-synchronous-parallel engine (paper §IV-B).

One subgraph == one worker == one mesh device. A superstep is
  1. compute:   local fixpoint over the subgraph's own edges ("think like a
                graph" — iterate to convergence inside the subgraph),
  2. exchange:  mirror→master reduction then master→mirror broadcast over
                fixed padded buffers (dense all_to_all; the TPU-native
                替代 of MPI point-to-point sends),
  3. barrier:   implicit in SPMD — the collective is the synchronization.

Two execution modes sharing the same superstep body:
  - simulation:   all p workers live on one device as a leading batch axis;
                  exchange is a transpose. Used by tests/benchmarks.
  - distributed:  shard_map over a mesh axis; exchange is lax.all_to_all.
                  Used by the multi-pod dry-run and real clusters.

Messages are counted with delta semantics (a mirror/master "sends" only if
its value changed this superstep) — the paper's platform-independent
communication metric (Tables IV/V). `exchange_period > 1` enables bounded
staleness (straggler mitigation): workers run k local supersteps between
global exchanges; monotone (min-semiring) programs converge to the same
fixpoint.
"""
from __future__ import annotations

import collections
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.api.config import check_compute_backend
from repro.compat import shard_map_compat
from repro.core.metrics import max_mean_ratio
from repro.graph.build import SubgraphSet
from repro.kernels import ops

INF_F32 = jnp.float32(3.0e38)
INF_I32 = jnp.int32(2**31 - 1)

# Simulation-mode driver implementations. "fused" runs the whole BSP loop as
# one jitted lax.while_loop program (one dispatch, one host sync per run);
# "host" runs one jitted superstep per Python iteration (kept for A/B and as
# the readable reference of the loop semantics).
DRIVERS = ("fused", "host")

# Device-program dispatch accounting for the sim drivers: keys "fused" /
# "host", incremented once per jitted call. tests/test_drivers.py pins the
# fused drivers to exactly one dispatch per run with this counter.
DISPATCH_COUNTS: collections.Counter = collections.Counter()


def check_driver(driver) -> str:
    if driver not in DRIVERS:
        raise ValueError(f"driver must be one of {DRIVERS}, got {driver!r}")
    return driver


@dataclasses.dataclass
class BSPStats:
    supersteps: int
    messages_per_worker: np.ndarray  # [p] total messages sent by each worker
    messages_per_step: np.ndarray  # [steps]
    comp_work_per_worker: np.ndarray  # [p] edge-relaxation work proxy
    inner_iters_per_step: np.ndarray  # [steps, p]
    # Full per-step per-worker message matrix [steps, p] — what the BSP cost
    # model in benchmarks/runtime.py consumes. messages_per_worker and
    # messages_per_step above are its marginals, kept for existing call
    # sites; every driver populates all three.
    messages_per_step_worker: np.ndarray

    @property
    def total_messages(self) -> int:
        return int(self.messages_per_worker.sum())

    @property
    def max_mean(self) -> float:
        """Paper Table-V max/mean message balance (single definition in
        repro.core.metrics)."""
        return max_mean_ratio(self.messages_per_worker)


# ---------------------------------------------------------------- helpers


def _gather_rows(val: jax.Array, idx: jax.Array) -> jax.Array:
    """val: [p, max_v+1]; idx: [p, p, m] → out[i, j, m] = val[i, idx[i,j,m]]."""
    p = val.shape[0]
    return jnp.take_along_axis(val, idx.reshape(p, -1), axis=1).reshape(idx.shape)


def _scatter_min(val: jax.Array, idx: jax.Array, upd: jax.Array) -> jax.Array:
    p = val.shape[0]
    rows = jnp.arange(p)[:, None]
    return val.at[rows, idx.reshape(p, -1)].min(upd.reshape(p, -1))


def _scatter_add(val: jax.Array, idx: jax.Array, upd: jax.Array) -> jax.Array:
    p = val.shape[0]
    rows = jnp.arange(p)[:, None]
    return val.at[rows, idx.reshape(p, -1)].add(upd.reshape(p, -1))


def _scatter_set(val: jax.Array, idx: jax.Array, upd: jax.Array) -> jax.Array:
    p = val.shape[0]
    rows = jnp.arange(p)[:, None]
    return val.at[rows, idx.reshape(p, -1)].set(upd.reshape(p, -1))


def _segment_min(data, seg, num_segments):
    return jax.ops.segment_min(data, seg, num_segments=num_segments, indices_are_sorted=True)


# ------------------------------------------------------- min-semiring BSP


@dataclasses.dataclass(frozen=True)
class MinProgram:
    """CC / SSSP family: propagate min(val[src] (+ w)) along edges."""

    name: str
    use_weight: bool  # SSSP adds edge weight; CC doesn't
    bidirectional: bool  # CC treats edges as undirected
    dtype: str  # "int32" | "float32"

    @property
    def inf(self):
        return INF_I32 if self.dtype == "int32" else INF_F32


CC = MinProgram("cc", use_weight=False, bidirectional=True, dtype="int32")
SSSP = MinProgram("sssp", use_weight=True, bidirectional=False, dtype="float32")


def _relax_xla(prog: MinProgram, sub: SubgraphSet, v: jax.Array) -> jax.Array:
    """One local relaxation sweep via generic XLA segment ops."""
    nseg = sub.max_v + 1
    inf = prog.inf
    data = jnp.take_along_axis(v, sub.lsrc, axis=1)
    if prog.use_weight:
        data = data + sub.weight.astype(v.dtype)
    data = jnp.where(sub.edge_mask, data, inf)
    cand = jax.vmap(lambda d, s: _segment_min(d, s, nseg))(data, sub.ldst)
    new = jnp.minimum(v, cand)
    if prog.bidirectional:
        data2 = jnp.take_along_axis(v, sub.ldst_s, axis=1)
        if prog.use_weight:
            data2 = data2 + sub.weight_s.astype(v.dtype)
        data2 = jnp.where(sub.edge_mask_s, data2, inf)
        cand2 = jax.vmap(lambda d, s: _segment_min(d, s, nseg))(data2, sub.lsrc_s)
        new = jnp.minimum(new, cand2)
    return new


def _make_relax_kernel(
    prog: MinProgram, sub: SubgraphSet, backend: str, interpret: bool | None = None
):
    """One local relaxation sweep via repro.kernels min-plus segment reduce,
    vmapped over the worker axis. Operates on f32 values (see the INF
    remapping in `_local_min_fixpoint`); padded edges carry the INF weight
    identity, matching the kernels' convention. `interpret=None` lets ops
    sniff the host backend; the distributed stepper passes the MESH
    platform instead, so lowering for a TPU mesh from a CPU host bakes in
    the compiled kernel, not the interpreter."""
    nseg = sub.max_v + 1

    def edge_w(weight, mask):
        w = weight if prog.use_weight else jnp.zeros_like(weight)
        return jnp.where(mask, w, INF_F32)

    w_fwd = edge_w(sub.weight, sub.edge_mask)
    w_bwd = edge_w(sub.weight_s, sub.edge_mask_s) if prog.bidirectional else None
    op = jax.vmap(
        functools.partial(ops.segment_min_plus, num_out=nseg, impl=backend, interpret=interpret),
        in_axes=(0, 0, 0, 0),
    )

    def relax(v):
        # segment_min_plus seeds the output with v, so `op` returns the
        # fully relaxed vector (no extra jnp.minimum with v needed).
        new = op(sub.lsrc, sub.ldst, w_fwd, v)
        if prog.bidirectional:
            # Reverse direction: reduce into sources using the src-sorted
            # edge copy (lsrc_s is the sorted/destination role here).
            new = jnp.minimum(new, op(sub.ldst_s, sub.lsrc_s, w_bwd, v))
        return new

    return relax


def _local_min_fixpoint(
    prog: MinProgram,
    sub: SubgraphSet,
    val: jax.Array,
    inner_cap: int,
    backend: str = "xla",
    interpret: bool | None = None,
):
    """Batched local fixpoint. val: [p, max_v+1] (last slot = dump).

    backend "xla" runs generic segment ops; "ref"/"pallas" route through
    repro.kernels.ops (f32 min-plus). For int32 programs (CC) the kernel
    path remaps INF_I32 <-> INF_F32 and runs the loop in f32 — exact only
    for vertex labels below 2^24 (`run_min_bsp` enforces this; graphs
    beyond it must use backend "xla").
    """
    if backend == "xla":
        relax = functools.partial(_relax_xla, prog, sub)
    else:
        relax = _make_relax_kernel(prog, sub, backend, interpret)

    to_f32 = backend != "xla" and prog.dtype == "int32"
    v0 = jnp.where(val == INF_I32, INF_F32, val.astype(jnp.float32)) if to_f32 else val

    def body_count(carry):
        v, ch, it, iters = carry
        new = relax(v)
        ch = jnp.any(new != v, axis=1)  # per worker
        return new, ch, it + 1, iters + ch.astype(jnp.int32)

    p = val.shape[0]
    carry = (v0, jnp.ones((p,), bool), jnp.int32(0), jnp.zeros((p,), jnp.int32))
    carry = jax.lax.while_loop(lambda c: jnp.any(c[1]) & (c[2] < inner_cap), body_count, carry)
    new_val, _, _, iters = carry
    if to_f32:
        new_val = jnp.where(new_val >= INF_F32, INF_I32, new_val.astype(jnp.int32))
    return new_val, iters


def _min_superstep(
    prog: MinProgram,
    sub: SubgraphSet,
    val,
    exchange,
    inner_cap: int,
    do_exchange: bool = True,
    count_ref=None,
    backend: str = "xla",
    interpret: bool | None = None,
):
    """One BSP superstep. Returns (new_val, per-worker msg count, iters).

    `count_ref` is the value snapshot of the LAST exchange — delta messages
    are counted against it (matters under bounded staleness).
    """
    start = val if count_ref is None else count_ref
    val2, iters = _local_min_fixpoint(prog, sub, val, inner_cap, backend, interpret)
    if not do_exchange:  # bounded-staleness local step (straggler mitigation)
        return val2, jnp.zeros((val.shape[0],), jnp.int32), iters

    # mirror → master (forward): send current values of mirror slots.
    S = _gather_rows(val2, sub.send_idx)  # [i, j, m]
    changed = val2 != start
    ch_send = jnp.take_along_axis(changed, sub.send_idx.reshape(val.shape[0], -1), axis=1).reshape(
        sub.send_idx.shape
    )
    msgs_fwd = jnp.sum(ch_send & sub.msg_mask, axis=(1, 2))
    R = exchange(S)  # receiver-rowed [j, i, m]
    val3 = _scatter_min(val2, sub.recv_idx, jnp.where(sub.recv_mask, R, prog.inf))

    # master → mirror (broadcast): masters push combined value back.
    B = _gather_rows(val3, sub.recv_idx)  # [j, i, m] master values
    ch_master = val3 != start
    ch_b = jnp.take_along_axis(
        ch_master, sub.recv_idx.reshape(val.shape[0], -1), axis=1
    ).reshape(sub.recv_idx.shape)
    msgs_bwd = jnp.sum(ch_b & sub.recv_mask, axis=(1, 2))
    Rb = exchange(B)  # sender-rowed view at mirrors: [i, j, m]
    idx_masked = jnp.where(sub.msg_mask, sub.send_idx, sub.max_v)
    val4 = _scatter_set(val3, idx_masked, Rb)

    return val4, msgs_fwd + msgs_bwd, iters


# --------------------------------------------------------------- PageRank


def _pr_superstep(
    sub: SubgraphSet, rank, exchange, damping: float, num_vertices: int, backend: str = "xla"
):
    """One PageRank (power-iteration) superstep."""
    p = rank.shape[0]
    nseg = sub.max_v + 1
    outdeg = jnp.concatenate([sub.out_degree, jnp.ones((p, 1), jnp.float32)], axis=1)
    share = jnp.where(outdeg > 0, rank / outdeg, 0.0)
    if backend == "xla":
        data = jnp.take_along_axis(share, sub.lsrc, axis=1)
        data = jnp.where(sub.edge_mask, data, 0.0)
        partial = jax.vmap(
            lambda d, s: jax.ops.segment_sum(d, s, num_segments=nseg, indices_are_sorted=True)
        )(data, sub.ldst)
    else:
        # sum-times segment reduce: padded edges carry scale=0 (sum identity).
        scale = sub.edge_mask.astype(jnp.float32)
        partial = jax.vmap(
            functools.partial(ops.segment_sum_scaled, num_out=nseg, impl=backend),
            in_axes=(0, 0, 0, 0),
        )(sub.lsrc, sub.ldst, scale, share)

    # mirror partials → master (sum), then master computes the new rank.
    S = _gather_rows(partial, sub.send_idx)
    msgs_fwd = jnp.sum(sub.msg_mask, axis=(1, 2))  # PR sends every superstep
    R = exchange(S)
    total = _scatter_add(partial, sub.recv_idx, jnp.where(sub.recv_mask, R, 0.0))
    base = (1.0 - damping) / num_vertices
    new_rank = jnp.where(sub.is_master, base + damping * total[:, : sub.max_v], 0.0)
    new_rank = jnp.concatenate([new_rank, jnp.zeros((p, 1), jnp.float32)], axis=1)

    # broadcast master rank → mirrors.
    B = _gather_rows(new_rank, sub.recv_idx)
    msgs_bwd = jnp.sum(sub.recv_mask, axis=(1, 2))
    Rb = exchange(B)
    idx_masked = jnp.where(sub.msg_mask, sub.send_idx, sub.max_v)
    new_rank = _scatter_set(new_rank, idx_masked, Rb)
    delta = jnp.abs(new_rank[:, : sub.max_v] - rank[:, : sub.max_v]).sum()
    return new_rank, msgs_fwd + msgs_bwd, delta


def check_int32_kernel_labels(prog: MinProgram, sub: SubgraphSet, compute_backend: str) -> None:
    """Refuse kernel backends for int32 programs with labels >= 2^24.

    The kernel path runs the int32 min-semiring in f32, which is only exact
    for labels below 2^24 — larger ids would merge distinct CC components
    silently. Both the sim and distributed drivers call this before
    launching.
    """
    check_compute_backend(compute_backend)
    if compute_backend != "xla" and prog.dtype == "int32":
        max_label = int(jnp.max(sub.gid))
        if max_label >= 1 << 24:
            raise ValueError(
                f"compute_backend={compute_backend!r} runs int32 {prog.name} in f32, "
                f"exact only for vertex ids < 2^24; graph has id {max_label} — "
                "use compute_backend='xla'"
            )


# ------------------------------------------------------------ entry points


def _sim_exchange(S: jax.Array) -> jax.Array:
    return jnp.swapaxes(S, 0, 1)


def init_cc(sub: SubgraphSet) -> jax.Array:
    p = sub.gid.shape[0]
    val = jnp.where(sub.vmask, sub.gid, INF_I32)
    return jnp.concatenate([val, jnp.full((p, 1), INF_I32, jnp.int32)], axis=1)


def init_sssp(sub: SubgraphSet, source: int) -> jax.Array:
    p = sub.gid.shape[0]
    val = jnp.where(sub.gid == source, 0.0, INF_F32).astype(jnp.float32)
    return jnp.concatenate([val, jnp.full((p, 1), INF_F32, jnp.float32)], axis=1)


def init_pr(sub: SubgraphSet, num_vertices: int) -> jax.Array:
    p = sub.gid.shape[0]
    # Mirrors start with the same 1/N as masters (broadcast of the init) —
    # every present vertex replica holds the global initial rank.
    val = jnp.where(sub.vmask, 1.0 / num_vertices, 0.0).astype(jnp.float32)
    return jnp.concatenate([val, jnp.zeros((p, 1), jnp.float32)], axis=1)


@functools.partial(jax.jit, static_argnames=("prog", "inner_cap", "do_exchange", "backend"))
def _jit_min_superstep_sim(prog, sub, val, inner_cap, do_exchange, count_ref, backend="xla"):
    return _min_superstep(prog, sub, val, _sim_exchange, inner_cap, do_exchange, count_ref, backend)


@functools.partial(jax.jit, static_argnames=("damping", "num_vertices", "backend"))
def _jit_pr_superstep_sim(sub, rank, damping, num_vertices, backend="xla"):
    return _pr_superstep(sub, rank, _sim_exchange, damping, num_vertices, backend)


# ------------------------------------------------------ fused sim drivers
#
# The host drivers below dispatch one device program per superstep and sync
# after each one (np.asarray of the message counts, the convergence bool).
# The fused drivers run the WHOLE BSP loop inside one jitted lax.while_loop:
# per-step stats land in preallocated [max_supersteps, p] on-device buffers,
# convergence exits the loop inside the trace, the value carry is donated,
# and the host syncs exactly once per run to fetch (steps, stats).


@functools.partial(
    jax.jit,
    static_argnames=("prog", "max_supersteps", "inner_cap", "exchange_period", "backend"),
    donate_argnums=(1,),
)
def _fused_min_bsp(sub, val, *, prog, max_supersteps, inner_cap, exchange_period, backend):
    p = val.shape[0]
    msgs_buf = jnp.zeros((max_supersteps, p), jnp.int32)
    iters_buf = jnp.zeros((max_supersteps, p), jnp.int32)

    def cond(carry):
        _, _, k, done, _, _ = carry
        return ~done & (k < max_supersteps)

    def body(carry):
        v, last_ex, k, _, msgs_buf, iters_buf = carry
        if exchange_period == 1:
            # Static specialization of the common case: every step exchanges,
            # so the trace needs no branch or last-exchange select.
            v2, msgs, iters = _min_superstep(
                prog, sub, v, _sim_exchange, inner_cap, True, last_ex, backend
            )
            converged = ~jnp.any(v2 != v)
            last_ex = v2
        else:
            do_ex = (k % exchange_period) == (exchange_period - 1)
            v2, msgs, iters = jax.lax.cond(
                do_ex,
                lambda v_, le: _min_superstep(prog, sub, v_, _sim_exchange, inner_cap, True, le, backend),
                lambda v_, le: _min_superstep(prog, sub, v_, _sim_exchange, inner_cap, False, le, backend),
                v, last_ex,
            )
            # Converged only when an exchange round produced no change
            # anywhere (identical to the host driver's break condition).
            converged = do_ex & ~jnp.any(v2 != v)
            last_ex = jnp.where(do_ex, v2, last_ex)
        return (v2, last_ex, k + 1, converged, msgs_buf.at[k].set(msgs), iters_buf.at[k].set(iters))

    carry = (val, val, jnp.int32(0), jnp.bool_(False), msgs_buf, iters_buf)
    val, _, steps, _, msgs_buf, iters_buf = jax.lax.while_loop(cond, body, carry)
    # Edge counts ride along so the stats assembly needs no extra dispatch.
    edges = jnp.sum(sub.edge_mask, axis=1, dtype=jnp.int32)
    return val, steps, msgs_buf, iters_buf, edges


@functools.partial(
    jax.jit,
    static_argnames=("damping", "num_vertices", "num_iters", "tol", "backend"),
    donate_argnums=(1,),
)
def _fused_pagerank(sub, rank, *, damping, num_vertices, num_iters, tol, backend):
    p = rank.shape[0]
    msgs_buf = jnp.zeros((num_iters, p), jnp.int32)

    def cond(carry):
        _, k, done, _ = carry
        return ~done & (k < num_iters)

    def body(carry):
        r, k, _, msgs_buf = carry
        r2, msgs, delta = _pr_superstep(sub, r, _sim_exchange, damping, num_vertices, backend)
        done = (delta < tol) if tol else jnp.bool_(False)
        return r2, k + 1, done, msgs_buf.at[k].set(msgs)

    rank, steps, _, msgs_buf = jax.lax.while_loop(
        cond, body, (rank, jnp.int32(0), jnp.bool_(False), msgs_buf)
    )
    edges = jnp.sum(sub.edge_mask, axis=1, dtype=jnp.int32)
    return rank, steps, msgs_buf, edges


def _min_stats(steps: int, msgs_sw: np.ndarray, iters_sw: np.ndarray, edges: np.ndarray) -> BSPStats:
    return BSPStats(
        supersteps=steps,
        messages_per_worker=msgs_sw.sum(axis=0),
        messages_per_step=msgs_sw.sum(axis=1),
        comp_work_per_worker=(iters_sw * edges[None, :]).sum(axis=0),
        inner_iters_per_step=iters_sw,
        messages_per_step_worker=msgs_sw,
    )


def run_min_bsp(
    sub: SubgraphSet,
    prog: MinProgram,
    init_val: jax.Array,
    *,
    max_supersteps: int = 200,
    inner_cap: int = 10_000,
    exchange_period: int = 1,
    compute_backend: str = "xla",
    driver: str = "fused",
) -> tuple[jax.Array, BSPStats]:
    """Simulation-mode driver for CC/SSSP. exchange_period>1 = bounded staleness.

    compute_backend selects the local-relaxation implementation (see
    repro.api.config.COMPUTE_BACKENDS); all backends converge to the same
    fixpoint. driver="fused" runs the whole loop as one device program;
    driver="host" dispatches one superstep per Python iteration (identical
    values and stats — tests/test_drivers.py pins the equivalence).

    driver="fused" DONATES init_val to the device program (that is where
    the fused loop's zero-copy value carry starts): on accelerators the
    caller's buffer is consumed, so build a fresh init per run (as
    repro.graph.algorithms does) rather than reusing one across calls.
    """
    check_int32_kernel_labels(prog, sub, compute_backend)
    check_driver(driver)
    p = init_val.shape[0]

    if driver == "fused":
        val, steps, msgs_buf, iters_buf, edges = _fused_min_bsp(
            sub,
            init_val,
            prog=prog,
            max_supersteps=max_supersteps,
            inner_cap=inner_cap,
            exchange_period=exchange_period,
            backend=compute_backend,
        )
        DISPATCH_COUNTS["fused"] += 1
        # The run's single host sync: one device_get for every stat buffer.
        steps, msgs_sw, iters_sw, edges = jax.device_get((steps, msgs_buf, iters_buf, edges))
        steps = int(steps)
        return val, _min_stats(
            steps,
            msgs_sw[:steps].astype(np.int64),
            iters_sw[:steps].astype(np.int64),
            edges.astype(np.int64),
        )

    val = init_val
    msg_steps = []
    iters_steps = []
    edges = np.asarray(sub.edge_mask.sum(axis=1), np.int64)
    steps = 0
    last_exchanged = val
    for k in range(max_supersteps):
        do_exchange = (k % exchange_period) == exchange_period - 1
        before = val
        val, msgs, iters = _jit_min_superstep_sim(
            prog, sub, val, inner_cap, do_exchange, last_exchanged, compute_backend
        )
        DISPATCH_COUNTS["host"] += 1
        if do_exchange:
            last_exchanged = val
        steps += 1
        msg_steps.append(np.asarray(msgs, np.int64))
        iters_steps.append(np.asarray(iters, np.int64))
        # Converged only when an exchange round produced no change anywhere.
        if do_exchange and not bool(jnp.any(val != before)):
            break
    msgs_sw = np.asarray(msg_steps).reshape(steps, p)
    iters_sw = np.asarray(iters_steps).reshape(steps, p)
    return val, _min_stats(steps, msgs_sw, iters_sw, edges)


def run_pagerank(
    sub: SubgraphSet,
    num_vertices: int,
    *,
    damping: float = 0.85,
    num_iters: int = 20,
    tol: float = 0.0,
    compute_backend: str = "xla",
    driver: str = "fused",
) -> tuple[jax.Array, BSPStats]:
    check_compute_backend(compute_backend)
    check_driver(driver)
    rank = init_pr(sub, num_vertices)
    p = rank.shape[0]

    if driver == "fused":
        rank, steps, msgs_buf, edges = _fused_pagerank(
            sub,
            rank,
            damping=damping,
            num_vertices=num_vertices,
            num_iters=num_iters,
            tol=tol,
            backend=compute_backend,
        )
        DISPATCH_COUNTS["fused"] += 1
        steps, msgs_sw, edges = jax.device_get((steps, msgs_buf, edges))
        steps = int(steps)
        msgs_sw = msgs_sw[:steps].astype(np.int64)
        edges = edges.astype(np.int64)
    else:
        msg_steps = []
        edges = np.asarray(sub.edge_mask.sum(axis=1), np.int64)
        steps = 0
        for _ in range(num_iters):
            rank, msgs, delta = _jit_pr_superstep_sim(
                sub, rank, damping, num_vertices, compute_backend
            )
            DISPATCH_COUNTS["host"] += 1
            steps += 1
            msg_steps.append(np.asarray(msgs, np.int64))
            if tol and float(delta) < tol:
                break
        msgs_sw = np.asarray(msg_steps).reshape(steps, p)
    return rank, BSPStats(
        supersteps=steps,
        messages_per_worker=msgs_sw.sum(axis=0),
        messages_per_step=msgs_sw.sum(axis=1),
        comp_work_per_worker=edges * steps,
        inner_iters_per_step=np.ones((steps, p), np.int64),
        messages_per_step_worker=msgs_sw,
    )


# ------------------------------------------------- distributed (shard_map)


_ARRAY_FIELDS = [
    "lsrc", "ldst", "weight", "edge_mask",
    "lsrc_s", "ldst_s", "weight_s", "edge_mask_s",
    "gid", "vmask", "is_master", "out_degree",
    "send_idx", "recv_idx", "msg_mask", "recv_mask",
]
_STATIC_FIELDS = ["num_parts", "max_v", "max_e", "max_msg"]


def subgraphs_to_arrays(sub: SubgraphSet) -> tuple[dict, dict]:
    arrays = {k: getattr(sub, k) for k in _ARRAY_FIELDS}
    statics = {k: getattr(sub, k) for k in _STATIC_FIELDS}
    return arrays, statics


def make_distributed_stepper(
    mesh,
    axes,
    prog: MinProgram,
    statics: dict,
    *,
    num_supersteps: int,
    inner_cap: int,
    compute_backend: str = "xla",
):
    """Builds a shard_map'd BSP runner: subgraphs sharded 1:1 over `axes`.

    `axes` may be a single mesh axis name or a tuple (e.g. ("pod","data",
    "model")) whose sizes multiply to the number of subgraphs — this is what
    the multi-pod dry-run lowers: p=512 subgraphs over (pod, data, model).
    Takes the subgraph tensors as a dict (see `subgraphs_to_arrays`) so the
    sharding specs form a clean pytree.

    Like the fused sim driver, the step loop is a lax.while_loop that exits
    as soon as a superstep changes nothing on any device (global flag via
    psum) and records per-step message/inner-iteration stats in
    [num_supersteps, local] device buffers. Returns
    (val, msgs_total, steps, msgs_per_step, iters_per_step).
    """
    check_compute_backend(compute_backend)
    # Pallas interpret vs compiled is keyed off the MESH platform, not the
    # host process backend: AOT-lowering for a TPU mesh from a CPU host must
    # bake in the compiled kernel, not the interpreter.
    try:
        mesh_platform = mesh.devices.reshape(-1)[0].platform
    except AttributeError:  # abstract/mock meshes: fall back to the host sniff
        mesh_platform = None
    interpret = None if mesh_platform is None else mesh_platform != "tpu"
    axis_tuple = axes if isinstance(axes, tuple) else (axes,)
    spec3 = P(axis_tuple, None, None)
    spec2 = P(axis_tuple, None)
    in_specs = ({k: (spec3 if k in ("send_idx", "recv_idx", "msg_mask", "recv_mask") else spec2) for k in _ARRAY_FIELDS}, spec2)

    def a2a_exchange(S):  # S: [1, p, m] per device
        out = jax.lax.all_to_all(S, axis_tuple, split_axis=1, concat_axis=0, tiled=False)
        # out: [p, 1, m] → receiver-rowed [1, p, m]
        return jnp.swapaxes(out, 0, 1)

    def stepper(arrays: dict, val: jax.Array):
        sub = SubgraphSet(**arrays, **statics)
        nloc = val.shape[0]  # subgraphs per device (1 on a fully sharded mesh)
        msgs_buf = jnp.zeros((num_supersteps, nloc), jnp.int32)
        iters_buf = jnp.zeros((num_supersteps, nloc), jnp.int32)

        def cond(carry):
            _, k, done, _, _ = carry
            return ~done & (k < num_supersteps)

        def body(carry):
            v, k, _, msgs_buf, iters_buf = carry
            v2, m, it = _min_superstep(
                prog, sub, v, a2a_exchange, inner_cap,
                backend=compute_backend, interpret=interpret,
            )
            # Convergence is global: psum the per-device change flag so every
            # device takes the same trip count (collectives stay uniform).
            changed = jax.lax.psum(jnp.any(v2 != v).astype(jnp.int32), axis_tuple)
            return v2, k + 1, changed == 0, msgs_buf.at[k].set(m), iters_buf.at[k].set(it)

        val_out, steps, _, msgs_buf, iters_buf = jax.lax.while_loop(
            cond, body, (val, jnp.int32(0), jnp.bool_(False), msgs_buf, iters_buf)
        )
        return val_out, msgs_buf.sum(axis=0), steps, msgs_buf, iters_buf

    return shard_map_compat(
        stepper,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(spec2, P(axis_tuple), P(), P(None, axis_tuple), P(None, axis_tuple)),
    )
