"""Subgraph-centric bulk-synchronous-parallel engine (paper §IV-B).

One subgraph == one worker == one mesh device. A superstep is
  1. compute:   local work over the subgraph's own edges ("think like a
                graph") — either a fixpoint relaxation iterated to local
                convergence (min/max-semiring programs) or a single sweep
                (PageRank's push-sum),
  2. exchange:  mirror→master reduction then master→mirror broadcast over
                fixed padded buffers (dense all_to_all; the TPU-native
                替代 of MPI point-to-point sends),
  3. barrier:   implicit in SPMD — the collective is the synchronization.

Every algorithm is expressed as a `VertexProgram` — a frozen description of
what actually varies between them (value dtype, exchange combine, local
compute, apply step, message policy, convergence rule). ONE generic
superstep body, ONE fused driver, ONE host driver, and ONE distributed
stepper execute any program; CC, SSSP, PageRank, BFS, and max-label
reachability are stock instances in `PROGRAMS`.

Two execution modes sharing the same superstep body:
  - simulation:   all p workers live on one device as a leading batch axis;
                  exchange is a transpose. Used by tests/benchmarks.
  - distributed:  shard_map over a mesh axis; exchange is lax.all_to_all.
                  Used by the multi-pod dry-run and real clusters.

Messages are counted with delta semantics (a mirror/master "sends" only if
its value changed this superstep) for semiring programs — the paper's
platform-independent communication metric (Tables IV/V) — and every-step
semantics for PageRank (it pushes rank shares unconditionally).
`exchange_period > 1` enables bounded staleness (straggler mitigation):
workers run k local supersteps between global exchanges; monotone
(min/max-semiring) programs converge to the same fixpoint.

Max-combine programs run on the existing min-plus machinery (and hence the
min-plus Pallas kernels) via negation at the driver boundary: values are
negated on entry and on exit, so the superstep body only ever sees the
{min, sum} combines.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.api.config import check_compute_backend
from repro.compat import shard_map_compat
from repro.core.metrics import max_mean_ratio
from repro.graph.build import SubgraphSet, check_addressing
from repro.kernels import ops

INF_F32 = jnp.float32(3.0e38)
INF_I32 = jnp.int32(2**31 - 1)

# Simulation-mode driver implementations. "fused" runs the whole BSP loop as
# one jitted lax.while_loop program (one dispatch, one host sync per run);
# "host" runs one jitted superstep per Python iteration (kept for A/B and as
# the readable reference of the loop semantics).
DRIVERS = ("fused", "host")

# Device-program dispatch accounting for the sim drivers: keys "fused" /
# "host", incremented once per jitted call. tests/test_drivers.py pins the
# fused drivers to exactly one dispatch per run with this counter.
DISPATCH_COUNTS: collections.Counter = collections.Counter()


def check_driver(driver) -> str:
    if driver not in DRIVERS:
        raise ValueError(f"driver must be one of {DRIVERS}, got {driver!r}")
    return driver


@dataclasses.dataclass
class BSPStats:
    supersteps: int
    messages_per_worker: np.ndarray  # [p] total messages sent by each worker
    messages_per_step: np.ndarray  # [steps]
    comp_work_per_worker: np.ndarray  # [p] edge-relaxation work proxy
    inner_iters_per_step: np.ndarray  # [steps, p]
    # Full per-step per-worker message matrix [steps, p] — what the BSP cost
    # model in benchmarks/runtime.py consumes. messages_per_worker and
    # messages_per_step above are its marginals, kept for existing call
    # sites; every driver populates all three.
    messages_per_step_worker: np.ndarray

    @property
    def total_messages(self) -> int:
        return int(self.messages_per_worker.sum())

    @property
    def max_mean(self) -> float:
        """Paper Table-V max/mean message balance (single definition in
        repro.core.metrics)."""
        return max_mean_ratio(self.messages_per_worker)


# ----------------------------------------------------------- VertexProgram


@dataclasses.dataclass(frozen=True)
class VertexProgram:
    """Everything that varies between BSP algorithms, in one hashable value.

    A program is a static argument to the jitted drivers, so every field
    must be hashable; semantics are strings/bools/floats, and `init_fn` is
    a module-level function (compared by identity, which keeps the jit
    cache stable across calls).

    | field | meaning |
    |---|---|
    | dtype       | value dtype: "int32" or "float32" |
    | combine     | exchange reduction & local semiring: "min" | "max" | "sum" |
    | local       | "fixpoint" (relax to local convergence) or "sweep" (one out-degree-normalized push-sum pass — PageRank's compute) |
    | weight      | what the semiring adds along an edge: "none", "edge" (the f32 edge weight), or "unit" (+1, BFS hop counts) |
    | bidirectional | relax both edge directions (undirected algorithms) |
    | apply       | master-side post-combine step: "none" or "pagerank" (damping + renormalize) |
    | message_policy | "delta" (count only changed values — paper Tables IV/V) or "always" |
    | convergence | "no_change" (fixpoint reached) or "tol" (L1 step delta below `tol`) |
    | damping     | apply="pagerank" damping factor |
    | init_fn     | (sub, *, num_vertices, source) -> [p, max_v+1] initial values |
    | needs_source | facade resolves a default source vertex (SSSP/BFS) |
    | default_steps | driver step budget when the caller passes none (PR's classic 20 power iterations) |
    """

    name: str
    dtype: str
    combine: str = "min"
    local: str = "fixpoint"
    weight: str = "none"
    bidirectional: bool = False
    apply: str = "none"
    message_policy: str = "delta"
    convergence: str = "no_change"
    damping: float = 0.85
    init_fn: Optional[Callable] = None
    needs_source: bool = False
    default_steps: Optional[int] = None
    aliases: tuple = ()

    def __post_init__(self):
        checks = (
            ("dtype", self.dtype, ("int32", "float32")),
            ("combine", self.combine, ("min", "max", "sum")),
            ("local", self.local, ("fixpoint", "sweep")),
            ("weight", self.weight, ("none", "edge", "unit")),
            ("apply", self.apply, ("none", "pagerank")),
            ("message_policy", self.message_policy, ("delta", "always")),
            ("convergence", self.convergence, ("no_change", "tol")),
        )
        for field, got, allowed in checks:
            if got not in allowed:
                raise ValueError(f"VertexProgram.{field} must be one of {allowed}, got {got!r}")
        if self.combine == "sum" and self.local != "sweep":
            raise ValueError("combine='sum' has no fixpoint semantics; use local='sweep'")
        if self.apply == "pagerank" and self.combine != "sum":
            raise ValueError("apply='pagerank' renormalizes summed partials; use combine='sum'")

    @property
    def inf(self):
        """Largest representable "unreached" value of the program's dtype."""
        return INF_I32 if self.dtype == "int32" else INF_F32

    @property
    def identity(self):
        """Identity of the exchange combine (fills masked recv slots)."""
        if self.combine == "sum":
            return jnp.float32(0.0)
        return -self.inf if self.combine == "max" else self.inf

    def init(self, sub: SubgraphSet, *, num_vertices: int = 0, source=None) -> jax.Array:
        if self.init_fn is None:
            raise ValueError(
                f"program {self.name!r} has no init_fn — pass init_val explicitly to run_bsp"
            )
        if self.needs_source and source is None:
            raise ValueError(
                f"program {self.name!r} is source-rooted: pass source= "
                "(GraphPipeline defaults it to the highest-degree covered vertex)"
            )
        return self.init_fn(sub, num_vertices=num_vertices, source=source)


def _exec_view(prog: VertexProgram) -> tuple[VertexProgram, bool]:
    """The semiring actually executed: max-combine programs run as min over
    negated values (reusing the min-plus kernels); everything else runs
    as-is. Returns (program-for-the-superstep, negate-values?)."""
    if prog.combine != "max":
        return prog, False
    return dataclasses.replace(prog, combine="min"), True


# --------------------------------------------------------- program registry

PROGRAMS: dict[str, VertexProgram] = {}


def register_program(prog: VertexProgram) -> VertexProgram:
    """Register a program under its name and aliases for string lookup
    (`GraphPipeline.run("bfs")`, `run_bsp(sub, "cc")`, benchmarks)."""
    # Keys are stored lowercased to match get_program's case-insensitive
    # lookup, and all validated before inserting any, so a rejected
    # registration leaves the registry untouched.
    keys = tuple(k.lower() for k in (prog.name, *prog.aliases))
    for key in keys:
        if key in PROGRAMS:
            raise ValueError(f"program name {key!r} already registered")
    for key in keys:
        PROGRAMS[key] = prog
    return prog


def get_program(program) -> VertexProgram:
    """Resolve a program handle (VertexProgram instance or registered name)."""
    if isinstance(program, VertexProgram):
        return program
    key = str(program).lower()
    if key not in PROGRAMS:
        names = sorted({p.name for p in PROGRAMS.values()})
        raise ValueError(f"unknown program {program!r}; registered programs: {names}")
    return PROGRAMS[key]


def program_names() -> tuple:
    """Primary (alias-free) names of all registered programs."""
    return tuple(sorted({p.name for p in PROGRAMS.values()}))


# ------------------------------------------------------------- init values


def check_source(sub: SubgraphSet, source, num_vertices: int = 0) -> int:
    """Validate a query source vertex id and return it as a Python int.

    Source-rooted inits (SSSP/BFS) must fail fast on an out-of-range
    source — silently accepting one returns an all-INF "answer" that looks
    like an unreachable graph. The valid range is [0, num_vertices) when
    the caller knows the global vertex count, else [0, max covered gid]
    (the tightest bound the subgraph tensors themselves carry). The serving
    tier validates at admission time so one bad source rejects that query
    alone instead of poisoning a whole micro-batch.
    """
    if source is None:
        raise ValueError("source must be a vertex id, got None")
    s = int(source)
    hi = int(num_vertices) if num_vertices > 0 else int(jnp.max(sub.gid)) + 1
    if not 0 <= s < hi:
        raise ValueError(f"source={s} is out of range: valid vertex ids are [0, {hi})")
    return s


def init_cc(sub: SubgraphSet, *, num_vertices: int = 0, source=None) -> jax.Array:
    p = sub.gid.shape[0]
    val = jnp.where(sub.vmask, sub.gid, INF_I32)
    return jnp.concatenate([val, jnp.full((p, 1), INF_I32, jnp.int32)], axis=1)


def init_sssp(sub: SubgraphSet, source: int, *, num_vertices: int = 0) -> jax.Array:
    source = check_source(sub, source, num_vertices)
    p = sub.gid.shape[0]
    val = jnp.where(sub.gid == source, 0.0, INF_F32).astype(jnp.float32)
    return jnp.concatenate([val, jnp.full((p, 1), INF_F32, jnp.float32)], axis=1)


def init_pr(sub: SubgraphSet, num_vertices: int, *, source=None) -> jax.Array:
    p = sub.gid.shape[0]
    # Mirrors start with the same 1/N as masters (broadcast of the init) —
    # every present vertex replica holds the global initial rank.
    val = jnp.where(sub.vmask, 1.0 / num_vertices, 0.0).astype(jnp.float32)
    return jnp.concatenate([val, jnp.zeros((p, 1), jnp.float32)], axis=1)


def init_bfs(sub: SubgraphSet, source: int, *, num_vertices: int = 0) -> jax.Array:
    source = check_source(sub, source, num_vertices)
    p = sub.gid.shape[0]
    val = jnp.where(sub.gid == source, 0, INF_I32).astype(jnp.int32)
    return jnp.concatenate([val, jnp.full((p, 1), INF_I32, jnp.int32)], axis=1)


def init_reach(sub: SubgraphSet, *, num_vertices: int = 0, source=None) -> jax.Array:
    # Max-label propagation: absent slots hold the max identity (-INF).
    p = sub.gid.shape[0]
    val = jnp.where(sub.vmask, sub.gid, -INF_I32)
    return jnp.concatenate([val, jnp.full((p, 1), -INF_I32, jnp.int32)], axis=1)


# ---------------------------------------------------------- stock programs

CC = register_program(VertexProgram(
    name="cc", dtype="int32", combine="min", bidirectional=True,
    init_fn=lambda sub, *, num_vertices=0, source=None: init_cc(sub),
    aliases=("components", "connected_components"),
))

SSSP = register_program(VertexProgram(
    name="sssp", dtype="float32", combine="min", weight="edge",
    init_fn=lambda sub, *, num_vertices=0, source=None: init_sssp(
        sub, source, num_vertices=num_vertices
    ),
    needs_source=True,
))

PR = register_program(VertexProgram(
    name="pr", dtype="float32", combine="sum", local="sweep", apply="pagerank",
    message_policy="always", convergence="tol",
    init_fn=lambda sub, *, num_vertices=0, source=None: init_pr(sub, num_vertices),
    default_steps=20,  # the classic fixed-iteration power-method budget
    aliases=("pagerank",),
))

BFS = register_program(VertexProgram(
    name="bfs", dtype="int32", combine="min", weight="unit",
    init_fn=lambda sub, *, num_vertices=0, source=None: init_bfs(
        sub, source, num_vertices=num_vertices
    ),
    needs_source=True,
))

REACH = register_program(VertexProgram(
    name="reach", dtype="int32", combine="max", bidirectional=True,
    init_fn=lambda sub, *, num_vertices=0, source=None: init_reach(sub),
    aliases=("reachability",),
))


# ---------------------------------------------------------------- helpers


def _gather_rows(val: jax.Array, idx: jax.Array) -> jax.Array:
    """val: [p, max_v+1]; idx: [p, p, m] → out[i, j, m] = val[i, idx[i,j,m]]."""
    p = val.shape[0]
    return jnp.take_along_axis(val, idx.reshape(p, -1), axis=1).reshape(idx.shape)


def _scatter_min(val: jax.Array, idx: jax.Array, upd: jax.Array) -> jax.Array:
    p = val.shape[0]
    rows = jnp.arange(p)[:, None]
    return val.at[rows, idx.reshape(p, -1)].min(upd.reshape(p, -1))


def _scatter_add(val: jax.Array, idx: jax.Array, upd: jax.Array) -> jax.Array:
    p = val.shape[0]
    rows = jnp.arange(p)[:, None]
    return val.at[rows, idx.reshape(p, -1)].add(upd.reshape(p, -1))


def _scatter_set(val: jax.Array, idx: jax.Array, upd: jax.Array) -> jax.Array:
    p = val.shape[0]
    rows = jnp.arange(p)[:, None]
    return val.at[rows, idx.reshape(p, -1)].set(upd.reshape(p, -1))


def _segment_min(data, seg, num_segments):
    return jax.ops.segment_min(data, seg, num_segments=num_segments, indices_are_sorted=True)


# -------------------------------------------------- local compute (stage 1)


def _edge_addend(prog: VertexProgram, weight: jax.Array, dtype) -> Optional[jax.Array]:
    """What the semiring adds along an edge, or None for weight='none'."""
    if prog.weight == "edge":
        return weight.astype(dtype)
    if prog.weight == "unit":
        return jnp.ones_like(weight, dtype=dtype)
    return None


def _add_saturating(prog: VertexProgram, data: jax.Array, w: jax.Array) -> jax.Array:
    """data + w with the INF identity absorbing: int32 INF + 1 must stay INF
    (not wrap to INT32_MIN and win every min — BFS over unreached sources).
    f32 INF absorbs additions natively."""
    if prog.dtype == "int32":
        return jnp.where(data >= prog.inf, prog.inf, data + w)
    return data + w


def _relax_xla(prog: VertexProgram, sub: SubgraphSet, v: jax.Array) -> jax.Array:
    """One local relaxation sweep via generic XLA segment ops."""
    nseg = sub.max_v + 1
    inf = prog.inf
    data = jnp.take_along_axis(v, sub.lsrc, axis=1)
    w = _edge_addend(prog, sub.weight, v.dtype)
    if w is not None:
        data = _add_saturating(prog, data, w)
    data = jnp.where(sub.edge_mask, data, inf)
    cand = jax.vmap(lambda d, s: _segment_min(d, s, nseg))(data, sub.ldst)
    new = jnp.minimum(v, cand)
    if prog.bidirectional:
        data2 = jnp.take_along_axis(v, sub.ldst_s, axis=1)
        w2 = _edge_addend(prog, sub.weight_s, v.dtype)
        if w2 is not None:
            data2 = _add_saturating(prog, data2, w2)
        data2 = jnp.where(sub.edge_mask_s, data2, inf)
        cand2 = jax.vmap(lambda d, s: _segment_min(d, s, nseg))(data2, sub.lsrc_s)
        new = jnp.minimum(new, cand2)
    return new


def _relax_stream(prog: VertexProgram, sub: SubgraphSet):
    """[p, E(+E)] (lsrc, ldst, weight) edge stream for `ops.bsp_superstep`:
    the forward CSR half and, for bidirectional programs, the reversed
    (src-sorted) half concatenated behind it. Weights are the semiring
    addend in f32 with padded edges carrying the INF identity; each half is
    dst-sorted, which is all the megakernel's rank compression needs."""

    def edge_w(weight, mask):
        w = _edge_addend(prog, weight, jnp.float32)
        if w is None:
            w = jnp.zeros_like(weight)
        return jnp.where(mask, w, INF_F32)

    lsrc, ldst, w = sub.lsrc, sub.ldst, edge_w(sub.weight, sub.edge_mask)
    if prog.bidirectional:
        # Reverse direction: reduce into sources using the src-sorted edge
        # copy (lsrc_s is the sorted/destination role here).
        lsrc = jnp.concatenate([lsrc, sub.ldst_s], axis=1)
        ldst = jnp.concatenate([ldst, sub.lsrc_s], axis=1)
        w = jnp.concatenate([w, edge_w(sub.weight_s, sub.edge_mask_s)], axis=1)
    return lsrc, ldst, w


def _local_fixpoint(
    prog: VertexProgram,
    sub: SubgraphSet,
    val: jax.Array,
    inner_cap: int,
    backend: str = "xla",
    interpret: bool | None = None,
    block_e: int = 512,
):
    """Batched local fixpoint. val: [p, max_v+1] (last slot = dump).

    backend "xla" runs generic segment ops; "ref"/"pallas" route the WHOLE
    local stage (every relaxation pass + the per-worker convergence flag)
    through the `ops.bsp_superstep` megakernel in one launch. For int32
    programs (CC/BFS/REACH) the kernel path remaps INF_I32 <-> INF_F32 and
    runs in f32 — exact only for values below 2^24 (`run_bsp` enforces
    this; graphs beyond it must use backend "xla"). The fused drivers hoist
    that remap to the run boundary by passing an f32 exec view of the
    program; this in-place branch only pays per call for the host driver.
    """
    to_f32 = backend != "xla" and prog.dtype == "int32"
    v0 = jnp.where(val == INF_I32, INF_F32, val.astype(jnp.float32)) if to_f32 else val

    if backend != "xla":
        lsrc, ldst, w = _relax_stream(prog, sub)
        new_val, iters = ops.bsp_superstep(
            lsrc, ldst, w, v0, num_out=sub.max_v + 1, combine="min",
            inner_cap=inner_cap, impl=backend, block_e=block_e, interpret=interpret,
        )
        if to_f32:
            new_val = jnp.where(new_val >= INF_F32, INF_I32, new_val.astype(jnp.int32))
        return new_val, iters

    relax = functools.partial(_relax_xla, prog, sub)

    def body_count(carry):
        v, ch, it, iters = carry
        new = relax(v)
        ch = jnp.any(new != v, axis=1)  # per worker
        return new, ch, it + 1, iters + ch.astype(jnp.int32)

    p = val.shape[0]
    carry = (v0, jnp.ones((p,), bool), jnp.int32(0), jnp.zeros((p,), jnp.int32))
    carry = jax.lax.while_loop(lambda c: jnp.any(c[1]) & (c[2] < inner_cap), body_count, carry)
    new_val, _, _, iters = carry
    return new_val, iters


def _local_sweep(
    prog: VertexProgram,
    sub: SubgraphSet,
    val: jax.Array,
    backend: str = "xla",
    interpret: bool | None = None,
    block_e: int = 512,
) -> jax.Array:
    """One out-degree-normalized push-sum pass (PageRank's local compute):
    each vertex pushes val/outdeg along its out-edges, summed at dst."""
    p = val.shape[0]
    nseg = sub.max_v + 1
    outdeg = jnp.concatenate([sub.out_degree, jnp.ones((p, 1), jnp.float32)], axis=1)
    if backend != "xla":
        # Megakernel path: the share division is fused at the gather, padded
        # edges carry weight 0 (the sum identity and the kernel's pad mask).
        scale = sub.edge_mask.astype(jnp.float32)
        new, _ = ops.bsp_superstep(
            sub.lsrc, sub.ldst, scale, val, num_out=nseg, combine="sum",
            out_degree=outdeg, impl=backend, block_e=block_e, interpret=interpret,
        )
        return new
    share = jnp.where(outdeg > 0, val / outdeg, 0.0)
    data = jnp.take_along_axis(share, sub.lsrc, axis=1)
    data = jnp.where(sub.edge_mask, data, 0.0)
    return jax.vmap(
        lambda d, s: jax.ops.segment_sum(d, s, num_segments=nseg, indices_are_sorted=True)
    )(data, sub.ldst)


# --------------------------------------------------- THE generic superstep


def _apply_step(prog: VertexProgram, sub: SubgraphSet, combined: jax.Array, num_vertices: int):
    """Master-side post-combine step. "none" passes the combined value
    through; "pagerank" turns summed partials into damped, renormalized
    ranks at masters (mirrors zeroed until the broadcast)."""
    if prog.apply == "none":
        return combined
    p = combined.shape[0]
    base = (1.0 - prog.damping) / num_vertices
    new = jnp.where(sub.is_master, base + prog.damping * combined[:, : sub.max_v], 0.0)
    return jnp.concatenate([new, jnp.zeros((p, 1), jnp.float32)], axis=1)


def _superstep(
    prog: VertexProgram,
    sub: SubgraphSet,
    val,
    exchange,
    inner_cap: int,
    do_exchange: bool = True,
    count_ref=None,
    num_vertices: int = 0,
    backend: str = "xla",
    interpret: bool | None = None,
    block_e: int = 512,
):
    """ONE BSP superstep for ANY program. Returns
    (new_val, per-worker msg count, per-worker inner iters, L1 delta).

    Stages: local compute → mirror→master exchange + combine → apply →
    master→mirror broadcast. `count_ref` is the value snapshot of the LAST
    exchange — delta messages are counted against it (matters under bounded
    staleness). The L1 delta is only materialized for convergence='tol'
    programs (a zero scalar otherwise).
    """
    p = val.shape[0]
    start = val if count_ref is None else count_ref

    # 1. local compute. Fixpoint programs carry the value itself; sweep
    # programs carry the per-vertex partial aggregate (one sweep = one
    # inner iteration of comp work per worker).
    if prog.local == "fixpoint":
        state, iters = _local_fixpoint(prog, sub, val, inner_cap, backend, interpret, block_e)
    else:
        state = _local_sweep(prog, sub, val, backend, interpret, block_e)
        iters = jnp.ones((p,), jnp.int32)
    if not do_exchange:  # bounded-staleness local step (straggler mitigation)
        return state, jnp.zeros((p,), jnp.int32), iters, jnp.float32(0.0)

    # 2. mirror → master (forward): send current state of mirror slots.
    S = _gather_rows(state, sub.send_idx)  # [i, j, m]
    if prog.message_policy == "delta":
        changed = state != start
        ch_send = jnp.take_along_axis(changed, sub.send_idx.reshape(p, -1), axis=1).reshape(
            sub.send_idx.shape
        )
        msgs_fwd = jnp.sum(ch_send & sub.msg_mask, axis=(1, 2))
    else:
        msgs_fwd = jnp.sum(sub.msg_mask, axis=(1, 2))
    R = exchange(S)  # receiver-rowed [j, i, m]
    upd = jnp.where(sub.recv_mask, R, prog.identity)
    if prog.combine == "sum":
        combined = _scatter_add(state, sub.recv_idx, upd)
    else:
        combined = _scatter_min(state, sub.recv_idx, upd)

    # 3. apply at masters, then master → mirror (broadcast).
    new_val = _apply_step(prog, sub, combined, num_vertices)
    B = _gather_rows(new_val, sub.recv_idx)  # [j, i, m] master values
    if prog.message_policy == "delta":
        ch_master = new_val != start
        ch_b = jnp.take_along_axis(
            ch_master, sub.recv_idx.reshape(p, -1), axis=1
        ).reshape(sub.recv_idx.shape)
        msgs_bwd = jnp.sum(ch_b & sub.recv_mask, axis=(1, 2))
    else:
        msgs_bwd = jnp.sum(sub.recv_mask, axis=(1, 2))
    Rb = exchange(B)  # sender-rowed view at mirrors: [i, j, m]
    idx_masked = jnp.where(sub.msg_mask, sub.send_idx, sub.max_v)
    out = _scatter_set(new_val, idx_masked, Rb)

    if prog.convergence == "tol":
        delta = jnp.abs(out[:, : sub.max_v] - val[:, : sub.max_v]).sum()
    else:
        delta = jnp.float32(0.0)
    return out, msgs_fwd + msgs_bwd, iters, delta


def check_int32_kernel_gid(prog: VertexProgram, gid: jax.Array, compute_backend: str) -> None:
    """FLAT-addressing guard: refuse kernel backends for int32 programs
    whose global-id space reaches 2^24.

    The kernel path runs the int32 semiring in f32, which is only exact for
    magnitudes below 2^24 — larger values would merge distinct CC/REACH
    labels (or BFS hop counts) silently. Under flat addressing the kernel
    label domain IS the global id space, so `max(gid)` bounds every int32
    program's finite values: CC/REACH propagate the labels themselves, and
    BFS hop counts are below the covered-vertex count <= max(gid)+1.
    Two-level runs enforce at the VALUE boundary instead
    (`check_int32_kernel_values` via `_kernel_value_boundary`), which is
    what lets 2^24+-vertex graphs stay exact on ref/pallas.
    """
    check_compute_backend(compute_backend)
    if compute_backend != "xla" and prog.dtype == "int32":
        max_label = int(jnp.max(gid))
        if max_label >= 1 << 24:
            raise ValueError(
                f"compute_backend={compute_backend!r} runs int32 {prog.name} in f32, "
                f"exact only for vertex ids < 2^24; graph has id {max_label} — "
                "use compute_backend='xla'"
            )


def check_int32_kernel_values(prog: VertexProgram, bound, compute_backend: str) -> None:
    """TWO-LEVEL-addressing guard at the kernel VALUE boundary.

    `bound` is the run's proven ceiling on every finite kernel value's
    magnitude — the max over workers of per-worker local value maxima
    (label-domain programs: the rank-codec size; unit-weight programs:
    the covered-vertex count bounding hop growth). Same exactness rule
    as `check_int32_kernel_gid`, applied to what the kernels actually
    see instead of the global id space.
    """
    check_compute_backend(compute_backend)
    if compute_backend != "xla" and prog.dtype == "int32":
        bound = int(bound)
        if bound >= 1 << 24:
            raise ValueError(
                f"compute_backend={compute_backend!r} runs int32 {prog.name} in f32, "
                f"exact only for kernel values < 2^24; this run's per-worker value "
                f"bound is {bound} — use compute_backend='xla'"
            )


def check_int32_kernel_labels(prog: VertexProgram, sub: SubgraphSet, compute_backend: str) -> None:
    """Addressing-aware kernel-boundary guard over a SubgraphSet.

    Flat addressing keeps the legacy global-id guard. Two-level addressing
    defers to the value boundary (`_kernel_value_boundary` in the drivers):
    label-domain programs are rank-compressed below 2^24 there and the
    guard checks per-worker value maxima, so a >= 2^24-vertex graph passes
    clean where flat addressing must raise.
    """
    check_addressing(sub.addressing)
    if sub.addressing == "flat":
        check_int32_kernel_gid(prog, sub.gid, compute_backend)


def _label_domain(prog: VertexProgram) -> bool:
    """True for programs whose finite values form a CLOSED label set: the
    semiring only ever min/max-combines values already present at init
    (CC/REACH label propagation), never synthesizes new finite values.
    Exactly these programs admit lossless rank compression."""
    return (
        prog.dtype == "int32"
        and prog.weight == "none"
        and prog.apply == "none"
        and prog.local == "fixpoint"
        and prog.combine in ("min", "max")
    )


@dataclasses.dataclass(frozen=True)
class _ValueCodec:
    """Order-preserving bijection between a closed finite label set and
    dense int32 ranks [0, size), with the exec-domain INF_I32 sentinel
    fixed. min/max, delta message counts, and no-change convergence
    commute with any strictly monotone map, so a BSP run over encoded
    values is step-for-step identical to the raw run — while the kernels
    only ever see ranks < size <= covered vertices, far below 2^24 even
    when the labels themselves are 2^24+ global ids."""

    uniq: tuple  # sorted distinct finite exec-domain values (hashable)

    @classmethod
    def from_values(cls, values: np.ndarray) -> "_ValueCodec":
        v = np.asarray(values)
        finite = np.abs(v.astype(np.int64)) != int(INF_I32)
        return cls(uniq=tuple(np.unique(v[finite]).tolist()))

    @property
    def size(self) -> int:
        return len(self.uniq)

    def _table(self) -> jax.Array:
        return jnp.asarray(np.asarray(self.uniq, np.int32))

    def encode(self, val: jax.Array) -> jax.Array:
        finite = jnp.abs(val) != INF_I32
        ranks = jnp.searchsorted(self._table(), val).astype(jnp.int32)
        return jnp.where(finite, ranks, val)

    def decode(self, val: jax.Array) -> jax.Array:
        finite = jnp.abs(val) != INF_I32
        idx = jnp.clip(val, 0, max(self.size - 1, 0))
        return jnp.where(finite, self._table()[idx], val)


def _kernel_value_boundary(
    prog: VertexProgram, sub: SubgraphSet, val: jax.Array, compute_backend: str
) -> tuple[jax.Array, Optional[_ValueCodec]]:
    """Two-level enforcement where values cross into the kernels (exec
    domain, i.e. after any max→min negation). Returns (kernel-ready
    values, codec-or-None); callers decode driver output with the codec.

    label-domain programs → rank-compress (bound = codec size); unit-weight
    programs (BFS hops) → bound = current max + covered vertices; any other
    int32 program falls back to the conservative global-id guard (its value
    growth is unknown — use flat addressing if that guard is too strict).
    """
    if compute_backend == "xla" or prog.dtype != "int32" or sub.addressing == "flat":
        return val, None
    if _label_domain(prog):
        codec = _ValueCodec.from_values(np.asarray(val))
        check_int32_kernel_values(prog, max(codec.size - 1, 0), compute_backend)
        return codec.encode(val), codec
    if prog.weight == "unit":
        covered = int(np.asarray(sub.is_master).sum())
        vnp = np.abs(np.asarray(val).astype(np.int64))
        finite = vnp != int(INF_I32)
        base = int(vnp[finite].max()) if finite.any() else 0
        check_int32_kernel_values(prog, base + covered, compute_backend)
        return val, None
    check_int32_kernel_gid(prog, sub.gid, compute_backend)
    return val, None


# ------------------------------------------------------------ entry points


def _sim_exchange(S: jax.Array) -> jax.Array:
    return jnp.swapaxes(S, 0, 1)


@functools.partial(
    jax.jit,
    static_argnames=("prog", "inner_cap", "do_exchange", "num_vertices", "backend", "block_e"),
)
def _jit_superstep_sim(prog, sub, val, inner_cap, do_exchange, count_ref, num_vertices=0,
                       backend="xla", block_e=512):
    return _superstep(
        prog, sub, val, _sim_exchange, inner_cap, do_exchange, count_ref, num_vertices, backend,
        block_e=block_e,
    )


# ------------------------------------------------------- fused sim driver
#
# The host loop in `run_bsp` dispatches one device program per superstep and
# syncs after each one (np.asarray of the message counts, the convergence
# check). The fused driver runs the WHOLE BSP loop inside one jitted
# lax.while_loop: per-step stats land in preallocated [max_supersteps, p]
# on-device buffers, convergence exits the loop inside the trace, the value
# carry is donated, and the host syncs exactly once per run to fetch
# (steps, stats).


@functools.partial(
    jax.jit,
    static_argnames=("prog", "max_supersteps", "inner_cap", "exchange_period", "tol",
                     "num_vertices", "backend", "block_e"),
    donate_argnums=(1,),
)
def _fused_bsp(sub, val, *, prog, max_supersteps, inner_cap, exchange_period, tol,
               num_vertices, backend, block_e=512):
    # Kernel backends run int32 programs in f32. Hoist the INF_I32 <->
    # INF_F32 remap OUT of the superstep loop: remap once here, run the
    # whole loop on an f32 exec view of the program, remap once on exit.
    # The remap is a bijection on every occurring value, so values, message
    # counts, and convergence are bit-identical to the in-loop remap (and
    # the host driver, which still pays it per superstep in
    # `_local_fixpoint`). Pinned by test_fused_no_inloop_remap.
    to_f32 = backend != "xla" and prog.dtype == "int32"
    if to_f32:
        val = jnp.where(val == INF_I32, INF_F32, val.astype(jnp.float32))
        prog = dataclasses.replace(prog, dtype="float32")
    p = val.shape[0]
    msgs_buf = jnp.zeros((max_supersteps, p), jnp.int32)
    iters_buf = jnp.zeros((max_supersteps, p), jnp.int32)

    def converged_flag(v, v2, do_ex, delta):
        if prog.convergence == "tol":
            return (delta < tol) if tol else jnp.bool_(False)
        # Converged only when an exchange round produced no change anywhere
        # (identical to the host driver's break condition).
        return do_ex & ~jnp.any(v2 != v)

    def cond(carry):
        _, _, k, done, _, _ = carry
        return ~done & (k < max_supersteps)

    def body(carry):
        v, last_ex, k, _, msgs_buf, iters_buf = carry
        if exchange_period == 1:
            # Static specialization of the common case: every step exchanges,
            # so the trace needs no branch or last-exchange select.
            v2, msgs, iters, delta = _superstep(
                prog, sub, v, _sim_exchange, inner_cap, True, last_ex, num_vertices, backend,
                block_e=block_e,
            )
            converged = converged_flag(v, v2, jnp.bool_(True), delta)
            last_ex = v2
        else:
            do_ex = (k % exchange_period) == (exchange_period - 1)
            v2, msgs, iters, delta = jax.lax.cond(
                do_ex,
                lambda v_, le: _superstep(
                    prog, sub, v_, _sim_exchange, inner_cap, True, le, num_vertices, backend,
                    block_e=block_e,
                ),
                lambda v_, le: _superstep(
                    prog, sub, v_, _sim_exchange, inner_cap, False, le, num_vertices, backend,
                    block_e=block_e,
                ),
                v, last_ex,
            )
            converged = converged_flag(v, v2, do_ex, delta)
            last_ex = jnp.where(do_ex, v2, last_ex)
        return (v2, last_ex, k + 1, converged, msgs_buf.at[k].set(msgs), iters_buf.at[k].set(iters))

    carry = (val, val, jnp.int32(0), jnp.bool_(False), msgs_buf, iters_buf)
    val, _, steps, converged, msgs_buf, iters_buf = jax.lax.while_loop(cond, body, carry)
    if to_f32:
        val = jnp.where(val >= INF_F32, INF_I32, val.astype(jnp.int32))
    # Edge counts ride along so the stats assembly needs no extra dispatch.
    # The converged flag disambiguates "fixpoint reached on the last step"
    # from "step budget exhausted" — the checkpointed segment driver in
    # repro.resilience.bsp needs it to stop instead of launching a phantom
    # extra segment (which would append a superstep the uninterrupted run
    # never paid, breaking bit-parity of the stats).
    edges = jnp.sum(sub.edge_mask, axis=1, dtype=jnp.int32)
    return val, steps, converged, msgs_buf, iters_buf, edges


def _assemble_stats(steps: int, msgs_sw: np.ndarray, iters_sw: np.ndarray,
                    edges: np.ndarray) -> BSPStats:
    return BSPStats(
        supersteps=steps,
        messages_per_worker=msgs_sw.sum(axis=0),
        messages_per_step=msgs_sw.sum(axis=1),
        comp_work_per_worker=(iters_sw * edges[None, :]).sum(axis=0),
        inner_iters_per_step=iters_sw,
        messages_per_step_worker=msgs_sw,
    )


def check_pagerank_num_vertices(prog: VertexProgram, num_vertices: int) -> None:
    """pagerank-apply programs renormalize by the GLOBAL vertex count at
    trace time — fail with a named argument, not a ZeroDivisionError."""
    if prog.apply == "pagerank" and num_vertices <= 0:
        raise ValueError(
            f"program {prog.name!r} renormalizes by the global vertex count: "
            "pass num_vertices= (GraphPipeline supplies graph.num_vertices)"
        )


def run_bsp(
    sub: SubgraphSet,
    program,
    init_val: Optional[jax.Array] = None,
    *,
    max_supersteps: Optional[int] = None,
    inner_cap: int = 10_000,
    exchange_period: int = 1,
    tol: float = 0.0,
    num_vertices: int = 0,
    source=None,
    compute_backend: str = "xla",
    driver: str = "fused",
    block_e: int = 512,
    checkpoint_every: Optional[int] = None,
    ckpt_dir=None,
    fault_plan=None,
) -> tuple[jax.Array, BSPStats]:
    """THE simulation-mode driver: runs any `VertexProgram` (instance or
    registered name). exchange_period>1 = bounded staleness (fixpoint
    programs only).

    Fault tolerance (docs/api.md "Fault tolerance"): `checkpoint_every=k`
    with `ckpt_dir=` snapshots the value carry + per-step stats buffers
    every k supersteps through `repro.checkpoint.ckpt`, and `fault_plan=`
    (a `repro.resilience.FaultPlan`) injects a deterministic worker crash;
    `repro.resilience.resume_bsp` restores the last checkpoint and
    continues to a final state bit-identical to an uninterrupted run. Any
    of the three kwargs routes the run through the segmented driver in
    `repro.resilience.bsp` (same values and stats, pinned by
    tests/test_resilience.py).

    init_val defaults to the program's own `init_fn` (pass `source=` /
    `num_vertices=` as the program needs). max_supersteps=None takes the
    program's `default_steps` budget (PR: 20), else 200. compute_backend
    selects the
    local-compute implementation (see repro.api.config.COMPUTE_BACKENDS);
    all backends converge to the same fixpoint. driver="fused" runs the
    whole loop as one device program; driver="host" dispatches one
    superstep per Python iteration (identical values and stats —
    tests/test_drivers.py pins the equivalence). `tol` is the L1 step-delta
    convergence threshold for convergence='tol' programs (0 = run all
    max_supersteps, PageRank's fixed-iteration mode). `block_e` is the
    megakernel's edge-block size for kernel backends (VMEM streaming
    granularity — see docs/api.md "Performance guide"; ignored by "xla";
    values are bit-identical across block_e choices).

    driver="fused" DONATES the initial value buffer to the device program
    (that is where the fused loop's zero-copy value carry starts): on
    accelerators the caller's buffer is consumed, so build a fresh init per
    run (as repro.graph.algorithms does) rather than reusing one across
    calls.
    """
    if checkpoint_every is not None or ckpt_dir is not None or fault_plan is not None:
        # Deferred import: resilience builds on this module.
        from repro.resilience.bsp import run_bsp_resilient

        return run_bsp_resilient(
            sub, program, init_val,
            max_supersteps=max_supersteps, inner_cap=inner_cap,
            exchange_period=exchange_period, tol=tol, num_vertices=num_vertices,
            source=source, compute_backend=compute_backend, driver=driver,
            block_e=block_e,
            checkpoint_every=checkpoint_every, ckpt_dir=ckpt_dir, fault_plan=fault_plan,
        )
    prog = get_program(program)
    check_int32_kernel_labels(prog, sub, compute_backend)
    check_pagerank_num_vertices(prog, num_vertices)
    check_driver(driver)
    if max_supersteps is None:
        max_supersteps = prog.default_steps or 200
    if exchange_period > 1 and (prog.local != "fixpoint" or prog.convergence != "no_change"):
        raise ValueError(
            f"exchange_period>1 (bounded staleness) needs a fixpoint/no-change program; "
            f"{prog.name!r} is local={prog.local!r}, convergence={prog.convergence!r}"
        )
    if init_val is None:
        init_val = prog.init(sub, num_vertices=num_vertices, source=source)
    # Max-combine runs as min over negated values (kernel reuse); delta
    # message counts and no-change convergence are negation-invariant.
    exec_prog, negate = _exec_view(prog)
    val = -init_val if negate else init_val
    # Two-level runs rank-compress label-domain values here so the kernels
    # only ever see ranks < 2^24; codec=None means values pass raw.
    val, codec = _kernel_value_boundary(prog, sub, val, compute_backend)
    p = val.shape[0]

    if driver == "fused":
        val, steps, _, msgs_buf, iters_buf, edges = _fused_bsp(
            sub,
            val,
            prog=exec_prog,
            max_supersteps=max_supersteps,
            inner_cap=inner_cap,
            exchange_period=exchange_period,
            tol=tol,
            num_vertices=num_vertices,
            backend=compute_backend,
            block_e=block_e,
        )
        DISPATCH_COUNTS["fused"] += 1
        # The run's single host sync: one device_get for every stat buffer.
        steps, msgs_sw, iters_sw, edges = jax.device_get((steps, msgs_buf, iters_buf, edges))
        steps = int(steps)
        if codec is not None:
            val = codec.decode(val)
        return (-val if negate else val), _assemble_stats(
            steps,
            msgs_sw[:steps].astype(np.int64),
            iters_sw[:steps].astype(np.int64),
            edges.astype(np.int64),
        )

    msg_steps = []
    iters_steps = []
    edges = np.asarray(sub.edge_mask.sum(axis=1), np.int64)
    steps = 0
    last_exchanged = val
    for k in range(max_supersteps):
        do_exchange = (k % exchange_period) == exchange_period - 1
        before = val
        val, msgs, iters, delta = _jit_superstep_sim(
            exec_prog, sub, val, inner_cap, do_exchange, last_exchanged,
            num_vertices, compute_backend, block_e,
        )
        DISPATCH_COUNTS["host"] += 1
        if do_exchange:
            last_exchanged = val
        steps += 1
        msg_steps.append(np.asarray(msgs, np.int64))
        iters_steps.append(np.asarray(iters, np.int64))
        if prog.convergence == "tol":
            if tol and float(delta) < tol:
                break
        # Converged only when an exchange round produced no change anywhere.
        elif do_exchange and not bool(jnp.any(val != before)):
            break
    msgs_sw = np.asarray(msg_steps).reshape(steps, p)
    iters_sw = np.asarray(iters_steps).reshape(steps, p)
    if codec is not None:
        val = codec.decode(val)
    return (-val if negate else val), _assemble_stats(steps, msgs_sw, iters_sw, edges)


# ----------------------------------------------- batched fused sim driver
#
# The serving tier runs a [B] batch of point queries over SHARED subgraph
# structure in one fused dispatch: the generic superstep is vmapped over a
# leading batch axis and the whole loop is one jitted lax.while_loop. A
# per-query convergence mask freezes finished queries — their values stop
# evolving and they stop contributing messages/inner iterations — while
# stragglers run to their own fixpoint, so per-query BSPStats report the
# supersteps each query actually paid (not the batch max) and are
# bit-identical to B separate single-source `run_bsp` runs.


@functools.partial(
    jax.jit,
    static_argnames=("prog", "max_supersteps", "inner_cap", "tol", "num_vertices", "backend",
                     "block_e"),
    donate_argnums=(1,),
)
def _fused_bsp_batch(sub, vals, *, prog, max_supersteps, inner_cap, tol, num_vertices, backend,
                     block_e=512):
    # Same run-boundary hoist of the kernel path's int32<->f32 remap as
    # `_fused_bsp` (bijective, so per-query values/stats are unchanged).
    to_f32 = backend != "xla" and prog.dtype == "int32"
    if to_f32:
        vals = jnp.where(vals == INF_I32, INF_F32, vals.astype(jnp.float32))
        prog = dataclasses.replace(prog, dtype="float32")
    B = vals.shape[0]
    p = vals.shape[1]
    msgs_buf = jnp.zeros((max_supersteps, B, p), jnp.int32)
    iters_buf = jnp.zeros((max_supersteps, B, p), jnp.int32)
    # Every step exchanges (exchange_period=1), so the delta-message
    # reference is the entry value itself — count_ref=None, as in the
    # specialized period-1 branch of `_fused_bsp`.
    vstep = jax.vmap(
        lambda v: _superstep(
            prog, sub, v, _sim_exchange, inner_cap, True, None, num_vertices, backend,
            block_e=block_e,
        )
    )

    def cond(carry):
        _, k, done, _, _, _ = carry
        return ~jnp.all(done) & (k < max_supersteps)

    def body(carry):
        v, k, done, steps_q, msgs_buf, iters_buf = carry
        v2, msgs, iters, delta = vstep(v)
        if prog.convergence == "tol":
            newly = (delta < tol) if tol else jnp.zeros((B,), bool)
        else:
            newly = ~jnp.any(v2 != v, axis=(1, 2))
        # Convergence masking: finished queries keep their values and send
        # nothing while stragglers run.
        v2 = jnp.where(done[:, None, None], v, v2)
        msgs = jnp.where(done[:, None], 0, msgs)
        iters = jnp.where(done[:, None], 0, iters)
        steps_q = steps_q + (~done).astype(jnp.int32)
        done = done | newly
        return v2, k + 1, done, steps_q, msgs_buf.at[k].set(msgs), iters_buf.at[k].set(iters)

    carry = (vals, jnp.int32(0), jnp.zeros((B,), bool), jnp.zeros((B,), jnp.int32),
             msgs_buf, iters_buf)
    vals, _, _, steps_q, msgs_buf, iters_buf = jax.lax.while_loop(cond, body, carry)
    if to_f32:
        vals = jnp.where(vals >= INF_F32, INF_I32, vals.astype(jnp.int32))
    edges = jnp.sum(sub.edge_mask, axis=1, dtype=jnp.int32)
    return vals, steps_q, msgs_buf, iters_buf, edges


def batch_init(prog, sub: SubgraphSet, sources=None, *, batch: Optional[int] = None,
               num_vertices: int = 0) -> jax.Array:
    """[B, p, max_v+1] initial values for a batch of point queries.

    Source-rooted programs take `sources` (a [B] sequence of vertex ids),
    each validated BEFORE any init is built — one bad source fails fast
    with the offending id named instead of poisoning the whole batch.
    Source-free programs (CC/PR/reach: whole-graph queries) take `batch`
    (or infer it from len(sources)) and tile one init B times.
    """
    prog = get_program(prog)
    if prog.needs_source:
        if sources is None:
            raise ValueError(
                f"program {prog.name!r} is source-rooted: pass sources= (a [B] "
                "sequence of vertex ids)"
            )
        for s in sources:
            check_source(sub, s, num_vertices)
        return jnp.stack(
            [prog.init(sub, num_vertices=num_vertices, source=s) for s in sources]
        )
    if batch is None:
        batch = len(sources) if sources is not None else 0
    if batch < 1:
        raise ValueError(
            f"program {prog.name!r} is source-free: pass batch= (or sources= "
            "to size the batch)"
        )
    one = prog.init(sub, num_vertices=num_vertices)
    return jnp.tile(one[None], (int(batch), 1, 1))


def _assemble_batch_stats(steps_q, msgs_sbw, iters_sbw, edges) -> list:
    """Per-query BSPStats from the batched [S, B, p] buffers: query b's
    series is truncated to the supersteps IT paid under masking."""
    edges = edges.astype(np.int64)
    return [
        _assemble_stats(
            int(steps_q[b]),
            msgs_sbw[: int(steps_q[b]), b].astype(np.int64),
            iters_sbw[: int(steps_q[b]), b].astype(np.int64),
            edges,
        )
        for b in range(msgs_sbw.shape[1])
    ]


def _resolve_batch_args(sub, program, *, max_supersteps, num_vertices, compute_backend,
                        exchange_period=1):
    prog = get_program(program)
    check_int32_kernel_labels(prog, sub, compute_backend)
    check_pagerank_num_vertices(prog, num_vertices)
    if exchange_period != 1:
        raise ValueError(
            "the batched driver always exchanges every superstep; "
            f"exchange_period={exchange_period} is not supported — run staleness "
            "experiments through single-query run_bsp"
        )
    if max_supersteps is None:
        max_supersteps = prog.default_steps or 200
    return prog, max_supersteps


def run_bsp_batch(
    sub: SubgraphSet,
    program,
    sources=None,
    init_vals: Optional[jax.Array] = None,
    *,
    batch: Optional[int] = None,
    max_supersteps: Optional[int] = None,
    inner_cap: int = 10_000,
    exchange_period: int = 1,
    tol: float = 0.0,
    num_vertices: int = 0,
    compute_backend: str = "xla",
    block_e: int = 512,
) -> tuple[jax.Array, list]:
    """Batched multi-source BSP: B queries of one program in ONE fused
    dispatch over shared subgraph structure.

    Returns (values [B, p, max_v+1], per-query BSPStats list) — each query's
    values AND stats are bit-identical to a single-source `run_bsp` call
    (tests/test_serve.py pins this across programs × backends). Like the
    single-query fused driver, the initial value buffer is DONATED.
    """
    prog, max_supersteps = _resolve_batch_args(
        sub, program, max_supersteps=max_supersteps, num_vertices=num_vertices,
        compute_backend=compute_backend, exchange_period=exchange_period,
    )
    if init_vals is None:
        init_vals = batch_init(prog, sub, sources, batch=batch, num_vertices=num_vertices)
    exec_prog, negate = _exec_view(prog)
    vals = -init_vals if negate else init_vals
    # One codec across the batch: the union of every query's finite values
    # (source-free programs tile one init, so this matches the per-query
    # codec exactly; ranks stay < covered either way).
    vals, codec = _kernel_value_boundary(prog, sub, vals, compute_backend)
    vals, steps_q, msgs_buf, iters_buf, edges = _fused_bsp_batch(
        sub, vals, prog=exec_prog, max_supersteps=max_supersteps, inner_cap=inner_cap,
        tol=tol, num_vertices=num_vertices, backend=compute_backend, block_e=block_e,
    )
    DISPATCH_COUNTS["batch"] += 1
    steps_q, msgs_sbw, iters_sbw, edges = jax.device_get((steps_q, msgs_buf, iters_buf, edges))
    if codec is not None:
        vals = codec.decode(vals)
    return (-vals if negate else vals), _assemble_batch_stats(steps_q, msgs_sbw, iters_sbw, edges)


@dataclasses.dataclass
class BatchExecutable:
    """AOT-compiled batched BSP loop for one (program, padded batch size).

    The serving tier's executable-cache value: `compile_batch_executable`
    lowers `_fused_bsp_batch` once for a fixed [B, p, max_v+1] value shape,
    and `run` replays it with zero retracing — steady-state queries never
    recompile. Negation (max-combine programs) and per-query stats assembly
    live in the wrapper, outside the compiled program.
    """

    program: VertexProgram
    sub: SubgraphSet
    batch: int
    negate: bool
    compiled: object
    compile_s: float
    compute_backend: str = "xla"

    def run(self, init_vals: jax.Array) -> tuple[jax.Array, list]:
        """Same contract as `run_bsp_batch` (init_vals is donated)."""
        if init_vals.shape[0] != self.batch:
            raise ValueError(
                f"executable compiled for batch {self.batch}, got {init_vals.shape[0]} "
                "— pad the batch to its bucket first"
            )
        vals = -init_vals if self.negate else init_vals
        # Per-call value boundary: the compiled program is shape-keyed, not
        # value-keyed, so each batch brings its own codec (a host-side
        # unique + searchsorted — no retrace, the dtype stays int32).
        vals, codec = _kernel_value_boundary(
            self.program, self.sub, vals, self.compute_backend
        )
        vals, steps_q, msgs_buf, iters_buf, edges = self.compiled(self.sub, vals)
        DISPATCH_COUNTS["batch"] += 1
        steps_q, msgs_sbw, iters_sbw, edges = jax.device_get(
            (steps_q, msgs_buf, iters_buf, edges)
        )
        if codec is not None:
            vals = codec.decode(vals)
        return (
            -vals if self.negate else vals
        ), _assemble_batch_stats(steps_q, msgs_sbw, iters_sbw, edges)


def compile_batch_executable(
    sub: SubgraphSet,
    program,
    batch: int,
    *,
    max_supersteps: Optional[int] = None,
    inner_cap: int = 10_000,
    tol: float = 0.0,
    num_vertices: int = 0,
    compute_backend: str = "xla",
    block_e: int = 512,
) -> BatchExecutable:
    """AOT-lower + compile the batched fused BSP loop for a fixed padded
    batch size (the warm path behind `repro.serve`'s executable cache)."""
    prog, max_supersteps = _resolve_batch_args(
        sub, program, max_supersteps=max_supersteps, num_vertices=num_vertices,
        compute_backend=compute_backend,
    )
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    exec_prog, negate = _exec_view(prog)
    dt = jnp.int32 if prog.dtype == "int32" else jnp.float32
    spec = jax.ShapeDtypeStruct((int(batch), sub.num_parts, sub.max_v + 1), dt)
    t0 = time.perf_counter()
    compiled = _fused_bsp_batch.lower(
        sub, spec, prog=exec_prog, max_supersteps=max_supersteps, inner_cap=inner_cap,
        tol=tol, num_vertices=num_vertices, backend=compute_backend, block_e=block_e,
    ).compile()
    return BatchExecutable(
        program=prog, sub=sub, batch=int(batch), negate=negate, compiled=compiled,
        compile_s=time.perf_counter() - t0, compute_backend=compute_backend,
    )


# ------------------------------------------------- distributed (shard_map)


_ARRAY_FIELDS = [
    "lsrc", "ldst", "weight", "edge_mask",
    "lsrc_s", "ldst_s", "weight_s", "edge_mask_s",
    "gid", "vmask", "is_master", "out_degree",
    "send_idx", "recv_idx", "msg_mask", "recv_mask",
]
_STATIC_FIELDS = ["num_parts", "max_v", "max_e", "max_msg", "addressing"]


def subgraphs_to_arrays(sub: SubgraphSet) -> tuple[dict, dict]:
    arrays = {k: getattr(sub, k) for k in _ARRAY_FIELDS}
    statics = {k: getattr(sub, k) for k in _STATIC_FIELDS}
    return arrays, statics


def make_distributed_stepper(
    mesh,
    axes,
    prog,
    statics: dict,
    *,
    num_supersteps: int,
    inner_cap: int,
    tol: float = 0.0,
    num_vertices: int = 0,
    compute_backend: str = "xla",
    block_e: int = 512,
    fault_plan=None,
):
    """Builds a shard_map'd BSP runner for ANY `VertexProgram`: subgraphs
    sharded 1:1 over `axes`.

    `fault_plan=` (a `repro.resilience.FaultPlan` with
    `crash_at_superstep=s`) injects a deterministic worker crash: the
    step loop is capped at s supersteps and the runner raises
    `WorkerCrashError` if the loop was still running when the cap hit
    (a run that converges in fewer than s supersteps completes — there
    is no superstep s to die in).

    `axes` may be a single mesh axis name or a tuple (e.g. ("pod","data",
    "model")) whose sizes multiply to the number of subgraphs — this is what
    the multi-pod dry-run lowers: p=512 subgraphs over (pod, data, model).
    Takes the subgraph tensors as a dict (see `subgraphs_to_arrays`) so the
    sharding specs form a clean pytree.

    Like the fused sim driver, the step loop is a lax.while_loop that exits
    on GLOBAL convergence — for no-change programs a psum'd change flag, for
    tol programs the psum'd L1 step delta against `tol` — and records
    per-step message/inner-iteration stats in [num_supersteps, local] device
    buffers. Callers always work in the program's true value domain:
    max-combine programs are negated in and out here. Returns
    (val, msgs_total, steps, msgs_per_step, iters_per_step).
    """
    prog = get_program(prog)
    check_compute_backend(compute_backend)
    check_pagerank_num_vertices(prog, num_vertices)
    crash_at = None
    if fault_plan is not None and fault_plan.crash_at_superstep is not None:
        crash_at = int(fault_plan.crash_at_superstep)
        if crash_at < num_supersteps:
            num_supersteps = crash_at  # the doomed superstep never completes
    # Pallas interpret vs compiled is keyed off the MESH platform, not the
    # host process backend: AOT-lowering for a TPU mesh from a CPU host must
    # bake in the compiled kernel, not the interpreter.
    try:
        mesh_platform = mesh.devices.reshape(-1)[0].platform
    except AttributeError:  # abstract/mock meshes: fall back to the host sniff
        mesh_platform = None
    interpret = None if mesh_platform is None else mesh_platform != "tpu"
    exec_prog, negate = _exec_view(prog)
    # Same run-boundary hoist as the fused sim drivers: kernel backends run
    # int32 programs in f32, remapped once per run inside the shard_map'd
    # loop (per shard), not once per superstep.
    to_f32 = compute_backend != "xla" and prog.dtype == "int32"
    if to_f32:
        exec_prog = dataclasses.replace(exec_prog, dtype="float32")
    axis_tuple = axes if isinstance(axes, tuple) else (axes,)
    spec3 = P(axis_tuple, None, None)
    spec2 = P(axis_tuple, None)
    in_specs = ({k: (spec3 if k in ("send_idx", "recv_idx", "msg_mask", "recv_mask") else spec2) for k in _ARRAY_FIELDS}, spec2)

    def a2a_exchange(S):  # S: [1, p, m] per device
        out = jax.lax.all_to_all(S, axis_tuple, split_axis=1, concat_axis=0, tiled=False)
        # out: [p, 1, m] → receiver-rowed [1, p, m]
        return jnp.swapaxes(out, 0, 1)

    def stepper(arrays: dict, val: jax.Array):
        sub = SubgraphSet(**arrays, **statics)
        if to_f32:
            val = jnp.where(val == INF_I32, INF_F32, val.astype(jnp.float32))
        nloc = val.shape[0]  # subgraphs per device (1 on a fully sharded mesh)
        msgs_buf = jnp.zeros((num_supersteps, nloc), jnp.int32)
        iters_buf = jnp.zeros((num_supersteps, nloc), jnp.int32)

        def cond(carry):
            _, k, done, _, _ = carry
            return ~done & (k < num_supersteps)

        def body(carry):
            v, k, _, msgs_buf, iters_buf = carry
            v2, m, it, delta = _superstep(
                exec_prog, sub, v, a2a_exchange, inner_cap,
                num_vertices=num_vertices, backend=compute_backend, interpret=interpret,
                block_e=block_e,
            )
            # Convergence is global: psum the per-device signal so every
            # device takes the same trip count (collectives stay uniform).
            if prog.convergence == "tol":
                gdelta = jax.lax.psum(delta, axis_tuple)
                done = (gdelta < tol) if tol else jnp.bool_(False)
            else:
                changed = jax.lax.psum(jnp.any(v2 != v).astype(jnp.int32), axis_tuple)
                done = changed == 0
            return v2, k + 1, done, msgs_buf.at[k].set(m), iters_buf.at[k].set(it)

        val_out, steps, _, msgs_buf, iters_buf = jax.lax.while_loop(
            cond, body, (val, jnp.int32(0), jnp.bool_(False), msgs_buf, iters_buf)
        )
        if to_f32:
            val_out = jnp.where(val_out >= INF_F32, INF_I32, val_out.astype(jnp.int32))
        return val_out, msgs_buf.sum(axis=0), steps, msgs_buf, iters_buf

    sharded = shard_map_compat(
        stepper,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(spec2, P(axis_tuple), P(), P(None, axis_tuple), P(None, axis_tuple)),
    )

    addressing = statics.get("addressing", "two_level")

    def runner(arrays: dict, val: jax.Array):
        # Same 2^24 exactness guard as run_bsp/_resolve_batch_args: an
        # inexact run must raise BEFORE any int->f32 remap. Flat addressing
        # bounds values by the global-id space; two-level checks the
        # per-worker VALUE maxima of the incoming carry (label-domain
        # callers encode to ranks first — GraphPipeline._run_distributed
        # does — so big global labels pass as small ranks, and a raw
        # unencoded 2^24+ label still raises). Under jit/AOT tracing the
        # arrays are abstract and the guard cannot run here — those paths
        # pre-check the concrete SubgraphSet before tracing.
        try:
            if addressing == "flat":
                check_int32_kernel_gid(prog, arrays["gid"], compute_backend)
            elif compute_backend != "xla" and prog.dtype == "int32":
                mag = jnp.abs(val)
                finite = mag != INF_I32
                bound = int(jnp.max(jnp.where(finite, mag, 0)))
                if prog.weight == "unit":
                    bound += int(jnp.sum(arrays["is_master"]))
                check_int32_kernel_values(prog, bound, compute_backend)
        except jax.errors.JAXTypeError:
            pass
        out, msgs, steps, msgs_b, iters_b = sharded(arrays, -val if negate else val)
        if negate:
            out = -out
        if crash_at is not None and int(steps) >= crash_at:
            # The loop was still running when the doomed superstep came due.
            from repro.resilience.faults import WorkerCrashError

            raise WorkerCrashError(superstep=crash_at)
        return out, msgs, steps, msgs_b, iters_b

    return runner
