"""repro.graph — subgraph-centric BSP substrate."""
from repro.graph.build import SubgraphSet, build_subgraphs
from repro.graph.engine import (
    BFS,
    CC,
    PR,
    REACH,
    SSSP,
    BSPStats,
    VertexProgram,
    get_program,
    program_names,
    register_program,
    run_bsp,
)
