"""repro.graph — subgraph-centric BSP substrate."""
from repro.graph.build import SubgraphSet, build_subgraphs
from repro.graph.engine import BSPStats, CC, SSSP, run_min_bsp, run_pagerank
