"""The benchmark algorithms on the BSP engine + numpy host oracles.

Every algorithm is a `VertexProgram` executed by the ONE generic engine
driver (`repro.graph.engine.run_bsp`); the named wrappers below just fix
the program and unwrap the dump slot. `run_program` accepts any program —
a registered name or a custom `VertexProgram` instance.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.types import Graph
from repro.graph.build import SubgraphSet
from repro.graph.engine import BFS, CC, PR, REACH, SSSP, BSPStats, run_bsp

_I32_INF = np.int64(2**31 - 1)


def run_program(
    sub: SubgraphSet, program, *, num_vertices: int = 0, source=None, **kw
) -> tuple[np.ndarray, BSPStats]:
    """Run any `VertexProgram` (instance or registered name) and return
    values indexed by (part, local) with the dump slot stripped."""
    val, stats = run_bsp(sub, program, num_vertices=num_vertices, source=source, **kw)
    return np.asarray(val[:, :-1]), stats


def connected_components(sub: SubgraphSet, **kw) -> tuple[np.ndarray, BSPStats]:
    """Min-label propagation CC. Returns labels indexed by (part, local)."""
    return run_program(sub, CC, **kw)


def sssp(sub: SubgraphSet, source: int, **kw) -> tuple[np.ndarray, BSPStats]:
    return run_program(sub, SSSP, source=source, **kw)


def bfs(sub: SubgraphSet, source: int, **kw) -> tuple[np.ndarray, BSPStats]:
    """Hop counts from `source` (min-plus over unit weights, int32)."""
    return run_program(sub, BFS, source=source, **kw)


def reachability(sub: SubgraphSet, **kw) -> tuple[np.ndarray, BSPStats]:
    """Max-label propagation: every vertex converges to the largest vertex
    id reachable from it over the undirected view (max-combine program,
    executed on the min-plus kernels via negation)."""
    return run_program(sub, REACH, **kw)


def pagerank(
    sub: SubgraphSet,
    num_vertices: int,
    *,
    damping: float = 0.85,
    num_iters: int = 20,
    tol: float = 0.0,
    **kw,
) -> tuple[np.ndarray, BSPStats]:
    prog = PR if damping == PR.damping else dataclasses.replace(PR, damping=float(damping))
    return run_program(
        sub, prog, num_vertices=num_vertices, max_supersteps=num_iters, tol=tol, **kw
    )


# ------------------------------------------------------------ host oracles


def cc_reference(graph: Graph) -> np.ndarray:
    """Min-label CC on the undirected view (numpy label propagation)."""
    src = np.asarray(graph.src, np.int64)
    dst = np.asarray(graph.dst, np.int64)
    labels = np.arange(graph.num_vertices, dtype=np.int64)
    while True:
        a = np.minimum.reduce([labels[src], labels[dst]])
        new = labels.copy()
        np.minimum.at(new, src, a)
        np.minimum.at(new, dst, a)
        if np.array_equal(new, labels):
            return labels
        labels = new


def sssp_reference(graph: Graph, source: int, weights: np.ndarray | None = None) -> np.ndarray:
    """Bellman-Ford (numpy, directed)."""
    src = np.asarray(graph.src, np.int64)
    dst = np.asarray(graph.dst, np.int64)
    w = np.ones(src.shape[0], np.float64) if weights is None else weights.astype(np.float64)
    dist = np.full(graph.num_vertices, np.inf)
    dist[source] = 0.0
    while True:
        cand = dist[src] + w
        new = dist.copy()
        np.minimum.at(new, dst, cand)
        if np.array_equal(new, dist, equal_nan=True) or np.allclose(new, dist, equal_nan=True):
            return dist
        dist = new


def bfs_reference(graph: Graph, source: int) -> np.ndarray:
    """Hop counts from `source` over DIRECTED edges (numpy relaxation).
    Unreachable vertices hold INF_I32 (the engine's int32 infinity)."""
    src = np.asarray(graph.src, np.int64)
    dst = np.asarray(graph.dst, np.int64)
    dist = np.full(graph.num_vertices, _I32_INF, np.int64)
    dist[source] = 0
    while True:
        cand = np.where(dist[src] < _I32_INF, dist[src] + 1, _I32_INF)
        new = dist.copy()
        np.minimum.at(new, dst, cand)
        if np.array_equal(new, dist):
            return dist
        dist = new


def reachability_reference(graph: Graph) -> np.ndarray:
    """Max-label propagation on the undirected view (numpy)."""
    src = np.asarray(graph.src, np.int64)
    dst = np.asarray(graph.dst, np.int64)
    labels = np.arange(graph.num_vertices, dtype=np.int64)
    while True:
        a = np.maximum.reduce([labels[src], labels[dst]])
        new = labels.copy()
        np.maximum.at(new, src, a)
        np.maximum.at(new, dst, a)
        if np.array_equal(new, labels):
            return labels
        labels = new


def pagerank_reference(graph: Graph, *, damping: float = 0.85, num_iters: int = 20) -> np.ndarray:
    src = np.asarray(graph.src, np.int64)
    dst = np.asarray(graph.dst, np.int64)
    N = graph.num_vertices
    outdeg = np.bincount(src, minlength=N).astype(np.float64)
    rank = np.full(N, 1.0 / N)
    for _ in range(num_iters):
        share = np.where(outdeg > 0, rank / np.maximum(outdeg, 1), 0.0)
        agg = np.zeros(N)
        np.add.at(agg, dst, share[src])
        rank = (1 - damping) / N + damping * agg
    return rank


def scatter_to_global(sub: SubgraphSet, local_vals: np.ndarray, num_vertices: int, reduce: str = "min") -> np.ndarray:
    """Collect per-(part, local) values into a global array via masters."""
    gid = np.asarray(sub.gid)
    is_m = np.asarray(sub.is_master)
    out = np.full(num_vertices, np.inf if reduce == "min" else 0.0)
    sel = is_m & (gid >= 0)
    out[gid[sel]] = local_vals[sel]
    return out
