"""The paper's three benchmark algorithms on the BSP engine + host oracles."""
from __future__ import annotations

import numpy as np

from repro.core.types import Graph, PartitionResult
from repro.graph.build import SubgraphSet, build_subgraphs
from repro.graph.engine import (
    CC,
    SSSP,
    BSPStats,
    init_cc,
    init_sssp,
    run_min_bsp,
    run_pagerank,
)


def connected_components(
    sub: SubgraphSet, **kw
) -> tuple[np.ndarray, BSPStats]:
    """Min-label propagation CC. Returns labels indexed by (part, local)."""
    val, stats = run_min_bsp(sub, CC, init_cc(sub), **kw)
    return np.asarray(val[:, :-1]), stats


def sssp(sub: SubgraphSet, source: int, **kw) -> tuple[np.ndarray, BSPStats]:
    val, stats = run_min_bsp(sub, SSSP, init_sssp(sub, source), **kw)
    return np.asarray(val[:, :-1]), stats


def pagerank(sub: SubgraphSet, num_vertices: int, **kw) -> tuple[np.ndarray, BSPStats]:
    val, stats = run_pagerank(sub, num_vertices, **kw)
    return np.asarray(val[:, :-1]), stats


# ------------------------------------------------------------ host oracles


def cc_reference(graph: Graph) -> np.ndarray:
    """Min-label CC on the undirected view (numpy label propagation)."""
    src = np.asarray(graph.src, np.int64)
    dst = np.asarray(graph.dst, np.int64)
    labels = np.arange(graph.num_vertices, dtype=np.int64)
    while True:
        a = np.minimum.reduce([labels[src], labels[dst]])
        new = labels.copy()
        np.minimum.at(new, src, a)
        np.minimum.at(new, dst, a)
        if np.array_equal(new, labels):
            return labels
        labels = new


def sssp_reference(graph: Graph, source: int, weights: np.ndarray | None = None) -> np.ndarray:
    """Bellman-Ford (numpy, directed)."""
    src = np.asarray(graph.src, np.int64)
    dst = np.asarray(graph.dst, np.int64)
    w = np.ones(src.shape[0], np.float64) if weights is None else weights.astype(np.float64)
    dist = np.full(graph.num_vertices, np.inf)
    dist[source] = 0.0
    while True:
        cand = dist[src] + w
        new = dist.copy()
        np.minimum.at(new, dst, cand)
        if np.array_equal(new, dist, equal_nan=True) or np.allclose(new, dist, equal_nan=True):
            return dist
        dist = new


def pagerank_reference(graph: Graph, *, damping: float = 0.85, num_iters: int = 20) -> np.ndarray:
    src = np.asarray(graph.src, np.int64)
    dst = np.asarray(graph.dst, np.int64)
    N = graph.num_vertices
    outdeg = np.bincount(src, minlength=N).astype(np.float64)
    rank = np.full(N, 1.0 / N)
    for _ in range(num_iters):
        share = np.where(outdeg > 0, rank / np.maximum(outdeg, 1), 0.0)
        agg = np.zeros(N)
        np.add.at(agg, dst, share[src])
        rank = (1 - damping) / N + damping * agg
    return rank


def scatter_to_global(sub: SubgraphSet, local_vals: np.ndarray, num_vertices: int, reduce: str = "min") -> np.ndarray:
    """Collect per-(part, local) values into a global array via masters."""
    gid = np.asarray(sub.gid)
    is_m = np.asarray(sub.is_master)
    out = np.full(num_vertices, np.inf if reduce == "min" else 0.0)
    sel = is_m & (gid >= 0)
    out[gid[sel]] = local_vals[sel]
    return out


def partition_and_build(
    graph: Graph,
    partitioner,
    num_parts: int,
    *,
    symmetrize: bool = False,
    **kw,
) -> tuple[PartitionResult, SubgraphSet]:
    """DEPRECATED glue — prefer `repro.api.GraphPipeline`, which caches the
    partition/build stages and owns the engine/metrics lifecycle."""
    result = partitioner(graph, num_parts, **kw)
    return result, build_subgraphs(graph, result, symmetrize=symmetrize)
