"""Synthetic graph generators (offline stand-ins for the paper's datasets).

The paper uses LiveJournal/Twitter/Friendster (power-law, eta 1.9-2.6) and
USARoad (non-power-law, eta 6.3). We generate:
  - rmat(...)      : R-MAT power-law graph; a/b/c/d control skew (eta).
  - barabasi(...)  : Barabasi-Albert preferential attachment.
  - road_grid(...) : 2D lattice with diagonal shortcuts — USARoad analogue
                     (near-uniform degree ~2.4-4, giant diameter).
All generators return directed Graphs without self loops, deduplicated.
"""
from __future__ import annotations

import numpy as np

from repro.core.types import Graph


def _finalize(src, dst, V) -> Graph:
    m = src != dst
    src, dst = src[m], dst[m]
    key = src.astype(np.int64) * V + dst
    key = np.unique(key)
    src = (key // V).astype(np.int32)
    dst = (key % V).astype(np.int32)
    return Graph(src=src, dst=dst, num_vertices=V)


def _rmat_bitplane(src, dst, r, a: float, b: float, c: float):
    """One R-MAT recursion level: descend every edge one quadrant using a
    single uniform draw per edge. Shared by the in-memory generator
    (plane-major draws) and the sharded writer in `repro.data.edgeshards`
    (chunk-major draws)."""
    ab, abc = a + b, a + b + c
    src = src * 2 + (r >= ab)
    dst = dst * 2 + ((r >= a) & (r < ab)) + (r >= abc)
    return src, dst


def rmat(
    num_vertices: int,
    num_edges: int,
    *,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
) -> Graph:
    """R-MAT generator. Defaults (.57,.19,.19,.05) give Twitter-like skew."""
    assert num_vertices & (num_vertices - 1) == 0, "num_vertices must be a power of 2"
    scale = int(np.log2(num_vertices))
    rng = np.random.default_rng(seed)
    n = int(num_edges * 1.15)  # oversample to survive dedup
    src = np.zeros(n, dtype=np.int64)
    dst = np.zeros(n, dtype=np.int64)
    for _ in range(scale):
        src, dst = _rmat_bitplane(src, dst, rng.random(n), a, b, c)
    g = _finalize(src, dst, num_vertices)
    if g.num_edges > num_edges:
        idx = rng.choice(g.num_edges, size=num_edges, replace=False)
        idx.sort()
        g = Graph(src=np.asarray(g.src)[idx], dst=np.asarray(g.dst)[idx], num_vertices=num_vertices)
    return g


def barabasi(num_vertices: int, attach: int = 8, *, seed: int = 0) -> Graph:
    """Barabasi-Albert preferential attachment (eta ~= 3).

    Vectorized: the legacy sampler appended every edge to a Python list and
    materialized the O(attach * V) `repeated` multiset just to index into it.
    The multiset has a closed form — index i lives in block b = i // (2*attach)
    with offset o = i % (2*attach); offsets < attach are that block's targets
    row, offsets >= attach are the block's new vertex (attach + b) — so each
    draw resolves with one gather into the (block, attach) targets table.
    The per-iteration `rng.integers(0, len, attach)` call sequence is kept
    verbatim, so the bit stream — and hence the graph — is identical to
    `barabasi_legacy` for a fixed seed (pinned in tests)."""
    rng = np.random.default_rng(seed)
    blocks = num_vertices - attach
    if blocks <= 0:
        return _finalize(np.zeros(0, np.int64), np.zeros(0, np.int64), num_vertices)
    two_a = 2 * attach
    idx = np.empty((blocks, attach), np.int64)
    idx[0] = np.arange(attach)  # unused; block 0's targets are fixed below
    for b in range(1, blocks):
        idx[b] = rng.integers(0, two_a * b, attach)
    blk, off = idx // two_a, idx % two_a
    # Entry e = b*attach + j is tg[b, j]. off >= attach resolves immediately
    # to the block's new vertex; off < attach chains to an entry in an
    # earlier block. Chains hop to uniformly-random earlier blocks, so
    # pointer jumping resolves the whole forest in O(log depth) passes.
    val = np.where(off >= attach, attach + blk, 0).ravel()
    known = (off >= attach).ravel()
    ee = np.arange(blocks * attach, dtype=np.int64)
    parent = np.where(known, ee, (blk * attach + off).ravel())
    val[:attach] = np.arange(attach)
    known[:attach] = True
    parent[:attach] = ee[:attach]
    while not known.all():
        val = np.where(known, val, val[parent])
        known = known | known[parent]
        parent = parent[parent]
    src = np.repeat(np.arange(attach, num_vertices, dtype=np.int64), attach)
    return _finalize(src, val, num_vertices)


def barabasi_legacy(num_vertices: int, attach: int = 8, *, seed: int = 0) -> Graph:
    """Original per-edge Python-list sampler; golden oracle for `barabasi`."""
    rng = np.random.default_rng(seed)
    targets = list(range(attach))
    repeated: list[int] = []
    src_l: list[int] = []
    dst_l: list[int] = []
    for v in range(attach, num_vertices):
        for t in targets:
            src_l.append(v)
            dst_l.append(t)
        repeated.extend(targets)
        repeated.extend([v] * attach)
        targets = [repeated[i] for i in rng.integers(0, len(repeated), attach)]
    return _finalize(np.asarray(src_l, np.int64), np.asarray(dst_l, np.int64), num_vertices)


def road_grid(side: int, *, diag_prob: float = 0.1, seed: int = 0) -> Graph:
    """2D lattice (side x side) + sparse diagonals; undirected (both dirs)."""
    rng = np.random.default_rng(seed)
    V = side * side
    ii, jj = np.meshgrid(np.arange(side), np.arange(side), indexing="ij")
    vid = (ii * side + jj).ravel()
    right = vid[(jj < side - 1).ravel()]
    down = vid[(ii < side - 1).ravel()]
    edges = [
        (right, right + 1),
        (down, down + side),
    ]
    diag = vid[((ii < side - 1) & (jj < side - 1)).ravel()]
    keep = rng.random(diag.shape[0]) < diag_prob
    edges.append((diag[keep], diag[keep] + side + 1))
    src = np.concatenate([e[0] for e in edges])
    dst = np.concatenate([e[1] for e in edges])
    # Both directions (paper treats undirected graphs as two directed edges).
    return _finalize(
        np.concatenate([src, dst]).astype(np.int64),
        np.concatenate([dst, src]).astype(np.int64),
        V,
    )


def estimate_eta(graph: Graph) -> float:
    """Log-binned least-squares slope of the degree distribution (paper eq. 1).

    Log-binning avoids the flat single-count tail that biases a naive fit.
    """
    deg = graph.degrees()
    deg = deg[deg > 0].astype(np.float64)
    if np.unique(deg).shape[0] < 8:
        return float("nan")  # near-uniform degrees: not a power law
    bins = np.logspace(0, np.log10(deg.max() + 1), 24)
    hist, edges = np.histogram(deg, bins=bins)
    widths = np.diff(edges)
    centers = np.sqrt(edges[:-1] * edges[1:])
    density = hist / (widths * deg.shape[0])
    m = density > 0
    slope = np.polyfit(np.log(centers[m]), np.log(density[m]), 1)[0]
    return float(-slope)


REGISTRY = {
    # name: (factory, kwargs) — sized for CPU-scale experiments; the paper's
    # graphs are listed in DESIGN.md with the mapping.
    "livejournal_like": (rmat, dict(num_vertices=1 << 17, num_edges=1 << 21, a=0.57, b=0.19, c=0.19)),
    "twitter_like": (rmat, dict(num_vertices=1 << 17, num_edges=1 << 21, a=0.65, b=0.15, c=0.15)),
    "friendster_like": (rmat, dict(num_vertices=1 << 18, num_edges=1 << 22, a=0.55, b=0.19, c=0.19)),
    "road_like": (road_grid, dict(side=512)),
    "tiny_powerlaw": (rmat, dict(num_vertices=1 << 10, num_edges=1 << 13)),
    "tiny_road": (road_grid, dict(side=32)),
}


def make_graph(name: str, **overrides) -> Graph:
    fn, kw = REGISTRY[name]
    kw = dict(kw, **overrides)
    return fn(**kw)
