"""Pallas TPU megakernel: one whole BSP local-compute stage per worker.

The per-superstep hot loop of the subgraph-centric engine used to be a
chain of separate XLA ops per relaxation pass — gather, segment-combine,
elementwise min — each round-tripping the [p, max_v+1] value state through
HBM, once per inner iteration per superstep. This kernel runs the ENTIRE
local-compute stage of a superstep for one worker in a single launch:

  - the worker's vertex values live in a VMEM accumulator for the whole
    stage (EBG's vertex balance bounds max_v, i.e. this kernel's VMEM
    footprint — the paper's balance objective is what makes the values
    fit);
  - CSR edge blocks (src, dst, weight) stream from HBM through
    double-buffered VMEM DMA — block b+1's copy is in flight while block
    b is reduced, so the edge stream never stalls the VPU;
  - each block is rank-compressed (dst-sorted runs -> boundary cumsum)
    and reduced with the same rank-onehot partial trick as
    `segment_reduce`, committed into the VMEM accumulator;
  - min-fixpoint programs (CC/SSSP/BFS/negated reach) iterate passes to
    LOCAL convergence inside the kernel: the per-worker convergence flag
    is fused (a VMEM compare of the pass's before/after values), and the
    per-worker inner-iteration count is the kernel's second output;
  - sweep programs (PageRank) fuse the out-degree share division
    (`val/outdeg` at the gather) and run one accumulation pass.

Values touch HBM exactly once per superstep: the initial DMA in (via the
value BlockSpec) and the final write of the converged state. Grid = one
step per worker; the sequential TPU grid keeps each worker's edge stream
private to its accumulator.

Bit-parity contract: identical values AND inner-iteration counts to the
batched XLA while-loop in `repro.graph.engine._local_fixpoint` (the
change-passes of a monotone relax form a prefix, so the per-worker loop
here and the any-worker batched loop there agree on both values and
iteration counts — pinned by tests/test_megakernel.py and the driver
parity suites).

Stream contract: min-fixpoint streams must be dst-sorted WITHIN each
direction half (rank compression only needs within-block runs, so a
concatenated fwd+reversed stream is fine); sum streams must be globally
dst-sorted so the float accumulation order matches `segment_sum`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.dispatch import default_interpret

INF = 3.0e38  # plain float: jnp constants would be captured by the kernel tracer


def _bsp_superstep_kernel(
    *refs, combine: str, block_e: int, nblk: int, inner_cap: int
):
    if combine == "sum":
        (lsrc_hbm, ldst_hbm, w_hbm, deg_ref, val_ref,
         out_ref, it_ref, prev, acc, ibuf, wbuf, isems, wsems) = refs
    else:
        (lsrc_hbm, ldst_hbm, w_hbm, val_ref,
         out_ref, it_ref, prev, acc, ibuf, wbuf, isems, wsems) = refs
    worker = pl.program_id(0)

    if combine == "sum":
        # Fused apply of the push-sum share: each vertex pushes
        # val/outdeg along its out-edges (0 where outdeg == 0).
        deg = deg_ref[...]
        prev[...] = jnp.where(deg > 0, val_ref[...] / deg, 0.0)
    else:
        prev[...] = val_ref[...]

    def edge_dmas(slot, b):
        """The three async copies moving block b into buffer `slot`."""
        sl = pl.ds(b * block_e, block_e)
        return (
            pltpu.make_async_copy(lsrc_hbm.at[worker, sl], ibuf.at[0, slot], isems.at[0, slot]),
            pltpu.make_async_copy(ldst_hbm.at[worker, sl], ibuf.at[1, slot], isems.at[1, slot]),
            pltpu.make_async_copy(w_hbm.at[worker, sl], wbuf.at[slot], wsems.at[slot]),
        )

    def one_pass():
        """Stream every edge block through the double buffer, reducing
        into `acc`. One pass = one relaxation (min) / the whole sweep (sum)."""
        if combine == "sum":
            acc[...] = jnp.zeros_like(acc)
        else:
            acc[...] = prev[...]  # min is seeded with the current values
        for dma in edge_dmas(0, 0):  # warm-up: start block 0's copy
            dma.start()

        def block_body(b, carry):
            slot = jax.lax.rem(b, 2)
            next_slot = jax.lax.rem(b + 1, 2)

            @pl.when(b + 1 < nblk)
            def _prefetch():
                for dma in edge_dmas(next_slot, b + 1):
                    dma.start()

            for dma in edge_dmas(slot, b):
                dma.wait()
            lsrc = ibuf[0, slot]
            ldst = ibuf[1, slot]
            w = wbuf[slot]

            gathered = prev[0, lsrc]
            if combine == "sum":
                # Sequential index-order adds: float sums must accumulate in
                # exactly `segment_sum`'s order for bitwise parity with the
                # XLA sweep — a rank-onehot partial would re-associate.
                contrib = jnp.where(w != 0.0, gathered * w, 0.0)

                def commit_edge(j, c):
                    d = ldst[j]
                    cur = pl.load(acc, (pl.dslice(0, 1), pl.dslice(d, 1)))
                    pl.store(acc, (pl.dslice(0, 1), pl.dslice(d, 1)), cur + contrib[j])
                    return c

                jax.lax.fori_loop(0, block_e, commit_edge, 0)
                return carry

            # Padded edges carry w = INF (the min identity) and must
            # absorb the gather, exactly as the ref oracle's mask.
            contrib = jnp.where(w < INF, gathered + w, INF)

            # Rank-compress equal-dst runs (dst-sorted within the block).
            boundary = jnp.concatenate(
                [jnp.ones((1,), jnp.int32), (ldst[1:] != ldst[:-1]).astype(jnp.int32)]
            )
            rank = jnp.cumsum(boundary) - 1
            ranks = jax.lax.broadcasted_iota(jnp.int32, (block_e, block_e), 0)
            hit = ranks == rank[None, :]
            partial = jnp.min(jnp.where(hit, contrib[None, :], INF), axis=1)
            iota_e = jax.lax.broadcasted_iota(jnp.int32, (block_e, block_e), 1)
            run_start = jnp.min(jnp.where(hit, iota_e, block_e - 1), axis=1)
            dst_of_rank = ldst[run_start]
            nruns = rank[-1] + 1

            def commit(r, c):
                d = dst_of_rank[r]
                cur = pl.load(acc, (pl.dslice(0, 1), pl.dslice(d, 1)))
                pl.store(acc, (pl.dslice(0, 1), pl.dslice(d, 1)), jnp.minimum(cur, partial[r]))
                return c

            jax.lax.fori_loop(0, nruns, commit, 0)
            return carry

        jax.lax.fori_loop(0, nblk, block_body, 0)

    if combine == "sum":
        one_pass()
        out_ref[...] = acc[...]
        it_ref[0] = jnp.int32(1)
    else:
        # Per-worker fixpoint: iterate passes until a pass changes nothing
        # (fused convergence flag) or the inner cap hits. Identical values
        # and counts to the batched driver loop: change-passes of the
        # monotone relax form a prefix, so iters = min(#changing, cap).
        def cond(carry):
            changed, it = carry
            return changed & (it < inner_cap)

        def body(carry):
            _, it = carry
            one_pass()
            changed = jnp.any(acc[...] != prev[...])
            prev[...] = acc[...]
            return changed, it + jnp.where(changed, 1, 0)

        _, iters = jax.lax.while_loop(cond, body, (jnp.bool_(True), jnp.int32(0)))
        out_ref[...] = prev[...]
        it_ref[0] = iters


@functools.partial(
    jax.jit, static_argnames=("num_out", "combine", "inner_cap", "block_e", "interpret")
)
def bsp_superstep_pallas(
    lsrc: jax.Array,  # [p, E] int32, E % block_e == 0
    ldst: jax.Array,  # [p, E] int32, dst-sorted within blocks (see module doc)
    weight: jax.Array,  # [p, E] f32; pads carry INF (min) / 0 (sum)
    val: jax.Array,  # [p, num_out] f32
    out_degree: jax.Array | None = None,  # [p, num_out] f32, combine="sum" only
    *,
    num_out: int,
    combine: str = "min",
    inner_cap: int = 1,
    block_e: int = 512,
    interpret: bool | None = None,
):
    """Whole-local-stage BSP superstep: returns (new_val [p, num_out] f32,
    inner iteration counts [p] int32)."""
    interpret = default_interpret(interpret)
    p, E = lsrc.shape
    assert E % block_e == 0, "pad edge streams to a multiple of block_e"
    assert val.shape == (p, num_out)
    nblk = E // block_e
    hbm = pl.BlockSpec(memory_space=pltpu.ANY)
    per_worker = pl.BlockSpec((1, num_out), lambda i: (i, 0))
    in_specs = [hbm, hbm, hbm]
    args = [lsrc, ldst, weight]
    if combine == "sum":
        if out_degree is None:
            raise ValueError("combine='sum' needs out_degree")
        in_specs.append(per_worker)
        args.append(out_degree)
    in_specs.append(per_worker)
    args.append(val)
    out, iters = pl.pallas_call(
        functools.partial(
            _bsp_superstep_kernel,
            combine=combine, block_e=block_e, nblk=nblk, inner_cap=inner_cap,
        ),
        grid=(p,),
        in_specs=in_specs,
        out_specs=[per_worker, pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[
            jax.ShapeDtypeStruct((p, num_out), jnp.float32),
            jax.ShapeDtypeStruct((p,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, num_out), jnp.float32),  # prev (values / shares)
            pltpu.VMEM((1, num_out), jnp.float32),  # acc
            pltpu.VMEM((2, 2, block_e), jnp.int32),  # double-buffered src/dst
            pltpu.VMEM((2, block_e), jnp.float32),  # double-buffered weights
            pltpu.SemaphoreType.DMA((2, 2)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(*args)
    return out, iters
