"""Single platform sniff shared by every Pallas kernel entry point.

`ops._resolve_impl` and the kernels' own jitted wrappers both resolve
`interpret=None` here, so a direct call to e.g. `ebg_membership_pallas`
on TPU gets the compiled kernel — the same default a call routed through
`repro.kernels.ops` would get — instead of silently running the
interpreter.
"""
from __future__ import annotations

import jax


def default_interpret(interpret: bool | None = None) -> bool:
    """interpret=None -> Pallas interpreter off-TPU, compiled kernel on TPU.

    An explicit True/False always wins over the sniff (compiled Pallas is
    forceable off-TPU, the interpreter on TPU).
    """
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)
