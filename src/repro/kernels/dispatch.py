"""Single platform sniff shared by every Pallas kernel entry point.

`ops._resolve_impl` and the kernels' own jitted wrappers both resolve
`interpret=None` here, so a direct call to e.g. `ebg_membership_pallas`
on TPU gets the compiled kernel — the same default a call routed through
`repro.kernels.ops` would get — instead of silently running the
interpreter.

The sniff itself (`jax.default_backend()`, which walks `jax.devices()`)
is paid ONCE per process and cached: per-block kernel launches resolve
`interpret=None` on every call, and the probe is pure overhead after the
first. `set_platform_is_tpu` is the test seam — pass True/False to force
a platform, None to drop the cache and re-sniff.
"""
from __future__ import annotations

import jax

# None = not sniffed yet; True/False = cached (or test-forced) answer.
_PLATFORM_IS_TPU: bool | None = None


def platform_is_tpu() -> bool:
    """Cached once-per-process `jax.default_backend() == "tpu"` probe."""
    global _PLATFORM_IS_TPU
    if _PLATFORM_IS_TPU is None:
        _PLATFORM_IS_TPU = jax.default_backend() == "tpu"
    return _PLATFORM_IS_TPU


def set_platform_is_tpu(is_tpu: bool | None) -> None:
    """Test-visible override: True/False force the platform answer for
    subsequent `default_interpret(None)` resolutions; None clears the
    cache so the next call re-sniffs the real backend."""
    global _PLATFORM_IS_TPU
    _PLATFORM_IS_TPU = None if is_tpu is None else bool(is_tpu)


def default_interpret(interpret: bool | None = None) -> bool:
    """interpret=None -> Pallas interpreter off-TPU, compiled kernel on TPU.

    An explicit True/False always wins over the sniff (compiled Pallas is
    forceable off-TPU, the interpreter on TPU).
    """
    if interpret is None:
        return not platform_is_tpu()
    return bool(interpret)
