"""Pallas TPU kernel: destination-sorted segmented reduction over edges.

This is the compute hot spot of the subgraph-centric BSP engine: one local
relaxation is `out[dst] ⊕= val[src] (+ w)` over all edges of the subgraph,
with ⊕ ∈ {min, +}.

TPU adaptation (see DESIGN.md §3): TPUs have no efficient random scatter, so
the engine sorts edges by destination ONCE at build time and the kernel
performs a *segmented* reduction:

  - the vertex-value vector `val` stays resident in VMEM for the whole grid
    (EBG's vertex balance is what bounds max_v per device — the paper's
    balance objective directly controls this kernel's VMEM footprint);
  - edges are streamed from HBM in blocks of BLOCK_E (src, dst, w);
  - within a block, equal-dst runs are rank-compressed with a boundary
    cumsum, partials are computed with a rank-onehot masked reduction
    (VPU-friendly: a [BLOCK_E, BLOCK_E] compare+select tree), and
  - at most BLOCK_E compressed partials are committed to the VMEM
    accumulator with a scalar loop of dynamic stores (runs, not edges —
    on power-law graphs hub vertices compress thousands of edges per block
    into one store).

The sequential TPU grid makes cross-block accumulation into `out_ref` safe.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dispatch import default_interpret

INF = 3.0e38  # plain float: jnp constants would be captured by the kernel tracer


def _segment_reduce_kernel(
    lsrc_ref, ldst_ref, w_ref, val_ref, out_ref, *, block_e: int, is_min: bool
):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        if is_min:
            out_ref[...] = val_ref[...]
        else:
            out_ref[...] = jnp.zeros_like(out_ref)

    lsrc = lsrc_ref[...]
    ldst = ldst_ref[...]
    w = w_ref[...]

    vals = val_ref[lsrc]  # gather from VMEM-resident vertex values
    if is_min:
        contrib = vals + w  # min-plus semiring; padded edges carry w=INF
    else:
        contrib = vals * w  # sum-times; padded edges carry w=0

    # Rank-compress equal-dst runs (dst-sorted within the block).
    boundary = jnp.concatenate(
        [jnp.ones((1,), jnp.int32), (ldst[1:] != ldst[:-1]).astype(jnp.int32)]
    )
    rank = jnp.cumsum(boundary) - 1  # [block_e] in [0, nruns)

    # Rank-onehot partial reduction: partial[r] = ⊕ contrib[rank == r].
    ranks = jax.lax.broadcasted_iota(jnp.int32, (block_e, block_e), 0)
    hit = ranks == rank[None, :]
    if is_min:
        partial = jnp.min(jnp.where(hit, contrib[None, :], INF), axis=1)
    else:
        partial = jnp.sum(jnp.where(hit, contrib[None, :], 0.0), axis=1)

    # dst of each rank = dst at the first edge of the run; scatter-free via
    # the same rank-onehot matrix (min over hit of edge index).
    iota_e = jax.lax.broadcasted_iota(jnp.int32, (block_e, block_e), 1)
    run_start = jnp.min(jnp.where(hit, iota_e, block_e - 1), axis=1)
    dst_of_rank = ldst[run_start]
    nruns = rank[-1] + 1

    def commit(r, _):
        d = dst_of_rank[r]
        cur = pl.load(out_ref, (pl.dslice(d, 1),))
        upd = jnp.minimum(cur, partial[r]) if is_min else cur + partial[r]
        pl.store(out_ref, (pl.dslice(d, 1),), upd)
        return _

    jax.lax.fori_loop(0, nruns, commit, 0)


@functools.partial(
    jax.jit, static_argnames=("num_out", "block_e", "op", "interpret")
)
def segment_reduce_pallas(
    lsrc: jax.Array,
    ldst: jax.Array,
    weight: jax.Array,
    val: jax.Array,
    *,
    num_out: int,
    block_e: int = 512,
    op: str = "min",
    interpret: bool | None = None,
):
    """⊕-reduce edge contributions into destinations.

    lsrc/ldst: [E] int32, destination-sorted; padded edges must point at the
    dump slot (ldst == num_out - 1 is fine as long as callers ignore it) and
    carry identity weight (INF for min / 0 for sum — matching ref.py masks).
    val: [V] f32 (V >= num_out).
    Returns out: [num_out] f32; for op=="min", out is pre-seeded with val.
    """
    interpret = default_interpret(interpret)
    E = lsrc.shape[0]
    assert E % block_e == 0, "pad edges to a multiple of block_e"
    is_min = op == "min"
    grid = (E // block_e,)
    return pl.pallas_call(
        functools.partial(_segment_reduce_kernel, block_e=block_e, is_min=is_min),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((val.shape[0],), lambda i: (0,)),  # val resident
        ],
        out_specs=pl.BlockSpec((num_out,), lambda i: (0,)),  # accumulator resident
        out_shape=jax.ShapeDtypeStruct((num_out,), jnp.float32),
        interpret=interpret,
    )(lsrc, ldst, weight, val)
