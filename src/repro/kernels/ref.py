"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

INF = jnp.float32(3.0e38)


def segment_min_plus_ref(lsrc, ldst, weight, mask, val, num_out):
    """out[d] = min(val[d], min over edges e with ldst[e]==d of val[lsrc[e]] + w[e]).

    Edges are destination-sorted; padded edges have mask False.
    """
    data = val[lsrc] + weight
    data = jnp.where(mask, data, INF)
    cand = jax.ops.segment_min(data, ldst, num_segments=num_out, indices_are_sorted=True)
    cand = jnp.minimum(cand, val[:num_out])
    return cand


def segment_sum_ref(lsrc, ldst, contrib_scale, mask, val, num_out):
    """out[d] = sum over edges e with ldst[e]==d of val[lsrc[e]] * scale[e]."""
    data = val[lsrc] * contrib_scale
    data = jnp.where(mask, data, 0.0)
    return jax.ops.segment_sum(data, ldst, num_segments=num_out, indices_are_sorted=True)


def bsp_superstep_ref(
    lsrc, ldst, weight, val, num_out, *, combine="min", inner_cap=1, out_degree=None
):
    """Batched whole-local-stage BSP superstep oracle (see
    repro.kernels.bsp_superstep for the Pallas twin).

    lsrc/ldst: [p, E] int32; weight: [p, E] f32 (pads carry INF for min,
    0 for sum); val: [p, num_out] f32. combine="min" iterates the min-plus
    relaxation to local convergence (or `inner_cap`), with the batched
    any-worker loop the engine's XLA path runs — bit-identical values and
    per-worker iteration counts. combine="sum" is one out-degree-normalized
    push-sum sweep (`out_degree`: [p, num_out] f32) — the share division is
    fused, matching the engine's sweep term for term.

    Returns (new_val [p, num_out] f32, inner iteration counts [p] int32).
    min streams may concatenate direction halves (each half dst-sorted);
    sum streams must be globally dst-sorted (float accumulation order).
    """
    p = val.shape[0]
    if combine == "sum":
        share = jnp.where(out_degree > 0, val / out_degree, 0.0)
        data = jnp.take_along_axis(share, lsrc, axis=1) * weight
        data = jnp.where(weight != 0.0, data, 0.0)
        new = jax.vmap(
            lambda d, s: jax.ops.segment_sum(
                d, s, num_segments=num_out, indices_are_sorted=True
            )
        )(data, ldst)
        return new, jnp.ones((p,), jnp.int32)
    if combine == "max":
        out, iters = bsp_superstep_ref(
            lsrc, ldst, weight, -val, num_out, combine="min", inner_cap=inner_cap
        )
        return -out, iters
    mask = weight < INF

    def relax(v):
        data = jnp.take_along_axis(v, lsrc, axis=1) + weight
        data = jnp.where(mask, data, INF)
        # indices_are_sorted=False: the stream may concatenate direction
        # halves, so ldst is only sorted per half — min is order-invariant.
        cand = jax.vmap(
            lambda d, s: jax.ops.segment_min(d, s, num_segments=num_out)
        )(data, ldst)
        return jnp.minimum(v, cand)

    def body(carry):
        v, ch, it, iters = carry
        new = relax(v)
        ch = jnp.any(new != v, axis=1)  # per worker
        return new, ch, it + 1, iters + ch.astype(jnp.int32)

    carry = (val, jnp.ones((p,), bool), jnp.int32(0), jnp.zeros((p,), jnp.int32))
    carry = jax.lax.while_loop(
        lambda c: jnp.any(c[1]) & (c[2] < inner_cap), body, carry
    )
    return carry[0], carry[3]


def _miss_ref(keep_bits, ids):
    """[B] vertex ids -> [p, B] f32: 1 where the id is absent from keep[i]."""
    word = keep_bits[:, ids >> 5]
    bit = (word >> (ids & 31).astype(jnp.uint32)) & 1
    return (1 - bit).astype(jnp.float32)


def ebg_membership_ref(keep_bits, u, v):
    """memb[i, b] = 1[u_b not in keep[i]] + 1[v_b not in keep[i]].

    keep_bits: [p, Vw] uint32 packed bitset (bit k of word w = vertex w*32+k).
    """
    return _miss_ref(keep_bits, u) + _miss_ref(keep_bits, v)


def ebg_commit_block_ref(
    keep_bits, e_count, v_count, u, v, valid, *,
    alpha, beta, inv_e, inv_v, eps=1.0, balance="static", wu=None, wv=None,
    window=False,
):
    """Fused streaming-scorer block commit: score + argmin + balance commit
    + bitset update, parameterized by the scorer's coefficient vector.

    alpha/beta are the generic edge/vertex balance coefficients (EBV's
    namesakes; HDRF's lambda rides in alpha with beta=0). `balance` picks
    the edge-balance normalizer: "static" uses inv_e (= p/|E|), "range"
    uses 1/(eps + max(e_count) − min(e_count)). wu/wv, when given, weight
    the membership term per edge (HDRF's 2−θ degree term).

    window=False (frozen commit): membership is evaluated against the
    BLOCK-START bitset (same staleness contract as the chunked scorer);
    the balance terms are committed exactly and sequentially within the
    block. window=True (speculative window commit): the whole block is
    still scored from block-start state in one vectorized shot, but each
    commit replays its membership consequences onto the remaining block
    columns — the winner's miss rows are cleared wherever a later edge
    touches the committed endpoints — so only conflicted edges see
    corrected columns and the assignments are bit-identical to the
    one-edge-at-a-time scan driver.

    Invalid (pad) edges are scored but never committed — their assignment
    is the out-of-bounds row p, dropped by the bit scatter (and, under
    window, they clear nothing). Arithmetic is term-for-term the per-edge
    loop the chunked partitioner ran in-engine before this op existed, so
    the assignments are bit-identical.

    Returns (keep_bits, e_count, v_count, parts).
    """
    p = keep_bits.shape[0]
    mu0 = _miss_ref(keep_bits, u)  # [p, B] against block-start keep
    mv0 = _miss_ref(keep_bits, v)

    def body(j, carry):
        e_c, v_c, kb, mu, mv, parts = carry
        if balance == "static":
            norm = inv_e
        else:
            norm = 1.0 / (eps + (jnp.max(e_c) - jnp.min(e_c)))
        gain = wu[j] * mu[:, j] + wv[j] * mv[:, j] if wu is not None else mu[:, j] + mv[:, j]
        score = gain + alpha * e_c * norm + beta * v_c * inv_v
        i = jnp.argmin(score).astype(jnp.int32)
        live = valid[j].astype(jnp.float32)
        e_c = e_c.at[i].add(live)
        v_c = v_c.at[i].add(live * (mu[i, j] + mv[i, j]))
        row = jnp.where(valid[j], i, p)
        uu, vv = u[j], v[j]
        bit_u = jnp.uint32(1) << (uu & 31).astype(jnp.uint32)
        kb = kb.at[row, uu >> 5].set(kb[i, uu >> 5] | bit_u, mode="drop")
        bit_v = jnp.uint32(1) << (vv & 31).astype(jnp.uint32)
        kb = kb.at[row, vv >> 5].set(kb[i, vv >> 5] | bit_v, mode="drop")
        if window:
            # Conflict replay: endpoints {u_j, v_j} now live in part i, so
            # any remaining column touching them must stop scoring a miss.
            hit_u = (u == uu) | (u == vv)
            hit_v = (v == uu) | (v == vv)
            mu = mu.at[i].set(jnp.where(hit_u & valid[j], 0.0, mu[i]))
            mv = mv.at[i].set(jnp.where(hit_v & valid[j], 0.0, mv[i]))
        return e_c, v_c, kb, mu, mv, parts.at[j].set(row)

    e_count, v_count, keep_bits, _, _, parts = jax.lax.fori_loop(
        0, u.shape[0], body,
        (e_count, v_count, keep_bits, mu0, mv0, jnp.zeros(u.shape, jnp.int32)),
    )
    return keep_bits, e_count, v_count, parts


def decode_attention_ref(q, k, v, *, softcap: float = 0.0):
    """Single-token GQA decode attention.

    q: [B, Hq, D]; k, v: [B, S, Hkv, D]; Hq % Hkv == 0.
    Returns [B, Hq, D]. fp32 accumulation.
    """
    B, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bhgd,bshd->bhgs", qf, kf) / jnp.sqrt(D).astype(jnp.float32)
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", w, vf)
    return out.reshape(B, Hq, D).astype(q.dtype)
