"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

INF = jnp.float32(3.0e38)


def segment_min_plus_ref(lsrc, ldst, weight, mask, val, num_out):
    """out[d] = min(val[d], min over edges e with ldst[e]==d of val[lsrc[e]] + w[e]).

    Edges are destination-sorted; padded edges have mask False.
    """
    data = val[lsrc] + weight
    data = jnp.where(mask, data, INF)
    cand = jax.ops.segment_min(data, ldst, num_segments=num_out, indices_are_sorted=True)
    cand = jnp.minimum(cand, val[:num_out])
    return cand


def segment_sum_ref(lsrc, ldst, contrib_scale, mask, val, num_out):
    """out[d] = sum over edges e with ldst[e]==d of val[lsrc[e]] * scale[e]."""
    data = val[lsrc] * contrib_scale
    data = jnp.where(mask, data, 0.0)
    return jax.ops.segment_sum(data, ldst, num_segments=num_out, indices_are_sorted=True)


def _miss_ref(keep_bits, ids):
    """[B] vertex ids -> [p, B] f32: 1 where the id is absent from keep[i]."""
    word = keep_bits[:, ids >> 5]
    bit = (word >> (ids & 31).astype(jnp.uint32)) & 1
    return (1 - bit).astype(jnp.float32)


def ebg_membership_ref(keep_bits, u, v):
    """memb[i, b] = 1[u_b not in keep[i]] + 1[v_b not in keep[i]].

    keep_bits: [p, Vw] uint32 packed bitset (bit k of word w = vertex w*32+k).
    """
    return _miss_ref(keep_bits, u) + _miss_ref(keep_bits, v)


def ebg_commit_block_ref(
    keep_bits, e_count, v_count, u, v, valid, *,
    alpha, beta, inv_e, inv_v, eps=1.0, balance="static", wu=None, wv=None,
):
    """Fused streaming-scorer block commit: score + argmin + balance commit
    + bitset update, parameterized by the scorer's coefficient vector.

    alpha/beta are the generic edge/vertex balance coefficients (EBV's
    namesakes; HDRF's lambda rides in alpha with beta=0). `balance` picks
    the edge-balance normalizer: "static" uses inv_e (= p/|E|), "range"
    uses 1/(eps + max(e_count) − min(e_count)). wu/wv, when given, weight
    the membership term per edge (HDRF's 2−θ degree term).

    Membership is evaluated against the BLOCK-START bitset (same staleness
    contract as the chunked scorer); the balance terms are committed exactly
    and sequentially within the block. Invalid (pad) edges are scored but
    never committed — their assignment is the out-of-bounds row p, dropped
    by the bit scatter. Arithmetic is term-for-term the per-edge loop the
    chunked partitioner ran in-engine before this op existed, so the
    assignments are bit-identical.

    Returns (keep_bits, e_count, v_count, parts).
    """
    p = keep_bits.shape[0]
    mu = _miss_ref(keep_bits, u)  # [p, B] against block-start keep
    mv = _miss_ref(keep_bits, v)
    memb = mu + mv
    wmemb = wu[None, :] * mu + wv[None, :] * mv if wu is not None else memb

    def body(j, carry):
        e_c, v_c, kb, parts = carry
        if balance == "static":
            norm = inv_e
        else:
            norm = 1.0 / (eps + (jnp.max(e_c) - jnp.min(e_c)))
        score = wmemb[:, j] + alpha * e_c * norm + beta * v_c * inv_v
        i = jnp.argmin(score).astype(jnp.int32)
        live = valid[j].astype(jnp.float32)
        e_c = e_c.at[i].add(live)
        v_c = v_c.at[i].add(live * memb[i, j])
        row = jnp.where(valid[j], i, p)
        uu, vv = u[j], v[j]
        bit_u = jnp.uint32(1) << (uu & 31).astype(jnp.uint32)
        kb = kb.at[row, uu >> 5].set(kb[i, uu >> 5] | bit_u, mode="drop")
        bit_v = jnp.uint32(1) << (vv & 31).astype(jnp.uint32)
        kb = kb.at[row, vv >> 5].set(kb[i, vv >> 5] | bit_v, mode="drop")
        return e_c, v_c, kb, parts.at[j].set(row)

    e_count, v_count, keep_bits, parts = jax.lax.fori_loop(
        0, u.shape[0], body,
        (e_count, v_count, keep_bits, jnp.zeros(u.shape, jnp.int32)),
    )
    return keep_bits, e_count, v_count, parts


def decode_attention_ref(q, k, v, *, softcap: float = 0.0):
    """Single-token GQA decode attention.

    q: [B, Hq, D]; k, v: [B, S, Hkv, D]; Hq % Hkv == 0.
    Returns [B, Hq, D]. fp32 accumulation.
    """
    B, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bhgd,bshd->bhgs", qf, kf) / jnp.sqrt(D).astype(jnp.float32)
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", w, vf)
    return out.reshape(B, Hq, D).astype(q.dtype)
