"""Pallas TPU kernel: fused streaming-scorer block commit (score + argmin
+ commit) for the chunked vertex-cut partitioners.

`ebg_membership_pallas` only covers the vectorizable score phase; the
chunked partitioner still paid one p-wide argmin plus four scattered
1-element updates per edge back in XLA land. This kernel fuses the whole
per-block pipeline:

  1. membership of the block's 2·B endpoints against the block-start
     packed bitset (vectorized, VPU-friendly), optionally weighted by the
     scorer's per-edge degree term (HDRF's 2−θ streams),
  2. the sequential per-edge argmin + exact balance-term commit,
  3. the per-winner bitset updates,

with the (p,) e/v counters and the (p, ⌈V/32⌉) uint32 bitset resident in
VMEM for the whole block — HBM sees one bitset read + one write per block
instead of four scattered touches per edge. Assignments are bit-identical
to the unfused path (`repro.kernels.ref.ebg_commit_block_ref`): membership
is pinned to the block-start bitset, so the in-loop bit commits never feed
back into this block's scores.

`window=True` turns the frozen commit into the speculative window commit:
scoring is still vectorized against block-start membership, but each
committed edge clears the now-stale membership columns of LATER in-block
edges that share one of its endpoints, so only conflicted edges replay
against corrected state — assignments become bit-identical to the
one-edge-at-a-time scan driver at any block size.

The scorer's coefficients ride in as a (5,) f32 vector — ce (edge-balance
coefficient: EBV alpha / HDRF lambda), cv (vertex-balance: EBV beta),
inv_e, inv_v (the static normalizers), eps (the range normalizer's
epsilon) — they are traced values in the chunked driver (inv_e depends on
the real edge count), so they cannot be static kernel parameters. The
scorer's STRUCTURE (balance mode, degree weighting) is static: it selects
the traced computation, keeping the stock-EBV path identical to the
pre-generalization kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dispatch import default_interpret


def _ebg_commit_kernel(
    u_ref, v_ref, valid_ref, wu_ref, wv_ref, coef_ref, keep_in_ref, e_in_ref, v_in_ref,
    keep_ref, e_ref, vc_ref, parts_ref, *, num_parts: int, balance: str, weighted: bool,
    window: bool = False,
):
    u = u_ref[...]
    v = v_ref[...]
    valid = valid_ref[...]
    ce, cv = coef_ref[0], coef_ref[1]
    inv_e, inv_v, eps = coef_ref[2], coef_ref[3], coef_ref[4]
    keep = keep_in_ref[...]  # [p, Vw] block-start bitset, pinned for scoring

    def miss(ids):
        words = keep[:, ids >> 5]  # [p, B] gather along the packed axis
        bits = (words >> (ids & 31).astype(jnp.uint32)) & jnp.uint32(1)
        return (jnp.uint32(1) - bits).astype(jnp.float32)

    mu0 = miss(u)
    mv0 = miss(v)
    keep_ref[...] = keep  # commit loop mutates the output copy in place

    def body(j, carry):
        e_c, v_c, mu, mv = carry
        if balance == "static":
            norm = inv_e
        else:
            norm = 1.0 / (eps + (jnp.max(e_c) - jnp.min(e_c)))
        if weighted:
            gain = wu_ref[j] * mu[:, j] + wv_ref[j] * mv[:, j]
        else:
            gain = mu[:, j] + mv[:, j]
        score = gain + ce * e_c * norm + cv * v_c * inv_v
        i = jnp.argmin(score).astype(jnp.int32)  # ties -> lowest subgraph id
        live = valid[j].astype(jnp.float32)
        e_c = e_c.at[i].add(live)
        v_c = v_c.at[i].add(live * (mu[i, j] + mv[i, j]))
        pl.store(
            parts_ref,
            (pl.dslice(j, 1),),
            jnp.where(valid[j] != 0, i, num_parts).reshape(1),
        )

        @pl.when(valid[j] != 0)
        def _commit_bits():
            wu = u[j] >> 5
            bu = jnp.uint32(1) << (u[j] & 31).astype(jnp.uint32)
            cur_u = pl.load(keep_ref, (pl.dslice(i, 1), pl.dslice(wu, 1)))
            pl.store(keep_ref, (pl.dslice(i, 1), pl.dslice(wu, 1)), cur_u | bu)
            # v's word is read AFTER u's store: u and v may share a word.
            wv = v[j] >> 5
            bv = jnp.uint32(1) << (v[j] & 31).astype(jnp.uint32)
            cur_v = pl.load(keep_ref, (pl.dslice(i, 1), pl.dslice(wv, 1)))
            pl.store(keep_ref, (pl.dslice(i, 1), pl.dslice(wv, 1)), cur_v | bv)

        if window:
            # Speculative window commit: the block was scored from frozen
            # state; replay this commit's membership consequences onto the
            # remaining columns (clear the winner's miss rows wherever a
            # later edge touches the committed endpoints) so conflicted
            # edges score against live state — bit-identical to the scan.
            hit_u = (u == u[j]) | (u == v[j])
            hit_v = (v == u[j]) | (v == v[j])
            gate = valid[j] != 0
            mu = mu.at[i].set(jnp.where(hit_u & gate, 0.0, mu[i]))
            mv = mv.at[i].set(jnp.where(hit_v & gate, 0.0, mv[i]))
        return e_c, v_c, mu, mv

    e_c, v_c, _, _ = jax.lax.fori_loop(
        0, u.shape[0], body, (e_in_ref[...], v_in_ref[...], mu0, mv0)
    )
    e_ref[...] = e_c
    vc_ref[...] = v_c


@functools.partial(jax.jit, static_argnames=("balance", "weighted", "window", "interpret"))
def ebg_commit_block_pallas(
    keep_bits: jax.Array,  # [p, Vw] uint32
    e_count: jax.Array,  # [p] f32
    v_count: jax.Array,  # [p] f32
    u: jax.Array,  # [B] int32
    v: jax.Array,  # [B] int32
    valid: jax.Array,  # [B] bool (pad edges False)
    wu: jax.Array,  # [B] f32 membership weights (ignored unless weighted)
    wv: jax.Array,  # [B] f32
    coef: jax.Array,  # [5] f32: ce, cv, inv_e, inv_v, eps
    *,
    balance: str = "static",
    weighted: bool = False,
    window: bool = False,
    interpret: bool | None = None,
):
    interpret = default_interpret(interpret)
    p, vw = keep_bits.shape
    B = u.shape[0]
    keep_out, e_out, v_out, parts = pl.pallas_call(
        functools.partial(
            _ebg_commit_kernel, num_parts=p, balance=balance, weighted=weighted,
            window=window,
        ),
        out_shape=(
            jax.ShapeDtypeStruct((p, vw), jnp.uint32),
            jax.ShapeDtypeStruct((p,), jnp.float32),
            jax.ShapeDtypeStruct((p,), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ),
        interpret=interpret,
    )(u, v, valid.astype(jnp.int32), wu, wv, coef, keep_bits, e_count, v_count)
    return keep_out, e_out, v_out, parts
