"""Pallas TPU kernel: flash-decode GQA attention (one query token).

Serving hot spot for the decode_32k / long_500k shapes: a single new token
attends over a long KV cache. Online-softmax over KV blocks streamed
HBM→VMEM; per-(batch, kv-head) accumulators live in VMEM scratch. The
query-group dim G (= Hq/Hkv) and head dim D form the VPU/MXU tile; the KV
sequence is the sequential grid dimension.

Layout: q [B, Hkv, G, D]; k, v [B, S, Hkv, D]. fp32 accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.dispatch import default_interpret

NEG_INF = -3.0e38  # plain float (kernel-capture-safe)


def _decode_attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *, softcap: float, scale: float):
    s_step = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # [G, D]
    k = k_ref[0, :, 0].astype(jnp.float32)  # [Sb, D]
    v = v_ref[0, :, 0].astype(jnp.float32)  # [Sb, D]

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [G, Sb]
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)

    m_prev = m_ref[...]  # [G, 1]
    m_new = jnp.maximum(m_prev, scores.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)  # [G, Sb]
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(s_step == n_s - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "softcap", "interpret"))
def decode_attention_pallas(
    q: jax.Array,  # [B, Hq, D]
    k: jax.Array,  # [B, S, Hkv, D]
    v: jax.Array,  # [B, S, Hkv, D]
    *,
    block_s: int = 512,
    softcap: float = 0.0,
    interpret: bool | None = None,
):
    interpret = default_interpret(interpret)
    B, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    assert S % block_s == 0
    qg = q.reshape(B, Hkv, G, D)
    grid = (B, Hkv, S // block_s)
    out = pl.pallas_call(
        functools.partial(
            _decode_attn_kernel, softcap=float(softcap), scale=1.0 / float(D) ** 0.5
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, block_s, 1, D), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, block_s, 1, D), lambda b, h, s: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),  # acc
            pltpu.VMEM((G, 1), jnp.float32),  # running max
            pltpu.VMEM((G, 1), jnp.float32),  # running denom
        ],
        interpret=interpret,
    )(qg, k, v)
    return out.reshape(B, Hq, D)
