"""Pallas TPU kernel: blocked EBG membership-score evaluation.

The vectorizable 99% of EBG's per-edge work is the membership term
`1[u∉keep[i]] + 1[v∉keep[i]]` over all p candidate subgraphs. The `keep`
sets are packed as a p × ⌈V/32⌉ uint32 bitset that stays VMEM-resident
(p=32, V=1M → 4 MB); edge-id blocks stream from HBM. The balance terms and
the sequential argmin-commit stay outside (lax.scan / fori_loop in
repro.core.ebg) — this kernel feeds the chunked variant's score phase.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dispatch import default_interpret


def _ebg_membership_kernel(u_ref, v_ref, keep_ref, out_ref):
    u = u_ref[...]
    v = v_ref[...]
    keep = keep_ref[...]  # [p, Vw] uint32

    def miss(ids):
        words = keep[:, ids >> 5]  # [p, B] gather along the packed axis
        bits = (words >> (ids & 31).astype(jnp.uint32)) & jnp.uint32(1)
        return (jnp.uint32(1) - bits).astype(jnp.float32)

    out_ref[...] = miss(u) + miss(v)


@functools.partial(jax.jit, static_argnames=("block_e", "interpret"))
def ebg_membership_pallas(
    keep_bits: jax.Array,  # [p, Vw] uint32
    u: jax.Array,  # [E] int32
    v: jax.Array,  # [E] int32
    *,
    block_e: int = 512,
    interpret: bool | None = None,
):
    interpret = default_interpret(interpret)
    E = u.shape[0]
    p, vw = keep_bits.shape
    assert E % block_e == 0
    return pl.pallas_call(
        _ebg_membership_kernel,
        grid=(E // block_e,),
        in_specs=[
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((p, vw), lambda i: (0, 0)),  # bitset resident
        ],
        out_specs=pl.BlockSpec((p, block_e), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((p, E), jnp.float32),
        interpret=interpret,
    )(u, v, keep_bits)
