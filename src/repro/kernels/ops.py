"""Public jit'd wrappers for the Pallas kernels with CPU-oracle dispatch.

On the CPU container the kernels run under interpret=True only in the test
sweeps (slow but exact); production entry points default to the pure-jnp
oracle on CPU and the Pallas path on TPU. Callers can force either with
`impl=`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attn import decode_attention_pallas
from repro.kernels.ebg_score import ebg_membership_pallas
from repro.kernels.segment_reduce import segment_reduce_pallas


def _default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def segment_min_plus(lsrc, ldst, weight, val, *, num_out: int, impl: str | None = None, block_e: int = 512):
    """out[d] = min(val[d], min_{e: dst=d} val[src_e] + w_e); dst-sorted edges.

    Padded edges must carry weight=INF (min identity).
    """
    impl = impl or _default_impl()
    if impl == "ref":
        mask = weight < ref.INF
        return ref.segment_min_plus_ref(lsrc, ldst, weight, mask, val, num_out)
    interpret = jax.default_backend() != "tpu"
    return segment_reduce_pallas(
        lsrc, ldst, weight, val, num_out=num_out, block_e=block_e, op="min", interpret=interpret
    )


def segment_sum_scaled(lsrc, ldst, scale, val, *, num_out: int, impl: str | None = None, block_e: int = 512):
    """out[d] = sum_{e: dst=d} val[src_e] * scale_e; padded edges scale=0."""
    impl = impl or _default_impl()
    if impl == "ref":
        mask = scale != 0.0
        return ref.segment_sum_ref(lsrc, ldst, scale, mask, val, num_out)
    interpret = jax.default_backend() != "tpu"
    return segment_reduce_pallas(
        lsrc, ldst, scale, val, num_out=num_out, block_e=block_e, op="sum", interpret=interpret
    )


def ebg_membership(keep_bits, u, v, *, impl: str | None = None, block_e: int = 512):
    """memb[i,b] = #endpoints of edge b absent from keep[i] (packed bitset)."""
    impl = impl or _default_impl()
    if impl == "ref":
        return ref.ebg_membership_ref(keep_bits, u, v)
    interpret = jax.default_backend() != "tpu"
    return ebg_membership_pallas(keep_bits, u, v, block_e=block_e, interpret=interpret)


def decode_attention(q, k, v, *, softcap: float = 0.0, impl: str | None = None, block_s: int = 512):
    """Single-token GQA decode attention over a KV cache."""
    impl = impl or _default_impl()
    if impl == "ref":
        return ref.decode_attention_ref(q, k, v, softcap=softcap)
    interpret = jax.default_backend() != "tpu"
    return decode_attention_pallas(q, k, v, softcap=softcap, block_s=block_s, interpret=interpret)


def pack_keep_bits(keep_bool: jax.Array) -> jax.Array:
    """[p, V] bool -> [p, ceil(V/32)] uint32 packed bitset."""
    p, V = keep_bool.shape
    pad = (-V) % 32
    kb = jnp.pad(keep_bool, ((0, 0), (0, pad)))
    words = kb.reshape(p, -1, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(words << shifts[None, None, :], axis=-1, dtype=jnp.uint32)
