"""Public jit'd wrappers for the Pallas kernels with CPU-oracle dispatch.

On the CPU container the kernels default to the pure-jnp oracle; on TPU the
production entry points default to the compiled Pallas path. Callers can
force either with `impl=`, and — independently — force interpret vs
compiled Pallas with `interpret=` (e.g. `impl="pallas", interpret=True`
runs the real kernel under the interpreter on any backend, which is how
the engine's `compute_backend="pallas"` stays testable off-TPU).

These wrappers also own the block-padding convention: edge streams are
padded to a multiple of `block_e` with identity-weight no-op edges, so
callers (the BSP engine pads to `pad_multiple`, not to `block_e`) never
have to know the kernels' grid granularity.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.api.config import COMPUTE_BACKENDS, check_compute_backend  # noqa: F401  (re-exported seam)
from repro.kernels import ref
from repro.kernels.bsp_superstep import bsp_superstep_pallas
from repro.kernels.decode_attn import decode_attention_pallas
from repro.kernels.dispatch import default_interpret, platform_is_tpu
from repro.kernels.ebg_commit import ebg_commit_block_pallas
from repro.kernels.ebg_score import ebg_membership_pallas
from repro.kernels.segment_reduce import segment_reduce_pallas

IMPLS = ("ref", "pallas")


def _default_impl() -> str:
    return "pallas" if platform_is_tpu() else "ref"


def _resolve_impl(impl: str | None, interpret: bool | None) -> tuple[str, bool]:
    """The single place backend sniffing happens.

    impl=None  -> pallas on TPU, pure-jnp oracle elsewhere.
    interpret=None -> interpreter off-TPU, compiled kernel on TPU.
    An explicit `interpret` always wins over the sniff, so callers can
    force compiled Pallas off-TPU (or the interpreter on TPU).
    """
    impl = impl or _default_impl()
    if impl not in IMPLS:
        raise ValueError(f"impl must be one of {IMPLS} or None, got {impl!r}")
    return impl, default_interpret(interpret)


def _pad_to_block(lsrc, ldst, weight, block_e: int, pad_dst: int, identity: float):
    """Pad an edge stream to a multiple of block_e with no-op edges.

    Pad edges point at `pad_dst` (callers pass num_out-1, the engine's dump
    slot, which also keeps dst-sortedness) and carry the reduction identity
    as weight, so they contribute nothing. Returns the (possibly smaller)
    block size actually used — a stream shorter than block_e becomes a
    single exact-size block instead of mostly padding.
    """
    E = lsrc.shape[0]
    block_e = max(min(block_e, E), 1)
    pad = (-E) % block_e
    if pad:
        lsrc = jnp.concatenate([lsrc, jnp.zeros((pad,), lsrc.dtype)])
        ldst = jnp.concatenate([ldst, jnp.full((pad,), pad_dst, ldst.dtype)])
        weight = jnp.concatenate([weight, jnp.full((pad,), identity, weight.dtype)])
    return lsrc, ldst, weight, block_e


def segment_min_plus(
    lsrc, ldst, weight, val, *, num_out: int,
    impl: str | None = None, block_e: int = 512, interpret: bool | None = None,
):
    """out[d] = min(val[d], min_{e: dst=d} val[src_e] + w_e); dst-sorted edges.

    Padded edges must carry weight=INF (min identity).
    """
    impl, interpret = _resolve_impl(impl, interpret)
    if impl == "ref":
        mask = weight < ref.INF
        return ref.segment_min_plus_ref(lsrc, ldst, weight, mask, val, num_out)
    lsrc, ldst, weight, block_e = _pad_to_block(
        lsrc, ldst, weight, block_e, num_out - 1, float(ref.INF)
    )
    return segment_reduce_pallas(
        lsrc, ldst, weight, val, num_out=num_out, block_e=block_e, op="min", interpret=interpret
    )


def segment_sum_scaled(
    lsrc, ldst, scale, val, *, num_out: int,
    impl: str | None = None, block_e: int = 512, interpret: bool | None = None,
):
    """out[d] = sum_{e: dst=d} val[src_e] * scale_e; padded edges scale=0."""
    impl, interpret = _resolve_impl(impl, interpret)
    if impl == "ref":
        mask = scale != 0.0
        return ref.segment_sum_ref(lsrc, ldst, scale, mask, val, num_out)
    lsrc, ldst, scale, block_e = _pad_to_block(lsrc, ldst, scale, block_e, num_out - 1, 0.0)
    return segment_reduce_pallas(
        lsrc, ldst, scale, val, num_out=num_out, block_e=block_e, op="sum", interpret=interpret
    )


def segment_max(
    lsrc, ldst, weight, val, *, num_out: int,
    impl: str | None = None, block_e: int = 512, interpret: bool | None = None,
):
    """out[d] = max(val[d], max_{e: dst=d} val[src_e]); dst-sorted edges.

    The max-combine entry point for max-semiring programs (e.g. the
    engine's reachability). It runs on the SAME min-plus kernels via
    negation — no separate Pallas kernel to maintain. `weight` is the pad
    carrier only: real edges must hold 0, padded edges the min identity
    INF (so they contribute nothing in the negated domain).
    """
    return -segment_min_plus(
        lsrc, ldst, weight, -val, num_out=num_out, impl=impl, block_e=block_e,
        interpret=interpret,
    )


def bsp_superstep(
    lsrc, ldst, weight, val, *, num_out: int, combine: str = "min",
    inner_cap: int = 1, out_degree=None,
    impl: str | None = None, block_e: int = 512, interpret: bool | None = None,
):
    """Whole-local-stage BSP superstep for a batch of workers (the engine's
    megakernel entry): lsrc/ldst/weight are [p, E] edge streams, val is the
    [p, num_out] f32 value state.

    combine="min" iterates the min-plus relaxation to local convergence
    (capped at `inner_cap`) — padded edges must carry weight=INF (the min
    identity); the stream may concatenate direction halves, each
    dst-sorted. combine="max" runs on the same machinery via negation
    (`weight` is the pad carrier only: real edges hold 0, pads INF).
    combine="sum" is one out-degree-normalized push-sum sweep
    (`out_degree`: [p, num_out] f32; the share division is fused) —
    padded edges carry weight=0 and the stream must be globally
    dst-sorted (float accumulation order).

    Returns (new_val [p, num_out] f32, per-worker inner iteration counts
    [p] int32) — bit-identical values and counts to the engine's batched
    XLA path across impls (the driver/backend/program parity suites pin
    this).
    """
    impl, interpret = _resolve_impl(impl, interpret)
    if combine not in ("min", "max", "sum"):
        raise ValueError(f"combine must be 'min', 'max' or 'sum', got {combine!r}")
    if combine == "max":
        out, iters = bsp_superstep(
            lsrc, ldst, weight, -val, num_out=num_out, combine="min",
            inner_cap=inner_cap, impl=impl, block_e=block_e, interpret=interpret,
        )
        return -out, iters
    if (combine == "sum") != (out_degree is not None):
        raise ValueError("out_degree is required for combine='sum' and only then")
    if impl == "ref":
        return ref.bsp_superstep_ref(
            lsrc, ldst, weight, val, num_out,
            combine=combine, inner_cap=inner_cap, out_degree=out_degree,
        )
    # Batched twin of _pad_to_block: pad every worker's stream to a
    # multiple of block_e with identity-weight no-op edges at the dump slot.
    p, E = lsrc.shape
    block_e = max(min(block_e, E), 1)
    pad = (-E) % block_e
    if pad:
        identity = 0.0 if combine == "sum" else float(ref.INF)
        lsrc = jnp.concatenate([lsrc, jnp.zeros((p, pad), lsrc.dtype)], axis=1)
        ldst = jnp.concatenate([ldst, jnp.full((p, pad), num_out - 1, ldst.dtype)], axis=1)
        weight = jnp.concatenate([weight, jnp.full((p, pad), identity, weight.dtype)], axis=1)
    return bsp_superstep_pallas(
        lsrc, ldst, weight, val, out_degree,
        num_out=num_out, combine=combine, inner_cap=inner_cap,
        block_e=block_e, interpret=interpret,
    )


def ebg_membership(
    keep_bits, u, v, *, impl: str | None = None, block_e: int = 512, interpret: bool | None = None,
):
    """memb[i,b] = #endpoints of edge b absent from keep[i] (packed bitset)."""
    impl, interpret = _resolve_impl(impl, interpret)
    if impl == "ref":
        return ref.ebg_membership_ref(keep_bits, u, v)
    E = u.shape[0]
    block_e = max(min(block_e, E), 1)
    pad = (-E) % block_e
    if pad:
        u = jnp.concatenate([u, jnp.zeros((pad,), u.dtype)])
        v = jnp.concatenate([v, jnp.zeros((pad,), v.dtype)])
    out = ebg_membership_pallas(keep_bits, u, v, block_e=block_e, interpret=interpret)
    return out[:, :E] if pad else out


def ebg_commit_block(
    keep_bits, e_count, v_count, u, v, valid, *,
    alpha, beta, inv_e, inv_v, eps=1.0, balance: str = "static",
    wu=None, wv=None, window: bool = False,
    impl: str | None = None, interpret: bool | None = None,
):
    """Fused streaming-scorer block commit: membership score + argmin +
    exact balance commit + bitset update for a whole edge block, with the
    (p,) counters and the (p, ⌈V/32⌉) bitset VMEM-resident on the Pallas
    path.

    The scorer rides in as its coefficient vector plus structure flags:
    alpha/beta are the generic edge/vertex balance coefficients (EBV's
    namesakes; HDRF's lambda is alpha with beta=0), `balance` selects the
    edge-balance normalizer ("static" inv_e = p/|E|, "range"
    1/(eps + max−min)), and wu/wv optionally weight the membership term
    per edge (HDRF's 2−θ degree streams). All coefficients may be traced
    scalars (inv_e depends on the real edge count). Pad edges carry
    valid=False: they are scored (uniform lane work) but never committed,
    and their assignment is the out-of-bounds row p. `window=True` turns
    the frozen-membership commit into the speculative window commit:
    scores stay vectorized against block-start state, but each commit
    replays its membership consequences onto later conflicted columns —
    assignments bit-identical to the one-edge-at-a-time scan driver.
    Returns (keep_bits, e_count, v_count, parts) — assignments
    bit-identical across impls and to the dense-membership XLA path.
    """
    impl, interpret = _resolve_impl(impl, interpret)
    if balance not in ("static", "range"):
        raise ValueError(f"balance must be 'static' or 'range', got {balance!r}")
    if (wu is None) != (wv is None):
        raise ValueError("wu and wv must be given together")
    if impl == "ref":
        return ref.ebg_commit_block_ref(
            keep_bits, e_count, v_count, u, v, valid,
            alpha=alpha, beta=beta, inv_e=inv_e, inv_v=inv_v,
            eps=eps, balance=balance, wu=wu, wv=wv, window=window,
        )
    coef = jnp.stack([
        jnp.float32(alpha), jnp.float32(beta), jnp.float32(inv_e),
        jnp.float32(inv_v), jnp.float32(eps),
    ])
    weighted = wu is not None
    if not weighted:
        wu = wv = jnp.zeros(u.shape, jnp.float32)
    return ebg_commit_block_pallas(
        keep_bits, e_count, v_count, u, v, valid, wu, wv, coef,
        balance=balance, weighted=weighted, window=window, interpret=interpret,
    )


def decode_attention(
    q, k, v, *, softcap: float = 0.0,
    impl: str | None = None, block_s: int = 512, interpret: bool | None = None,
):
    """Single-token GQA decode attention over a KV cache."""
    impl, interpret = _resolve_impl(impl, interpret)
    if impl == "ref":
        return ref.decode_attention_ref(q, k, v, softcap=softcap)
    return decode_attention_pallas(q, k, v, softcap=softcap, block_s=block_s, interpret=interpret)


def pack_keep_bits(keep_bool: jax.Array) -> jax.Array:
    """[p, V] bool -> [p, ceil(V/32)] uint32 packed bitset."""
    p, V = keep_bool.shape
    pad = (-V) % 32
    kb = jnp.pad(keep_bool, ((0, 0), (0, pad)))
    words = kb.reshape(p, -1, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(words << shifts[None, None, :], axis=-1, dtype=jnp.uint32)
