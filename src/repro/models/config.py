"""Model configuration for all assigned architectures.

A model is a stack of GROUPS scanned `n_groups` times; each group is a
fixed tuple of layer specs (attention / ssm variants + mlp / moe). This
keeps the lowered HLO small (one group body) while expressing the
heterogeneous patterns (gemma2 local/global alternation, jamba 1:7
mamba:attention interleave with MoE every other layer).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str  # "attn" | "ssm"
    mlp: str  # "dense" | "moe" | "none"
    sliding_window: Optional[int] = None  # local attention window (gemma2)
    cross_attn: bool = False  # enc-dec decoder layers


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int  # per-expert hidden size
    capacity_factor: float = 1.25  # per-expert buffer = T*k/E * this


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64  # SSD head dim (d_inner / n_heads)
    chunk: int = 256  # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 → d_model // n_heads
    group: Sequence[LayerSpec] = ()  # layer pattern; scanned n_layers/len(group) times
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_softcap: float = 0.0
    logit_softcap: float = 0.0
    rope_theta: float = 10000.0
    mrope_sections: Optional[tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    tie_embeddings: bool = False
    # enc-dec
    n_enc_layers: int = 0  # >0 → encoder-decoder
    # modality frontend stub: model consumes precomputed embeddings
    frontend: Optional[str] = None  # None | "audio" | "vision"
    act: str = "silu"  # mlp activation
    norm_eps: float = 1e-6
    # which shapes are runnable (DESIGN.md §4): full-attention archs skip long_500k
    sub_quadratic: bool = False
    decoder: bool = True  # False → encoder-only (no decode shapes)

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_groups(self) -> int:
        assert self.n_layers % len(self.group) == 0, (self.name, self.n_layers, len(self.group))
        return self.n_layers // len(self.group)

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    def num_params(self) -> int:
        """Total parameter count (embedding + layers), for roofline math."""
        d, h = self.d_model, self.head_dim_
        total = self.vocab * d * (1 if self.tie_embeddings else 2)

        def layer_params(spec: LayerSpec) -> int:
            n = 2 * d  # 2 rmsnorm scales
            if spec.kind == "attn":
                qkv = d * h * (self.n_heads + 2 * self.n_kv_heads)
                n += qkv + self.n_heads * h * d
                if spec.cross_attn:
                    n += qkv + self.n_heads * h * d + d
            else:  # ssm
                s = self.ssm
                d_in = s.expand * d
                # in_proj (x, z, B, C, dt) + conv + out_proj (approximate mamba2)
                nh = d_in // s.head_dim
                n += d * (2 * d_in + 2 * s.d_state + nh) + d_in * s.d_conv + d_in * d + 2 * nh
            if spec.mlp == "dense":
                n += 3 * d * self.d_ff
            elif spec.mlp == "moe":
                n += self.moe.num_experts * 3 * d * self.moe.d_ff + d * self.moe.num_experts
            return n

        per_group = sum(layer_params(s) for s in self.group)
        total += per_group * self.n_groups
        if self.is_encdec:
            enc_spec = LayerSpec(kind="attn", mlp="dense")
            total += self.n_enc_layers * layer_params(enc_spec)
        return total

    def num_active_params(self) -> int:
        """Active parameters per token (MoE: top_k of num_experts)."""
        if self.moe is None:
            return self.num_params()
        full = self.num_params()
        moe_layers = sum(1 for s in self.group if s.mlp == "moe") * self.n_groups
        all_experts = moe_layers * self.moe.num_experts * 3 * self.d_model * self.moe.d_ff
        active = moe_layers * self.moe.top_k * 3 * self.d_model * self.moe.d_ff
        return full - all_experts + active


def dense_group(n: int = 1, **kw) -> tuple[LayerSpec, ...]:
    return tuple(LayerSpec(kind="attn", mlp="dense", **kw) for _ in range(n))
