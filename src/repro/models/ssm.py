"""Mamba2 SSD (state-space duality) block — chunked parallel form + O(1) decode.

Implements the chunk decomposition from the Mamba2 paper: within-chunk
quadratic ("attention-like") term on the MXU + cross-chunk linear state
recurrence, which is the TPU-native way to run an SSM over long sequences
(the sequential scan form would serialize the MXU).

Shapes: x [B, S, d_model] → d_inner = expand*d_model split into
nh = d_inner/head_dim heads of size P; state size N per head (one shared
B/C group, as in mamba2's default ngroups=1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import rms_norm


def _segsum(a: jax.Array) -> jax.Array:
    """a: [..., L] → out[..., i, j] = sum_{k=j+1..i} a_k  (i >= j), -inf else."""
    L = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]  # sum_{j+1..i} = cum_i - cum_j
    ii = jnp.arange(L)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def pick_chunk(S: int, chunk: int) -> int:
    """Largest divisor of S that is <= chunk (trace-time helper)."""
    c = min(chunk, S)
    while S % c:
        c -= 1
    return c


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int):
    """SSD forward.

    x: [b, s, h, p]; dt: [b, s, h] (positive); A: [h] (negative);
    Bm, Cm: [b, s, n] (single group). Returns y [b, s, h, p] and the final
    state [b, h, p, n].
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    assert s % chunk == 0
    nc, cl = s // chunk, chunk

    xr = x.reshape(b, nc, cl, h, p)
    dtr = dt.reshape(b, nc, cl, h)
    Br = Bm.reshape(b, nc, cl, n)
    Cr = Cm.reshape(b, nc, cl, n)
    a = dtr * A[None, None, None, :]  # [b,nc,cl,h] log-decay per step
    a_hsplit = jnp.moveaxis(a, -1, 2)  # [b,nc,h,cl]

    # 1) within-chunk (diagonal blocks): attention-like quadratic term.
    L = jnp.exp(_segsum(a_hsplit))  # [b,nc,h,cl,cl]
    scores = jnp.einsum("bcin,bcjn->bcij", Cr, Br)  # [b,nc,cl,cl]
    y_diag = jnp.einsum("bcij,bchij,bcjh,bcjhp->bcihp", scores, L, dtr, xr)

    # 2) chunk-final states: decayed sum of inputs within each chunk.
    a_cum = jnp.cumsum(a_hsplit, axis=-1)  # [b,nc,h,cl]
    a_tail = a_cum[..., -1:] - a_cum  # decay from step j to chunk end
    states = jnp.einsum("bchj,bcjh,bcjn,bcjhp->bchpn", jnp.exp(a_tail), dtr, Br, xr)

    # 3) cross-chunk recurrence: H_c = H_{c-1}·exp(sum a_c) + states_c.
    chunk_decay = jnp.exp(a_cum[..., -1])  # [b,nc,h]

    def step(carry, inp):
        dec, st = inp
        new = carry * dec[..., None, None] + st
        return new, carry  # emit the state *entering* this chunk

    init = jnp.zeros((b, h, p, n), jnp.float32)
    last, h_prev = jax.lax.scan(
        step,
        init,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)),
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)  # [b,nc,h,p,n]

    # 4) off-diagonal contribution: decayed incoming state read by C.
    decay_in = jnp.exp(a_cum)  # [b,nc,h,cl]: decay from chunk start to step i
    y_off = jnp.einsum("bcin,bchi,bchpn->bcihp", Cr, decay_in, h_prev)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, last


def ssm_block(cfg: ModelConfig, p: dict, x: jax.Array, *, cache: dict | None = None):
    """Full mamba2 mixer. x: [B, S, d]. cache: {"state": [B,h,p,n], "conv": [B,K-1,c]}."""
    s = cfg.ssm
    B_, S, d = x.shape
    d_in = s.expand * d
    nh = d_in // s.head_dim
    n = s.d_state

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xs, Bm, Cm, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1
    )
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)  # [B,S,c]; c = d_in+2n

    K = s.d_conv
    if cache is not None:
        prev = cache["conv"]  # [B, K-1, c]
        padded = jnp.concatenate([prev, conv_in], axis=1)
        new_conv_state = padded[:, -(K - 1) :, :]
    else:
        padded = jnp.pad(conv_in, ((0, 0), (K - 1, 0), (0, 0)))
        new_conv_state = padded[:, -(K - 1) :, :]
    # causal depthwise conv.
    conv_out = sum(
        padded[:, i : i + S, :] * p["conv_w"][i][None, None, :] for i in range(K)
    )
    conv_out = jax.nn.silu(conv_out + p["conv_b"][None, None, :])
    xs, Bm, Cm = jnp.split(conv_out, [d_in, d_in + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])  # [B,S,nh]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # [nh]
    xh = xs.astype(jnp.float32).reshape(B_, S, nh, s.head_dim)

    if cache is not None and S > 1:
        # Prefill with a fresh cache: chunked SSD from zero state (the
        # engine only prefills into empty caches), keep the final state.
        cl = pick_chunk(S, s.chunk)
        y, state = ssd_chunked(xh, dt, A, Bm.astype(jnp.float32), Cm.astype(jnp.float32), cl)
        new_cache = dict(state=state, conv=new_conv_state)
    elif cache is not None:
        # O(1) decode: state update per step (S is small, typically 1).
        state = cache["state"]  # [B,nh,p,n]

        def one(state, inp):
            xt, dtt, Bt, Ct = inp  # [B,nh,p],[B,nh],[B,n],[B,n]
            dec = jnp.exp(dtt * A[None, :])  # [B,nh]
            state = state * dec[..., None, None] + jnp.einsum(
                "bh,bn,bhp->bhpn", dtt, Bt, xt
            )
            y = jnp.einsum("bn,bhpn->bhp", Ct, state)
            return state, y

        xt = jnp.moveaxis(xh, 1, 0)
        state, ys = jax.lax.scan(
            one,
            state,
            (xt, jnp.moveaxis(dt, 1, 0), jnp.moveaxis(Bm.astype(jnp.float32), 1, 0), jnp.moveaxis(Cm.astype(jnp.float32), 1, 0)),
        )
        y = jnp.moveaxis(ys, 0, 1)  # [B,S,nh,p]
        new_cache = dict(state=state, conv=new_conv_state)
    else:
        y, state = ssd_chunked(xh, dt, A, Bm.astype(jnp.float32), Cm.astype(jnp.float32), pick_chunk(S, s.chunk))
        new_cache = dict(state=state, conv=new_conv_state)

    y = y + xh * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B_, S, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"]), new_cache


def init_ssm(cfg: ModelConfig, key, dtype) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    nh = d_in // s.head_dim
    n = s.d_state
    c = d_in + 2 * n
    e = 2 * d_in + 2 * n + nh
    ks = jax.random.split(key, 3)
    return {
        "in_proj": (jax.random.normal(ks[0], (d, e)) * d ** -0.5).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, c)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((c,), dtype),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "out_norm": jnp.zeros((d_in,), dtype),
        "out_proj": (jax.random.normal(ks[2], (d_in, d)) * d_in ** -0.5).astype(dtype),
    }
