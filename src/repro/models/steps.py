"""Train / prefill / decode step functions — the units the launcher jits
and the dry-run lowers.

Batch dicts (see launch/shapes.py input_specs):
  train:   {tokens|embeds, targets, (enc_tokens|enc_embeds)}
  prefill: {tokens|embeds, (enc_*)}                → caches + last logits
  decode:  {token [B,1]|embed, caches, (enc_*)}    → next logits + caches
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import forward, init_caches
from repro.optim.adam import AdamWConfig, apply_updates


def lm_loss(cfg: ModelConfig, params, batch, *, remat: bool = True,
            vocab_parallel: bool = False):
    logits, _ = forward(
        cfg, params,
        tokens=batch.get("tokens"),
        embeds=batch.get("embeds"),
        enc_tokens=batch.get("enc_tokens"),
        enc_embeds=batch.get("enc_embeds"),
        remat=remat,
    )
    targets = batch["targets"]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    if vocab_parallel:
        # Megatron-style: with logits sharded on vocab, take_along_axis
        # forces an all-gather of the full [B,S,V] tensor. A one-hot
        # contraction keeps the reduction local per vocab shard and
        # all-reduces only [B,S] scalars.
        onehot = jax.nn.one_hot(targets, cfg.vocab, dtype=logits.dtype)
        tgt = jnp.einsum("bsv,bsv->bs", logits, onehot).astype(jnp.float32)
    else:
        tgt = jnp.take_along_axis(
            logits.astype(jnp.float32), targets[..., None], axis=-1
        )[..., 0]
    nll = lse - tgt
    return nll.mean(), dict(loss=nll.mean())


def make_train_step(cfg: ModelConfig, opt: AdamWConfig, *, remat: bool = True,
                    vocab_parallel: bool = False):
    def train_step(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, batch, remat=remat,
                              vocab_parallel=vocab_parallel),
            has_aux=True,
        )(params)
        params, opt_state, om = apply_updates(params, grads, opt_state, opt)
        return params, opt_state, dict(loss=loss, **om)

    return train_step


def make_prefill_step(cfg: ModelConfig, max_seq: int):
    def prefill_step(params, batch):
        B = (batch.get("tokens") if "tokens" in batch else batch["embeds"]).shape[0]
        caches = init_caches(cfg, B, max_seq)
        logits, caches = forward(
            cfg, params,
            tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
            enc_tokens=batch.get("enc_tokens"),
            enc_embeds=batch.get("enc_embeds"),
            caches=caches,
            cache_pos=jnp.int32(0),
            remat=False,
        )
        return logits[:, -1], caches

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One decode step: token [B,1] + caches → logits [B,vocab] + caches."""

    def serve_step(params, caches, batch):
        pos = batch["pos"]  # [] int32: current length of the cache
        logits, caches = forward(
            cfg, params,
            tokens=batch.get("token"),
            embeds=batch.get("embed"),
            enc_embeds=batch.get("enc_embeds"),
            enc_tokens=batch.get("enc_tokens"),
            enc_out=batch.get("enc_out"),  # precomputed at prefill (enc-dec)
            caches=caches,
            cache_pos=pos,
            remat=False,
        )
        return logits[:, -1], caches

    return serve_step
