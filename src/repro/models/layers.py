"""Transformer layer primitives (pure-functional JAX).

Covers every attention variant the assigned architectures need: GQA,
sliding-window (gemma2 local layers), attention/logit soft-capping, QK-norm
(qwen3), QKV bias (qwen2), RoPE and M-RoPE (qwen2-vl), cross-attention
(seamless enc-dec). bf16 params / f32 accumulation throughout.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

NEG_INF = -2.0e38


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def _rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float, mrope_sections=None) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S] or [3, B, S] for M-RoPE."""
    D = x.shape[-1]
    freqs = _rope_freqs(D, theta)  # [D/2]
    if positions.ndim == 2:
        angles = positions[..., None].astype(jnp.float32) * freqs  # [B,S,D/2]
    else:
        # M-RoPE: frequency bands partitioned into (t, h, w) sections.
        t_sec, h_sec, w_sec = mrope_sections
        sec = jnp.concatenate(
            [jnp.zeros(t_sec, jnp.int32), jnp.ones(h_sec, jnp.int32), jnp.full(w_sec, 2, jnp.int32)]
        )  # [D/2] → which positional stream drives each band
        pos = jnp.take(positions, sec, axis=0)  # [D/2, B, S]
        angles = jnp.moveaxis(pos, 0, -1).astype(jnp.float32) * freqs  # [B,S,D/2]
    sin, cos = jnp.sin(angles)[:, :, None, :], jnp.cos(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _attn_mask(S_q: int, S_kv: int, *, causal: bool, window: Optional[int], offset: int = 0):
    """[S_q, S_kv] additive mask. `offset` = absolute position of query 0."""
    q_pos = jnp.arange(S_q)[:, None] + offset
    k_pos = jnp.arange(S_kv)[None, :]
    ok = jnp.ones((S_q, S_kv), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= k_pos > q_pos - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def attention(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,  # [B, S, d]
    positions: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    kv_x: Optional[jax.Array] = None,  # cross-attention source
    cache: Optional[dict] = None,  # {"k","v": [B,Smax,Hkv,hd], "pos": scalar}
) -> tuple[jax.Array, Optional[dict]]:
    B, S, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    src = x if kv_x is None else kv_x
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if kv_x is None:  # RoPE only for self-attention
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        kv_pos = positions if cache is None else positions
        k = apply_rope(k, kv_pos, cfg.rope_theta, cfg.mrope_sections)

    if cache is not None:
        # Decode: write this step's K/V at `pos`, attend over the full cache.
        pos = cache["pos"]
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
        k, v = ck, cv
        new_cache = dict(k=ck, v=cv, pos=pos + S)
        S_kv = k.shape[1]
        q_pos = pos + jnp.arange(S)[:, None]  # absolute query positions
        k_pos = jnp.arange(S_kv)[None, :]
        kmask = k_pos <= q_pos  # causal over written slots
        if window is not None:
            kmask &= k_pos > q_pos - window
        mask = jnp.where(kmask, 0.0, NEG_INF).astype(jnp.float32)
    else:
        new_cache = None
        S_kv = k.shape[1]
        mask = _attn_mask(S, S_kv, causal=causal and kv_x is None, window=window)

    G = H // Hkv
    qf = q.astype(jnp.float32).reshape(B, S, Hkv, G, hd)
    scores = jnp.einsum("bshgk,bthk->bhgst", qf, k.astype(jnp.float32)) / jnp.sqrt(hd)
    if cfg.attn_softcap:
        scores = cfg.attn_softcap * jnp.tanh(scores / cfg.attn_softcap)
    scores = scores + mask
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgst,bthk->bshgk", w, v.astype(jnp.float32))
    out = out.reshape(B, S, H, hd).astype(x.dtype)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


def mlp(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = act(jnp.einsum("bsd,df->bsf", x, p["w_gate"])) * jnp.einsum("bsd,df->bsf", x, p["w_in"])
    return jnp.einsum("bsf,fd->bsd", h, p["w_out"])


# ------------------------------------------------------------------ init


def init_attention(cfg: ModelConfig, key, dtype, cross: bool = False) -> dict:
    H, Hkv, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_, cfg.d_model
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, H, hd)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, Hkv, hd)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, Hkv, hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (H, hd, d)) * (H * hd) ** -0.5).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((Hkv, hd), dtype)
        p["bv"] = jnp.zeros((Hkv, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def init_mlp(cfg: ModelConfig, key, dtype, d_ff: Optional[int] = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": (jax.random.normal(ks[0], (d, f)) * d ** -0.5).astype(dtype),
        "w_in": (jax.random.normal(ks[1], (d, f)) * d ** -0.5).astype(dtype),
        "w_out": (jax.random.normal(ks[2], (f, d)) * f ** -0.5).astype(dtype),
    }
