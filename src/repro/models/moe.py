"""Mixture-of-Experts FFN with capacity-based sorted dispatch (EP-shardable).

Dispatch is the sort-and-segment pattern (no [T,E,C] one-hot tensors):
assignments are argsorted by expert, ranked within expert, capacity-dropped,
scattered into an [E, C, d] buffer, run through a grouped SwiGLU einsum
(the leading E axis shards over the `model`/EP mesh axis → the all-to-alls
GSPMD inserts around the scatter/gather ARE the MoE dispatch collectives),
and combined back with router gates.

EBG hook (beyond-paper, DESIGN.md §4): `expert_permutation` from
repro.core.placement reorders expert ids before sharding so that hot
(co-activated) experts land on different devices — the paper's balance
objective applied to the token→expert routing graph.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.compat import shard_map_compat
from repro.models import pspec
from repro.models.config import ModelConfig


def moe_ffn(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,  # [B, S, d]
    *,
    expert_perm: Optional[jax.Array] = None,
) -> jax.Array:
    m = cfg.moe
    capacity_factor = m.capacity_factor
    B, S, d = x.shape
    T = B * S
    E, k = m.num_experts, m.top_k
    xf = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xf, p["router"].astype(x.dtype)).astype(jnp.float32)
    gate_logits, expert_idx = jax.lax.top_k(logits, k)  # [T, k]
    gates = jax.nn.softmax(gate_logits, axis=-1)
    if expert_perm is not None:  # EBG placement: reorder expert ids
        expert_idx = expert_perm[expert_idx]

    cap = int(T * k / E * capacity_factor)
    cap = max(8, -(-cap // 8) * 8)

    flat_e = expert_idx.reshape(-1)  # [T*k]
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    rank = jnp.arange(T * k) - starts[sorted_e]
    keep = rank < cap
    token_of = order // k

    safe_rank = jnp.where(keep, rank, 0)
    buf = jnp.zeros((E, cap, d), x.dtype)
    buf = buf.at[sorted_e, safe_rank].add(
        jnp.where(keep[:, None], xf[token_of], 0).astype(x.dtype)
    )
    buf = pspec.constrain(buf, "tp", None, None)  # EP: experts over model axis

    # Grouped expert SwiGLU — leading E axis is the EP shard axis.
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["w_in"]
    )
    out = jnp.einsum("ecf,efd->ecd", h, p["w_out"])

    contrib = out[sorted_e, safe_rank]  # [T*k, d]
    gate_sorted = gates.reshape(-1)[order]
    contrib = jnp.where(keep[:, None], contrib * gate_sorted[:, None].astype(x.dtype), 0)
    y = jnp.zeros((T, d), x.dtype).at[token_of].add(contrib)
    y = pspec.constrain(y, "dp", None)
    return y.reshape(B, S, d)


def _moe_body(cfg: ModelConfig, xb, router, wg, wi, wo, *, tp_axis: str):
    """Per-EP-shard MoE: tokens are model-replicated, so each shard gathers
    ITS experts' tokens locally (no dispatch collective at all) and the
    combine is one psum of [T_loc, d] partial outputs — ~E·C·d/(T·d) times
    fewer bytes than GSPMD's full-buffer all-reduce."""
    m = cfg.moe
    E, k = m.num_experts, m.top_k
    E_loc = wg.shape[0]
    j = jax.lax.axis_index(tp_axis)
    Tl, d = xb.shape

    logits = jnp.einsum("td,de->te", xb, router.astype(xb.dtype)).astype(jnp.float32)
    gate_logits, expert_idx = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(gate_logits, axis=-1)

    cap = int(Tl * k / E * m.capacity_factor)
    cap = max(8, -(-cap // 8) * 8)

    flat_e = expert_idx.reshape(-1) - j * E_loc  # local expert ids
    mine = (flat_e >= 0) & (flat_e < E_loc)
    sort_key = jnp.where(mine, flat_e, E_loc)  # foreign → dump bucket
    order = jnp.argsort(sort_key)
    sorted_e = sort_key[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E_loc), side="left")
    safe_e = jnp.clip(sorted_e, 0, E_loc - 1)
    rank = jnp.arange(Tl * k) - starts[safe_e]
    keep = (sorted_e < E_loc) & (rank >= 0) & (rank < cap)
    token_of = order // k
    safe_rank = jnp.where(keep, rank, 0)

    buf = jnp.zeros((E_loc, cap, d), xb.dtype)
    buf = buf.at[safe_e, safe_rank].add(
        jnp.where(keep[:, None], xb[token_of], 0).astype(xb.dtype)
    )
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * jnp.einsum(
        "ecd,edf->ecf", buf, wi
    )
    out = jnp.einsum("ecf,efd->ecd", h, wo)

    contrib = out[safe_e, safe_rank]
    gate_sorted = gates.reshape(-1)[order]
    contrib = jnp.where(keep[:, None], contrib * gate_sorted[:, None].astype(xb.dtype), 0)
    y = jnp.zeros((Tl, d), xb.dtype).at[token_of].add(contrib)
    return jax.lax.psum(y, tp_axis)


def moe_ffn_ep(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    """shard_map EP dispatch (plan `ep`); falls back to moe_ffn off-mesh."""
    from jax.sharding import PartitionSpec as P

    ctx = pspec.ep_shard_map()
    if ctx is None:
        return moe_ffn(cfg, p, x)
    mesh, dp, tp = ctx
    B, S, d = x.shape
    xf = x.reshape(B * S, d)
    body = lambda xb, router, wg, wi, wo: _moe_body(
        cfg, xb, router, wg, wi, wo, tp_axis=tp
    )
    y = shard_map_compat(
        body,
        mesh=mesh,
        in_specs=(P(dp, None), P(None, None), P(tp, None, None),
                  P(tp, None, None), P(tp, None, None)),
        out_specs=P(dp, None),
    )(xf, p["router"], p["w_gate"], p["w_in"], p["w_out"])
    return y.reshape(B, S, d)


def aux_load_balance_loss(logits: jax.Array, expert_idx: jax.Array, num_experts: int) -> jax.Array:
    """Switch-style auxiliary loss: E[fraction routed] x E[router prob]."""
    probs = jax.nn.softmax(logits, axis=-1)
    frac = jnp.mean(
        jax.nn.one_hot(expert_idx[..., 0], num_experts, dtype=jnp.float32), axis=0
    )
    return num_experts * jnp.sum(frac * probs.mean(axis=0))


def init_moe(cfg: ModelConfig, key, dtype) -> dict:
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff, m.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": (jax.random.normal(ks[0], (d, E)) * d ** -0.5).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d, f)) * d ** -0.5).astype(dtype),
        "w_in": (jax.random.normal(ks[2], (E, d, f)) * d ** -0.5).astype(dtype),
        "w_out": (jax.random.normal(ks[3], (E, f, d)) * f ** -0.5).astype(dtype),
    }
