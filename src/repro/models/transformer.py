"""Model assembly: decoder-only / encoder-decoder / hybrid stacks.

Layers are organized as GROUPS (cfg.group = tuple of LayerSpec) scanned
n_groups times — one lowered group body regardless of depth, which keeps
dry-run compiles fast and enables jax.checkpoint per group (remat policy).
Caches (KV / SSM state / conv state) are pytrees stacked along the group
axis and threaded through the same scan.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import pspec
from repro.models import ssm as SSM
from repro.models.config import LayerSpec, ModelConfig
from repro.models.pspec import constrain


# ----------------------------------------------------------------- params


def _init_layer(cfg: ModelConfig, spec: LayerSpec, key, dtype) -> dict:
    ks = iter(jax.random.split(key, 8))
    p: dict = {"pre_norm": jnp.zeros((cfg.d_model,), dtype)}
    if spec.kind == "attn":
        p["attn"] = L.init_attention(cfg, next(ks), dtype)
    else:
        p["ssm"] = SSM.init_ssm(cfg, next(ks), dtype)
    if spec.cross_attn:
        p["cross_norm"] = jnp.zeros((cfg.d_model,), dtype)
        p["cross"] = L.init_attention(cfg, next(ks), dtype, cross=True)
    if spec.mlp == "dense":
        p["mlp_norm"] = jnp.zeros((cfg.d_model,), dtype)
        p["mlp"] = L.init_mlp(cfg, next(ks), dtype)
    elif spec.mlp == "moe":
        p["mlp_norm"] = jnp.zeros((cfg.d_model,), dtype)
        p["moe"] = MOE.init_moe(cfg, next(ks), dtype)
    return p


def _init_group(cfg: ModelConfig, group, key, dtype) -> dict:
    ks = jax.random.split(key, len(group))
    return {f"layer_{i}": _init_layer(cfg, spec, ks[i], dtype) for i, spec in enumerate(group)}


def init_params(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> dict:
    k_emb, k_groups, k_enc, k_out = jax.random.split(key, 4)
    d = cfg.d_model
    params: dict = {
        "embed": (jax.random.normal(k_emb, (cfg.vocab, d)) * d ** -0.5).astype(dtype),
        "final_norm": jnp.zeros((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (jax.random.normal(k_out, (d, cfg.vocab)) * d ** -0.5).astype(dtype)
    gkeys = jax.random.split(k_groups, cfg.n_groups)
    params["groups"] = jax.vmap(lambda k: _init_group(cfg, cfg.group, k, dtype))(gkeys)
    if cfg.is_encdec:
        enc_spec = (LayerSpec(kind="attn", mlp="dense"),)
        ekeys = jax.random.split(k_enc, cfg.n_enc_layers)
        params["enc_groups"] = jax.vmap(lambda k: _init_group(cfg, enc_spec, k, dtype))(ekeys)
        params["enc_final_norm"] = jnp.zeros((d,), dtype)
    return params


# ---------------------------------------------------------------- forward


def _apply_layer(cfg, spec: LayerSpec, p, x, positions, *, causal, enc_out=None, cache=None):
    new_cache = {}
    h = L.rms_norm(x, p["pre_norm"], cfg.norm_eps)
    if spec.kind == "attn":
        h, kv = L.attention(
            cfg, p["attn"], h, positions,
            causal=causal, window=spec.sliding_window,
            cache=None if cache is None else cache["kv"],
        )
        if cache is not None:
            new_cache["kv"] = kv
    else:
        h, st = SSM.ssm_block(cfg, p["ssm"], h, cache=None if cache is None else cache["ssm"])
        if cache is not None:
            new_cache["ssm"] = st
    x = x + h
    if spec.cross_attn:
        h = L.rms_norm(x, p["cross_norm"], cfg.norm_eps)
        h, _ = L.attention(cfg, p["cross"], h, positions, causal=False, kv_x=enc_out)
        x = x + h
    if spec.mlp != "none":
        h = L.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
        h = L.mlp(cfg, p["mlp"], h) if spec.mlp == "dense" else MOE.moe_ffn_ep(cfg, p["moe"], h)
        x = x + h
    return x, new_cache


def _run_stack(cfg, groups_params, group_spec, x, positions, *, causal, enc_out=None, caches=None, remat=True):
    """Scan over stacked groups. caches: pytree with leading n_groups axis."""

    def group_fn(carry, scanned):
        xc = constrain(carry, "dp", "sp", None)
        gp = scanned[0]
        gc = scanned[1] if caches is not None else None
        new_gc = {}
        for i, spec in enumerate(group_spec):
            lc = None if gc is None else gc[f"layer_{i}"]
            xc, nc = _apply_layer(
                cfg, spec, gp[f"layer_{i}"], xc, positions,
                causal=causal, enc_out=enc_out, cache=lc,
            )
            new_gc[f"layer_{i}"] = nc
        return xc, (new_gc if caches is not None else None)

    fn = jax.checkpoint(group_fn) if remat else group_fn
    xs = (groups_params,) if caches is None else (groups_params, caches)
    n_groups = jax.tree.leaves(groups_params)[0].shape[0]
    unroll = n_groups if pspec.scan_unroll() else 1
    x, new_caches = jax.lax.scan(fn, x, xs, unroll=unroll)
    return x, new_caches


def forward(
    cfg: ModelConfig,
    params: dict,
    *,
    tokens: Optional[jax.Array] = None,  # [B, S]
    embeds: Optional[jax.Array] = None,  # [B, S, d] (modality frontend stub)
    positions: Optional[jax.Array] = None,
    enc_tokens: Optional[jax.Array] = None,
    enc_embeds: Optional[jax.Array] = None,
    enc_out: Optional[jax.Array] = None,  # precomputed encoder output (decode)
    caches=None,
    cache_pos: Optional[jax.Array] = None,
    remat: bool = True,
):
    """Returns (logits [B,S,vocab], new_caches)."""
    if embeds is None:
        x = params["embed"][tokens]
    else:
        x = embeds
    x = constrain(x, "dp", "sp", None)
    B, S = x.shape[:2]
    if positions is None:
        base = jnp.arange(S)[None, :] + (0 if cache_pos is None else cache_pos)
        positions = jnp.broadcast_to(base, (B, S))
        if cfg.mrope_sections is not None:
            positions = jnp.broadcast_to(positions[None], (3, B, S))

    if cfg.is_encdec and enc_out is None:
        if enc_embeds is None and enc_tokens is not None:
            enc_embeds = params["embed"][enc_tokens]
        if enc_embeds is not None:
            Se = enc_embeds.shape[1]
            enc_pos = jnp.broadcast_to(jnp.arange(Se)[None, :], (B, Se))
            enc_spec = (LayerSpec(kind="attn", mlp="dense"),)
            enc_out, _ = _run_stack(
                cfg, params["enc_groups"], enc_spec, enc_embeds, enc_pos,
                causal=False, remat=remat,
            )
            enc_out = L.rms_norm(enc_out, params["enc_final_norm"], cfg.norm_eps)

    x, new_caches = _run_stack(
        cfg, params["groups"], cfg.group, x, positions,
        causal=True, enc_out=enc_out, caches=caches, remat=remat and caches is None,
    )
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", x, unembed)
    logits = constrain(logits, "dp", "sp", "tp")  # vocab-parallel logits
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits, new_caches


# ----------------------------------------------------------------- caches


def init_caches(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    """Cache pytree stacked along the group axis (leading dim n_groups)."""

    def one_layer(spec: LayerSpec):
        c = {}
        if spec.kind == "attn":
            c["kv"] = dict(
                k=jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.head_dim_), dtype),
                v=jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.head_dim_), dtype),
                pos=jnp.int32(0),
            )
        else:
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            nh = d_in // s.head_dim
            c["ssm"] = dict(
                state=jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
                conv=jnp.zeros((batch, s.d_conv - 1, d_in + 2 * s.d_state), dtype),
            )
        return c

    group_cache = {f"layer_{i}": one_layer(spec) for i, spec in enumerate(cfg.group)}
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_groups,) + x.shape), group_cache
    )
