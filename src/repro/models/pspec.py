"""Activation-sharding context: the launcher declares which mesh axes carry
data parallelism / tensor parallelism, and the model applies
with_sharding_constraint at group boundaries so GSPMD never silently
replicates activations (the embedding gather otherwise drops the batch
sharding and every downstream tensor blows up replicated).
"""
from __future__ import annotations

import contextlib
import math
import threading

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


@contextlib.contextmanager
def activation_axes(mesh, dp=("data",), tp="model", sp=None, unroll_scan=False,
                    ep_shard_map=False):
    """dp: data-parallel axes (batch dim); tp: tensor axis; sp: sequence axis.

    unroll_scan=True unrolls the layer-group scan at lowering time — the
    dry-run uses it so cost_analysis counts every layer (XLA reports a
    while-loop body's FLOPs once, not x trip count).
    ep_shard_map=True routes MoE through the manual shard_map dispatch
    (local expert gather + psum combine) instead of GSPMD's scatter.
    """
    sizes = dict(mesh.shape)
    prev = getattr(_state, "axes", None)
    _state.axes = dict(dp=dp, tp=tp, sp=sp, sizes=sizes, unroll_scan=unroll_scan,
                       ep_shard_map=ep_shard_map, mesh=mesh)
    try:
        yield
    finally:
        _state.axes = prev


def scan_unroll() -> bool:
    a = axes()
    return bool(a and a.get("unroll_scan"))


def ep_shard_map():
    """Returns (mesh, dp_axes, tp_axis) when the manual EP path is on."""
    a = axes()
    if a and a.get("ep_shard_map"):
        return a["mesh"], a["dp"], a["tp"]
    return None


def axes():
    return getattr(_state, "axes", None)


def _size(sizes, v) -> int:
    if v is None:
        return 1
    if isinstance(v, str):
        return sizes.get(v, 1)
    return math.prod(sizes.get(a, 1) for a in v)


def constrain(x, *dims):
    """dims entries: 'dp' | 'tp' | 'sp' | None per tensor dim."""
    a = axes()
    if a is None:
        return x
    entries = []
    used: set = set()
    for i, d in enumerate(dims):
        v = a.get(d) if d is not None else None
        flat = (v,) if isinstance(v, str) else tuple(v or ())
        if (v is not None and x.shape[i] % _size(a["sizes"], v) == 0
                and not (set(flat) & used)):
            entries.append(v)
            used |= set(flat)
        else:
            entries.append(None)
    if all(e is None for e in entries):
        return x
    return jax.lax.with_sharding_constraint(x, P(*entries))
