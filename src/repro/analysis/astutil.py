"""Shared AST plumbing for the static-analysis checkers.

Everything here is deliberately resolution-light: we canonicalize names
through each module's import table (``np.asarray`` -> ``numpy.asarray``,
``lax.scan`` -> ``jax.lax.scan``) and resolve calls to module-local or
project-local function definitions by name. No type inference, no
execution — the checkers are grep-with-structure, tuned for zero false
positives on this repo's idioms.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Iterator, Optional


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def build_import_map(tree: ast.Module) -> dict:
    """alias -> fully-qualified dotted prefix, from top-level imports.

    ``import numpy as np``                    -> {"np": "numpy"}
    ``from jax import lax``                   -> {"lax": "jax.lax"}
    ``from repro.kernels import ref``         -> {"ref": "repro.kernels.ref"}
    ``from a.b import f as g``                -> {"g": "a.b.f"}
    """
    imports: dict = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                imports[alias.asname or alias.name.split(".")[0]] = (
                    alias.name if alias.asname else alias.name.split(".")[0]
                )
        elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
            for alias in node.names:
                if alias.name == "*":
                    continue
                imports[alias.asname or alias.name] = f"{node.module}.{alias.name}"
    return imports


def qualify(name: Optional[str], imports: dict) -> Optional[str]:
    """Canonicalize a dotted name through the module's import aliases."""
    if name is None:
        return None
    head, sep, rest = name.partition(".")
    if head in imports:
        return imports[head] + (sep + rest if rest else "")
    return name


def call_qualname(call: ast.Call, imports: dict) -> Optional[str]:
    return qualify(dotted_name(call.func), imports)


def const_value(node: ast.AST):
    """Fold pure-literal arithmetic (1 << 24, 2**24, -1) to a Python value;
    returns None when the expression is not a literal computation."""
    try:
        return ast.literal_eval(node)
    except (ValueError, SyntaxError, TypeError):
        pass
    if isinstance(node, ast.BinOp):
        left, right = const_value(node.left), const_value(node.right)
        if left is None or right is None:
            return None
        try:
            if isinstance(node.op, ast.LShift):
                return left << right
            if isinstance(node.op, ast.Pow):
                return left**right
            if isinstance(node.op, ast.Mult):
                return left * right
            if isinstance(node.op, ast.Sub):
                return left - right
            if isinstance(node.op, ast.Add):
                return left + right
        except (TypeError, ValueError, OverflowError):
            return None
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        inner = const_value(node.operand)
        return None if inner is None else -inner
    return None


@dataclasses.dataclass
class FuncInfo:
    """One function/method definition with its lexical context."""

    qualname: str  # "Class.method" / "outer.<locals>.inner"
    node: ast.AST  # FunctionDef | AsyncFunctionDef | Lambda
    parent: Optional["FuncInfo"]  # enclosing function, if any
    in_class: bool  # direct child of a ClassDef

    @property
    def name(self) -> str:
        return getattr(self.node, "name", "<lambda>")

    @property
    def is_public(self) -> bool:
        """Module-level functions and class methods not starting with '_'."""
        return self.parent is None and not self.name.startswith("_")

    def params(self) -> list:
        a = self.node.args
        return [p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)]

    def positional_params(self) -> list:
        a = self.node.args
        return [p.arg for p in (a.posonlyargs + a.args)]


def iter_functions(tree: ast.Module) -> Iterator[FuncInfo]:
    """All function definitions with qualnames and parent links."""

    def visit(node, prefix: str, parent: Optional[FuncInfo], in_class: bool):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qn = f"{prefix}{child.name}" if prefix else child.name
                info = FuncInfo(qualname=qn, node=child, parent=parent, in_class=in_class)
                yield info
                yield from visit(child, qn + ".<locals>.", info, False)
            elif isinstance(child, ast.ClassDef):
                cp = f"{prefix}{child.name}." if prefix else child.name + "."
                yield from visit(child, cp, parent, True)
            else:
                yield from visit(child, prefix, parent, in_class)

    yield from visit(tree, "", None, False)


def local_function_table(tree: ast.Module) -> dict:
    """name -> module-level FunctionDef node (top level only)."""
    return {
        n.name: n
        for n in tree.body
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
    }


def decorator_is_jit(dec: ast.AST, imports: dict) -> bool:
    """@jax.jit, @jax.jit(...), @functools.partial(jax.jit, ...)."""
    qn = qualify(dotted_name(dec), imports)
    if qn in ("jax.jit", "jax.pmap"):
        return True
    if isinstance(dec, ast.Call):
        fn = qualify(dotted_name(dec.func), imports)
        if fn in ("jax.jit", "jax.pmap"):
            return True
        if fn == "functools.partial" and dec.args:
            return qualify(dotted_name(dec.args[0]), imports) in ("jax.jit", "jax.pmap")
    return False


def jit_call_donated(call: ast.Call, imports: dict) -> Optional[tuple]:
    """If `call` is jax.jit(...)/functools.partial(jax.jit, ...) carrying a
    literal donate_argnums, return the donated positions tuple."""
    fn = qualify(dotted_name(call.func), imports)
    if fn == "functools.partial" and call.args:
        if qualify(dotted_name(call.args[0]), imports) != "jax.jit":
            return None
    elif fn != "jax.jit":
        return None
    for kw in call.keywords:
        if kw.arg in ("donate_argnums", "donate_argnames"):
            val = const_value(kw.value)
            if isinstance(val, int):
                return (val,)
            if isinstance(val, (tuple, list)) and all(isinstance(v, int) for v in val):
                return tuple(val)
    return None


def unparse(node: ast.AST, limit: int = 60) -> str:
    try:
        text = ast.unparse(node)
    except Exception:  # pragma: no cover - unparse is total on 3.9+
        text = "<expr>"
    return text if len(text) <= limit else text[: limit - 3] + "..."
