"""Analyzer core: findings, modules, suppressions, baseline, registry.

The pass is two-phase: every target file is parsed once into a `Module`,
then each registered checker runs either per-module (``scope="module"``)
or once over the whole module set (``scope="project"`` — the
interprocedural checkers: call graphs, registry cross-references, kernel
impl pairs). Findings are filtered through inline/file suppression
comments and the committed baseline before they reach the CLI.

Suppression syntax (see docs/api.md "Static analysis"):

    x = np.asarray(y)          # repro: ignore[HS01]     one line, one code
    x = np.asarray(y)          # repro: ignore           one line, all codes
    # repro: ignore-file[DS01]                           whole file, one code
    # repro: ignore-file                                 whole file, all codes

``# noqa`` on a line additionally suppresses the hygiene codes (UI01/DS01/
MD01/EH01) so existing flake8-style pragmas keep working.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
import re
from pathlib import Path
from typing import Callable, Iterable, Optional

SEVERITIES = ("error", "warning")

_SUPPRESS_RE = re.compile(r"#\s*repro:\s*ignore(?P<file>-file)?(?:\[(?P<codes>[A-Za-z0-9_,\s]+)\])?")
_NOQA_RE = re.compile(r"#\s*noqa\b")
_NOQA_CODES = ("UI01", "DS01", "MD01", "EH01")  # hygiene codes honor plain `# noqa`


@dataclasses.dataclass(frozen=True)
class Finding:
    """One checker hit, anchored to a file position."""

    code: str
    path: str  # repo-relative posix path
    line: int
    col: int
    message: str
    severity: str = "error"
    anchor: str = ""  # enclosing symbol (fingerprint stability across edits)

    @property
    def fingerprint(self) -> str:
        """Line-number-free identity used by the baseline file."""
        digest = hashlib.sha256(self.message.encode()).hexdigest()[:12]
        return f"{self.code}:{self.path}:{self.anchor}:{digest}"

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.code} [{self.severity}] {self.message}"

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["fingerprint"] = self.fingerprint
        return d


@dataclasses.dataclass
class Module:
    """One parsed source file."""

    path: str  # repo-relative posix path
    dotted: str  # best-effort dotted module name ("repro.graph.engine")
    source: str
    tree: ast.Module
    lines: list

    @classmethod
    def from_source(cls, path: str, source: str) -> "Module":
        rel = Path(path).as_posix()
        parts = list(Path(rel).with_suffix("").parts)
        if "src" in parts:
            parts = parts[parts.index("src") + 1 :]
        dotted = ".".join(p for p in parts if p != "__init__")
        return cls(
            path=rel,
            dotted=dotted or rel,
            source=source,
            tree=ast.parse(source, filename=rel),
            lines=source.splitlines(),
        )

    @property
    def name(self) -> str:
        """Last dotted component ("engine" for repro/graph/engine.py)."""
        return self.dotted.rsplit(".", 1)[-1]

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""


class Checker:
    """Base class: subclass, set the class attributes, implement one hook."""

    code: str = ""
    name: str = ""
    description: str = ""
    severity: str = "error"
    scope: str = "module"  # "module" | "project"

    def check_module(self, module: Module, report: Callable) -> None:  # pragma: no cover
        raise NotImplementedError

    def check_project(self, modules: list, report: Callable) -> None:  # pragma: no cover
        raise NotImplementedError


CHECKERS: dict = {}


def register_checker(cls):
    """Class decorator: register a Checker subclass by its code."""
    if not cls.code or not cls.code.isalnum():
        raise ValueError(f"checker {cls!r} needs an alphanumeric `code`")
    if cls.code in CHECKERS:
        raise ValueError(f"checker code {cls.code!r} already registered")
    if cls.severity not in SEVERITIES:
        raise ValueError(f"checker severity must be one of {SEVERITIES}, got {cls.severity!r}")
    CHECKERS[cls.code] = cls()
    return cls


def all_checkers() -> tuple:
    """Registered checker instances, stable order. Importing the checkers
    package is what populates the registry."""
    from repro.analysis import checkers  # noqa: F401  (registration side effect)

    return tuple(CHECKERS[c] for c in sorted(CHECKERS))


def _suppressed(module: Module, finding: Finding, file_directives: list) -> bool:
    for codes in file_directives:
        if codes is None or finding.code in codes:
            return True
    text = module.line_text(finding.line)
    m = _SUPPRESS_RE.search(text)
    if m and not m.group("file"):
        codes = m.group("codes")
        if codes is None or finding.code in {c.strip() for c in codes.split(",")}:
            return True
    if finding.code in _NOQA_CODES and _NOQA_RE.search(text):
        return True
    return False


def _file_directives(module: Module) -> list:
    """All `# repro: ignore-file[...]` directives in the file (None = all codes)."""
    out = []
    for line in module.lines:
        m = _SUPPRESS_RE.search(line)
        if m and m.group("file"):
            codes = m.group("codes")
            out.append(None if codes is None else {c.strip() for c in codes.split(",")})
    return out


def run_checkers(modules: list, select: Optional[Iterable] = None) -> list:
    """Run every (selected) checker over parsed modules; returns findings
    with suppression comments already applied, sorted by position."""
    selected = None if select is None else set(select)
    by_path = {m.path: m for m in modules}
    findings: list = []

    def reporter(checker):
        def report(path, line, col, message, anchor=""):
            findings.append(
                Finding(
                    code=checker.code,
                    path=path,
                    line=int(line),
                    col=int(col),
                    message=message,
                    severity=checker.severity,
                    anchor=anchor,
                )
            )

        return report

    for checker in all_checkers():
        if selected is not None and checker.code not in selected:
            continue
        report = reporter(checker)
        if checker.scope == "project":
            checker.check_project(modules, report)
        else:
            for module in modules:
                checker.check_module(module, report)

    directives = {m.path: _file_directives(m) for m in modules}
    kept = [
        f
        for f in findings
        if f.path not in by_path or not _suppressed(by_path[f.path], f, directives[f.path])
    ]
    kept.sort(key=lambda f: (f.path, f.line, f.col, f.code))
    # A checker may legitimately hit the same position twice via different
    # traversal routes; report each (pos, code, message) once.
    seen, unique = set(), []
    for f in kept:
        key = (f.path, f.line, f.col, f.code, f.message)
        if key not in seen:
            seen.add(key)
            unique.append(f)
    return unique


def collect_files(paths: Iterable, root: Optional[Path] = None) -> list:
    """Expand files/directories into a sorted .py file list."""
    out = []
    for p in paths:
        p = Path(p)
        if not p.is_absolute() and root is not None:
            p = root / p
        if p.is_dir():
            out.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            out.append(p)
    seen, files = set(), []
    for f in out:
        r = f.resolve()
        if r not in seen:
            seen.add(r)
            files.append(f)
    return files


def load_modules(files: Iterable, rel_root: Optional[Path] = None) -> list:
    modules = []
    for f in files:
        f = Path(f)
        rel = f
        if rel_root is not None:
            try:
                rel = f.resolve().relative_to(Path(rel_root).resolve())
            except ValueError:
                rel = f
        modules.append(Module.from_source(str(rel), f.read_text()))
    return modules


def analyze_sources(sources: dict, select: Optional[Iterable] = None) -> list:
    """Analyze in-memory sources: {relpath: code} -> findings (test seam)."""
    return run_checkers([Module.from_source(p, s) for p, s in sources.items()], select)


def analyze_paths(
    paths: Iterable,
    *,
    root: Optional[Path] = None,
    select: Optional[Iterable] = None,
) -> list:
    files = collect_files(paths, root=root)
    return run_checkers(load_modules(files, rel_root=root), select)


# ------------------------------------------------------------------ baseline


def load_baseline(path) -> set:
    """Fingerprint set from a committed baseline file (empty set if absent)."""
    p = Path(path)
    if not p.exists():
        return set()
    data = json.loads(p.read_text() or "{}")
    return set(data.get("findings", []))


def apply_baseline(findings: list, baseline: set) -> list:
    return [f for f in findings if f.fingerprint not in baseline]


def write_baseline(findings: list, path) -> None:
    Path(path).write_text(
        json.dumps({"findings": sorted(f.fingerprint for f in findings)}, indent=2) + "\n"
    )
