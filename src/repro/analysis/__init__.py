"""repro.analysis — tracer-safety and kernel-contract static analyzer.

AST-based checks for the invariants the runtime only sees when the right
path executes: host-sync leaks in traced code (HS01), the 2^24 exactness
guard on int->f32 remaps (XD01), ref/pallas kernel impl-pair parity
(KP01), registry capability consistency and frozen-config purity
(RC01/RC02), donated-buffer reads (DA01), plus hygiene warnings
(UI01/DS01/MD01). Run `python -m repro.analysis --help`; the CI gate is
`python -m repro.analysis --fail-on-findings`.
"""
from repro.analysis.core import (
    Checker,
    Finding,
    all_checkers,
    analyze_paths,
    analyze_sources,
    apply_baseline,
    load_baseline,
    register_checker,
    write_baseline,
)

__all__ = [
    "Checker",
    "Finding",
    "all_checkers",
    "analyze_paths",
    "analyze_sources",
    "apply_baseline",
    "load_baseline",
    "register_checker",
    "write_baseline",
]
