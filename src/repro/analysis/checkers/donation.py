"""DA01 — donated buffer read after the jitted call.

`donate_argnums` hands the argument's device buffer to the jitted
computation: after the call returns, the caller's array is deleted on
accelerators (reads raise "buffer was donated") — but NOT on CPU, where
donation is a no-op and the stale read silently works. This checker makes
the accelerator semantics the static contract.

Per module, donating callables are discovered from

  - defs decorated `@functools.partial(jax.jit, donate_argnums=...)` or
    `@jax.jit(donate_argnums=...)`, and
  - `name = jax.jit(fn, donate_argnums=...)` aliases,

with literal argnums only. At each call site, a plain variable passed in
a donated position is tracked through the remaining statements of the
enclosing body: a read before a rebind is flagged. Rebinding in the same
statement (`val, stats = f(sub, val)` — the repo's carry idiom) is the
sanctioned pattern and ends tracking immediately. The scan is linear
(document order, same statement list); loop-carried donation hazards are
out of scope.
"""
from __future__ import annotations

import ast

from repro.analysis.astutil import build_import_map, decorator_is_jit, jit_call_donated
from repro.analysis.core import Checker, register_checker


def _donating_callables(tree: ast.Module, imports: dict) -> dict:
    """name -> (donated positions, callable kind) for this module."""
    out: dict = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if isinstance(dec, ast.Call) and decorator_is_jit(dec, imports):
                    donated = jit_call_donated(dec, imports)
                    if donated:
                        out[node.name] = donated
        elif isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            donated = jit_call_donated(node.value, imports)
            if donated:
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        out[tgt.id] = donated
    return out


def _assigned_names(stmt: ast.stmt) -> set:
    """Names (re)bound by this statement's targets."""
    names: set = set()
    targets = []
    if isinstance(stmt, ast.Assign):
        targets = stmt.targets
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign, ast.For, ast.AsyncFor)):
        targets = [stmt.target]
    elif isinstance(stmt, (ast.With, ast.AsyncWith)):
        targets = [i.optional_vars for i in stmt.items if i.optional_vars]
    for t in targets:
        for n in ast.walk(t):
            if isinstance(n, ast.Name):
                names.add(n.id)
    return names


def _reads(stmt: ast.stmt, name: str):
    for n in ast.walk(stmt):
        if isinstance(n, ast.Name) and n.id == name and isinstance(n.ctx, ast.Load):
            yield n


@register_checker
class DonationChecker(Checker):
    code = "DA01"
    name = "donation-after-use"
    description = (
        "a variable passed in a donate_argnums position is read again after the "
        "jitted call without being rebound (deleted buffer on accelerators)"
    )
    severity = "error"
    scope = "module"

    def check_module(self, module, report) -> None:
        imports = build_import_map(module.tree)
        donating = _donating_callables(module.tree, imports)
        if not donating:
            return
        for body in self._statement_lists(module.tree):
            self._scan_body(module, body, donating, report)

    def _statement_lists(self, tree: ast.Module):
        yield tree.body
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield node.body
            elif isinstance(node, (ast.For, ast.AsyncFor, ast.While, ast.With, ast.AsyncWith)):
                yield node.body
            elif isinstance(node, ast.If):
                yield node.body
                yield node.orelse
            elif isinstance(node, ast.Try):
                yield node.body
                yield node.finalbody
            elif isinstance(node, ast.ExceptHandler):
                yield node.body

    def _scan_body(self, module, body: list, donating: dict, report) -> None:
        for idx, stmt in enumerate(body):
            if not isinstance(
                stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign, ast.Expr, ast.Return)
            ):
                # Compound statements are scanned through their own body
                # lists — judging a nested call's rebinding against the
                # OUTER statement would mis-track across branches/functions.
                continue
            for call in ast.walk(stmt):
                if not (
                    isinstance(call, ast.Call)
                    and isinstance(call.func, ast.Name)
                    and call.func.id in donating
                ):
                    continue
                rebound_here = _assigned_names(stmt)
                for pos in donating[call.func.id]:
                    if pos >= len(call.args) or not isinstance(call.args[pos], ast.Name):
                        continue
                    var = call.args[pos].id
                    if var in rebound_here:
                        continue  # `x, ... = f(..., x)` — the sanctioned carry
                    self._track(module, body[idx + 1 :], var, call, report)

    def _track(self, module, rest: list, var: str, call: ast.Call, report) -> None:
        for stmt in rest:
            for read in _reads(stmt, var):
                report(
                    module.path, read.lineno, read.col_offset,
                    f"`{var}` was donated to `{call.func.id}` (line {call.lineno}) and "
                    "is read here without rebinding — on accelerators this buffer is "
                    "deleted; rebind the result or pass a fresh array",
                    anchor=call.func.id,
                )
                return
            if var in _assigned_names(stmt):
                return
