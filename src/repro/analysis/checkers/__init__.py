"""Checker registration: importing this package populates
`repro.analysis.core.CHECKERS` via the `@register_checker` decorators.

Add a new checker by dropping a module here that defines a
`Checker` subclass under `@register_checker` and importing it below
(see docs/api.md "Static analysis").
"""
from repro.analysis.checkers import (  # noqa: F401  (registration side effect)
    donation,
    exactness,
    exceptions,
    host_sync,
    hygiene,
    kernel_parity,
    registry_consistency,
)
