"""EH01 — swallowed broad exception handlers.

A bare ``except:`` / ``except Exception:`` / ``except BaseException:``
whose body does nothing (``pass`` / ``...``) silently discards failures —
exactly the bug class the AsyncCheckpointer fix in ``repro.checkpoint``
removed: a checkpoint save that fails on the writer thread must surface,
or a later crash "resumes" from a snapshot that does not exist. The
fault-tolerance machinery in ``repro.resilience`` leans on this: every
failure is either retried, recorded as a named result, or raised —
never dropped on the floor.

Narrow handlers (``except jax.errors.JAXTypeError: pass``) are fine —
they document exactly which condition is expected and ignorable. Broad
handlers that DO something (log, fall back, re-raise) are also fine.
Only the broad-and-silent combination is flagged.

Warning severity, plain ``# noqa`` honored (hygiene tier) — but policy
per the repo's lint bar: true findings get FIXED, not baselined.
"""
from __future__ import annotations

import ast

from repro.analysis.core import Checker, register_checker

# Names that count as "broad": catching these says nothing about which
# failure you expected.
_BROAD = ("Exception", "BaseException")


def _is_broad(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:  # bare `except:`
        return True
    names = t.elts if isinstance(t, ast.Tuple) else [t]
    for n in names:
        if isinstance(n, ast.Name) and n.id in _BROAD:
            return True
        if isinstance(n, ast.Attribute) and n.attr in _BROAD:
            return True  # e.g. builtins.Exception
    return False


def _is_silent(handler: ast.ExceptHandler) -> bool:
    for stmt in handler.body:
        if isinstance(stmt, ast.Pass):
            continue
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Constant):
            continue  # `...` or a docstring-style constant — still silent
        return False
    return True


@register_checker
class SwallowedExceptionChecker(Checker):
    code = "EH01"
    name = "swallowed-broad-exception"
    description = "broad except handler silently discards the exception"
    severity = "warning"
    scope = "module"

    def check_module(self, module, report) -> None:
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if _is_broad(node) and _is_silent(node):
                caught = "except:" if node.type is None else (
                    f"except {ast.unparse(node.type)}:"
                )
                report(
                    module.path, node.lineno, node.col_offset,
                    f"`{caught}` with a pass-only body swallows every failure — "
                    "catch the specific exception, or handle it (log / fall back / "
                    "re-raise)",
                    anchor=caught,
                )
