"""HS01 — host-sync leak inside traced (jitted / loop-body) code.

The fused BSP drivers' headline invariant is ONE host sync per run
(pinned at runtime by `engine.DISPATCH_COUNTS`). A `np.asarray`,
`.item()`, `float()`, `bool()` or `jax.device_get` on a traced value
inside a `@jax.jit` function or a `lax.while_loop`/`lax.scan` body either
breaks tracing outright (ConcretizationTypeError at the first run with a
new shape) or — worse — silently forces a device round-trip on every
call when the value happens to be concrete. This checker protects the
single-dispatch invariant statically.

Traced scopes are collected per module:
  - functions decorated `@jax.jit` / `@functools.partial(jax.jit, ...)`,
  - functions wrapped by a `jax.jit(fn)` / `shard_map(fn, ...)` call,
  - functions (or lambdas) passed to `lax.while_loop` / `lax.scan` /
    `lax.fori_loop` / `lax.cond` / `lax.switch` / `lax.map` or used as a
    `pl.pallas_call` kernel,
  - anything lexically nested inside one of the above.

`float()`/`bool()`/`int()` are flagged only when the argument is clearly
dynamic (not a literal, `len(...)`, `.shape`/`.ndim` access, or a module
constant spelled UPPER_CASE) — converting static shape arithmetic is fine.
"""
from __future__ import annotations

import ast

from repro.analysis.astutil import (
    build_import_map,
    call_qualname,
    decorator_is_jit,
    dotted_name,
    qualify,
    unparse,
)
from repro.analysis.core import Checker, register_checker

# Canonical (import-map-qualified) names that force a device->host sync.
SYNC_CALLS = {
    "numpy.asarray",
    "numpy.array",
    "numpy.asscalar",
    "jax.device_get",
    "jax.block_until_ready",
}
SYNC_METHODS = {"item", "tolist", "block_until_ready"}
CAST_BUILTINS = {"float", "bool", "int"}

# lax control-flow primitives whose callable args become traced bodies.
LOOP_PRIMS = {
    "jax.lax.while_loop",
    "jax.lax.scan",
    "jax.lax.fori_loop",
    "jax.lax.cond",
    "jax.lax.switch",
    "jax.lax.map",
    "jax.lax.associative_scan",
}
WRAPPERS = {"jax.jit", "jax.pmap", "jax.vmap"}
KERNEL_WRAPPERS = {"pallas_call", "shard_map", "shard_map_compat"}


def _is_static_expr(node: ast.AST) -> bool:
    """Expressions whose host conversion is trace-safe (static metadata)."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Name):
        # Module-level UPPER_CASE constants (INF, BLOCK_E, ...) are static.
        return node.id.isupper()
    if isinstance(node, ast.Attribute):
        return node.attr in ("ndim", "size", "dtype") or node.attr.isupper()
    if isinstance(node, ast.Subscript):
        base = node.value
        return isinstance(base, ast.Attribute) and base.attr == "shape"
    if isinstance(node, ast.Call):
        fn = dotted_name(node.func)
        return fn in ("len", "min", "max") and all(_is_static_expr(a) for a in node.args)
    if isinstance(node, ast.BinOp):
        return _is_static_expr(node.left) and _is_static_expr(node.right)
    if isinstance(node, ast.UnaryOp):
        return _is_static_expr(node.operand)
    return False


def _callable_args(call: ast.Call, qn: str) -> list:
    """The argument positions of `call` that are traced callables."""
    if qn in LOOP_PRIMS:
        return list(call.args)
    if qn in WRAPPERS or qn.rsplit(".", 1)[-1] in KERNEL_WRAPPERS:
        return list(call.args[:1]) + [
            kw.value for kw in call.keywords if kw.arg in ("f", "fun", "kernel")
        ]
    return []


def _jit_static_names(dec: ast.AST) -> set:
    """Literal static_argnames on a jit decorator call — those parameters
    are concrete Python values inside the trace, not tracers."""
    names: set = set()
    if isinstance(dec, ast.Call):
        for kw in dec.keywords:
            if kw.arg in ("static_argnames", "static_argnums"):
                for node in ast.walk(kw.value):
                    if isinstance(node, ast.Constant) and isinstance(node.value, str):
                        names.add(node.value)
    return names


def _collect_traced(tree: ast.Module, imports: dict) -> list:
    """(scope node, static param names) pairs whose bodies trace under jit."""
    local_funcs = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # Innermost definition wins for nested same-name defs; good
            # enough for scope marking (names are module-unique in practice).
            local_funcs.setdefault(node.name, node)

    traced = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if decorator_is_jit(dec, imports):
                    traced.append((node, _jit_static_names(dec)))
                    break
        elif isinstance(node, ast.Call):
            qn = call_qualname(node, imports) or ""
            args = _callable_args(node, qn)
            statics = _jit_static_names(node)
            # functools.partial(kernel, ...) as a pallas_call kernel arg.
            expanded = []
            for a in args:
                if (
                    isinstance(a, ast.Call)
                    and qualify(dotted_name(a.func), imports) == "functools.partial"
                    and a.args
                ):
                    expanded.append(a.args[0])
                else:
                    expanded.append(a)
            for a in expanded:
                if isinstance(a, ast.Lambda):
                    traced.append((a, statics))
                elif isinstance(a, ast.Name) and a.id in local_funcs:
                    traced.append((local_funcs[a.id], statics))
    return traced


@register_checker
class HostSyncChecker(Checker):
    code = "HS01"
    name = "host-sync-leak"
    description = (
        "np.asarray/.item()/float()/bool()/jax.device_get on traced values inside "
        "@jax.jit functions or lax.while_loop/lax.scan bodies (breaks the "
        "single-dispatch invariant)"
    )
    severity = "error"
    scope = "module"

    def check_module(self, module, report) -> None:
        imports = build_import_map(module.tree)
        traced = _collect_traced(module.tree, imports)
        # Nested functions inside traced scopes are traced too; ast.walk from
        # each traced root covers them, and run_checkers dedupes overlaps.
        seen = set()
        for scope, statics in traced:
            scope_name = getattr(scope, "name", "<lambda>")
            for node in ast.walk(scope):
                if not isinstance(node, ast.Call) or id(node) in seen:
                    continue
                seen.add(id(node))
                self._check_call(node, imports, module, scope_name, statics, report)

    def _check_call(self, node: ast.Call, imports, module, scope_name, statics, report) -> None:
        qn = call_qualname(node, imports)
        if qn in SYNC_CALLS:
            report(
                module.path,
                node.lineno,
                node.col_offset,
                f"`{unparse(node)}` inside traced scope `{scope_name}` forces a "
                "device->host sync (or fails to trace); hoist it out of the jitted code",
                anchor=scope_name,
            )
            return
        if (
            isinstance(node.func, ast.Attribute)
            and node.func.attr in SYNC_METHODS
            and not node.args
            and dotted_name(node.func.value) not in imports  # e.g. config.item(...) modules
        ):
            report(
                module.path,
                node.lineno,
                node.col_offset,
                f"`.{node.func.attr}()` inside traced scope `{scope_name}` forces a "
                "device->host sync; return the array and convert outside the trace",
                anchor=scope_name,
            )
            return
        if (
            isinstance(node.func, ast.Name)
            and node.func.id in CAST_BUILTINS
            and len(node.args) == 1
            and not _is_static_expr(node.args[0])
            and not (
                isinstance(node.args[0], ast.Name) and node.args[0].id in statics
            )
        ):
            report(
                module.path,
                node.lineno,
                node.col_offset,
                f"`{unparse(node)}` inside traced scope `{scope_name}` concretizes a "
                f"traced value; use jnp.{node.func.id}32-style casts or move the "
                "conversion to the host side",
                anchor=scope_name,
            )
