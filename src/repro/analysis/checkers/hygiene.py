"""UI01/DS01/MD01 — low-severity hygiene: unused imports, dead stores,
mutable default arguments.

These are warnings, not errors, and additionally honor plain ``# noqa``
pragmas (see repro.analysis.core). Policy per the repo's lint bar: true
findings get FIXED, not baselined — the committed baseline ships empty.

  - UI01: a top-level import alias never referenced in the module. Skipped
    entirely in ``__init__.py`` (re-export surface) and for imports inside
    ``try`` blocks (the optional-dependency gating idiom) or named in a
    literal ``__all__``.
  - DS01: a local assigned through a plain single-name target that is
    never read anywhere in its function — the classic leftover from a
    refactor. Tuple unpacking and ``_``-prefixed names are exempt
    (discarding one of several results is idiomatic), as are closures
    referenced by nested functions.
  - MD01: ``def f(x=[])``-style mutable defaults (list/dict/set literals
    or constructor calls) — shared state across calls, and unhashable
    where configs must hash.
"""
from __future__ import annotations

import ast

from repro.analysis.core import Checker, register_checker


@register_checker
class UnusedImportChecker(Checker):
    code = "UI01"
    name = "unused-import"
    description = "imported name is never used in the module"
    severity = "warning"
    scope = "module"

    def check_module(self, module, report) -> None:
        if module.path.endswith("__init__.py"):
            return
        in_try: set = set()
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Try):
                for child in ast.walk(node):
                    in_try.add(id(child))

        used: set = set()
        exported: set = set()
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
                used.add(node.id)
            elif isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name) and t.id == "__all__":
                        for elt in ast.walk(node.value):
                            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                                exported.add(elt.value)

        for node in ast.walk(module.tree):
            if id(node) in in_try:
                continue
            if isinstance(node, ast.Import):
                for alias in node.names:
                    local = alias.asname or alias.name.split(".")[0]
                    if local not in used and local not in exported:
                        report(
                            module.path, node.lineno, node.col_offset,
                            f"`import {alias.name}` is unused",
                            anchor=local,
                        )
            elif isinstance(node, ast.ImportFrom):
                if node.module == "__future__":
                    continue
                for alias in node.names:
                    if alias.name == "*":
                        continue
                    local = alias.asname or alias.name
                    if local not in used and local not in exported:
                        report(
                            module.path, node.lineno, node.col_offset,
                            f"`from {'.' * node.level}{node.module or ''} import "
                            f"{alias.name}` is unused",
                            anchor=local,
                        )


@register_checker
class DeadStoreChecker(Checker):
    code = "DS01"
    name = "dead-store"
    description = "local variable is assigned but never read in its function"
    severity = "warning"
    scope = "module"

    def check_module(self, module, report) -> None:
        for node in ast.walk(module.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._check_function(module, node, report)

    def _check_function(self, module, fn, report) -> None:
        loaded: set = set()
        declared: set = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
                loaded.add(node.id)
            elif isinstance(node, (ast.Global, ast.Nonlocal)):
                declared.update(node.names)
            elif isinstance(node, ast.Call):
                # locals()/eval/exec make static liveness unknowable.
                fname = getattr(node.func, "id", "")
                if fname in ("locals", "vars", "eval", "exec"):
                    return
        # Only this function's own statements: stores in nested defs belong
        # to the nested function's scope (and were walked above for loads —
        # a closure read keeps the outer store alive).
        for stmt in self._own_statements(fn):
            if not isinstance(stmt, ast.Assign) or len(stmt.targets) != 1:
                continue
            target = stmt.targets[0]
            if not isinstance(target, ast.Name):
                continue  # tuple unpacking / attribute / subscript: exempt
            name = target.id
            if name.startswith("_") or name in loaded or name in declared:
                continue
            report(
                module.path, stmt.lineno, stmt.col_offset,
                f"`{name}` is assigned but never read in `{fn.name}`",
                anchor=f"{fn.name}.{name}",
            )

    def _own_statements(self, fn):
        stack = list(fn.body)
        while stack:
            stmt = stack.pop()
            yield stmt
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                continue
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.stmt):
                    stack.append(child)
                elif hasattr(child, "body") and not isinstance(child, ast.expr):
                    stack.append(child)


@register_checker
class MutableDefaultChecker(Checker):
    code = "MD01"
    name = "mutable-default-arg"
    description = "function parameter default is a mutable object"
    severity = "warning"
    scope = "module"

    MUTABLE = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp, ast.SetComp)

    def check_module(self, module, report) -> None:
        for node in ast.walk(module.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                continue
            a = node.args
            pos = a.posonlyargs + a.args
            for param, default in zip(pos[len(pos) - len(a.defaults):], a.defaults):
                self._check_default(module, node, param, default, report)
            for param, default in zip(a.kwonlyargs, a.kw_defaults):
                if default is not None:
                    self._check_default(module, node, param, default, report)

    def _check_default(self, module, fn, param, default, report) -> None:
        bad = isinstance(default, self.MUTABLE) or (
            isinstance(default, ast.Call)
            and getattr(default.func, "id", "") in ("list", "dict", "set", "bytearray")
            and not default.args
            and not default.keywords
        )
        if bad:
            fname = getattr(fn, "name", "<lambda>")
            report(
                module.path, default.lineno, default.col_offset,
                f"`{fname}` parameter `{param.arg}` defaults to a mutable object — "
                "use None and create it in the body (shared across calls otherwise)",
                anchor=f"{fname}.{param.arg}",
            )
