"""XD01 — int→f32 exactness-domain remap reachable without a 2^24 guard.

The kernel backends run int32 semirings in f32 via the INF_I32↔INF_F32
remap in `engine._local_fixpoint` — exact only for magnitudes below 2^24.
Every public entry point from which that remap is reachable must pass
through a dominating guard (compare against `1 << 24`, raise) BEFORE the
remap can run. The repo has two such guards, and the structural detector
below recognizes both without naming them: `engine.check_int32_kernel_gid`
(flat addressing — the global-id space IS the kernel value domain, so
max(gid) is the bound) and `engine.check_int32_kernel_values` (two-level
addressing — enforcement moves to the kernel VALUE boundary, where
`engine._kernel_value_boundary` proves a per-worker bound: the rank-codec
size for label-domain programs, the covered-vertex count for unit-weight
hop counts). This is the static version of those runtime ValueErrors.

Detection is interprocedural over the analyzed module set:

  - **remap site**: a function whose body both references an `INF_I32`
    sentinel constant and casts with `.astype(float32)` — the repo's (and
    this checker's) canonical int-domain remap signature.
  - **guard**: a function containing a comparison against the constant
    2^24 (any literal spelling: `1 << 24`, `2 ** 24`, `16777216`)
    alongside a `raise` (or as an `assert`).
  - **call graph**: name-resolved edges (module-local defs + `from x
    import f` / `import x` aliases). Defining a closure counts as
    reaching whatever the closure reaches (the stepper/runner pattern).
  - **dominance** (approximation): a remap-reaching call in a top-level
    statement needs a guard-reaching call in an earlier-or-same top-level
    statement, unless the callee guards internally. A remap-reaching call
    inside a nested def needs a guard-reaching call anywhere in the
    enclosing function (closures run out of definition order).

Only public functions/methods (no leading underscore) are reported —
private helpers are expected to rely on their callers' guards. `self.*`
method calls are not resolved; route guard-sensitive flows through
module-level functions.
"""
from __future__ import annotations

import ast

from repro.analysis.astutil import (
    build_import_map,
    const_value,
    dotted_name,
    iter_functions,
    qualify,
)
from repro.analysis.core import Checker, register_checker

GUARD_CONST = 1 << 24
SENTINEL = "INF_I32"
F32_NAMES = {"jax.numpy.float32", "jnp.float32", "numpy.float32", "float32"}


def _own_nodes(fn: ast.AST):
    """Walk a function's body, pruning nested function/lambda bodies."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            stack.extend(ast.iter_child_nodes(node))


def _is_f32_cast(node: ast.AST, imports: dict) -> bool:
    if not (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Attribute)
        and node.func.attr == "astype"
        and node.args
    ):
        return False
    arg = node.args[0]
    target = qualify(dotted_name(arg), imports)
    return target in F32_NAMES or (isinstance(arg, ast.Constant) and arg.value == "float32")


def _is_remap(fn: ast.AST, imports: dict) -> bool:
    """INF_I32 reference + .astype(float32) in the same function body."""
    has_sentinel = has_cast = False
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and SENTINEL in node.id:
            has_sentinel = True
        elif isinstance(node, ast.Attribute) and SENTINEL in node.attr:
            has_sentinel = True
        elif _is_f32_cast(node, imports):
            has_cast = True
        if has_sentinel and has_cast:
            return True
    return False


def _is_guard(fn: ast.AST) -> bool:
    """Contains a comparison against 2^24 plus a raise (or an assert)."""
    has_raise = any(isinstance(n, ast.Raise) for n in ast.walk(fn))
    for node in ast.walk(fn):
        if isinstance(node, ast.Compare):
            sides = [node.left, *node.comparators]
            if any(const_value(s) == GUARD_CONST for s in sides):
                in_assert = any(
                    isinstance(a, ast.Assert) and node in ast.walk(a) for a in ast.walk(fn)
                )
                if has_raise or in_assert:
                    return True
    return False


class _Graph:
    """Name-resolved project call graph over the analyzed modules."""

    def __init__(self, modules):
        self.funcs: dict = {}  # key -> (module, FuncInfo)
        self.by_dotted: dict = {}  # "repro.graph.engine.run_bsp" -> key
        self.local: dict = {}  # module.path -> {local name -> key}
        self.imports: dict = {}  # module.path -> import map
        for m in modules:
            self.imports[m.path] = build_import_map(m.tree)
            self.local[m.path] = {}
            for info in iter_functions(m.tree):
                key = (m.path, info.qualname)
                self.funcs[key] = (m, info)
                if info.parent is None and not info.in_class:
                    self.by_dotted[f"{m.dotted}.{info.qualname}"] = key
                    self.local[m.path][info.qualname] = key
        # Imported aliases resolve cross-module once every def is indexed.
        for m in modules:
            for alias, target in self.imports[m.path].items():
                if target in self.by_dotted:
                    self.local[m.path].setdefault(alias, self.by_dotted[target])

    def resolve(self, module, name_node: ast.AST):
        qn = dotted_name(name_node)
        if qn is None:
            return None
        full = qualify(qn, self.imports[module.path])
        if full in self.by_dotted:
            return self.by_dotted[full]
        return self.local[module.path].get(qn)

    def callees(self, key, nodes) -> list:
        """Function keys referenced (called or passed) in `nodes`."""
        module, _ = self.funcs[key]
        out = []
        for node in nodes:
            if isinstance(node, (ast.Name, ast.Attribute)):
                target = self.resolve(module, node)
                if target is not None and target != key:
                    out.append(target)
        return out

    def nested(self, key) -> list:
        """Keys of functions lexically nested directly under `key`."""
        module, info = self.funcs[key]
        prefix = info.qualname + ".<locals>."
        return [
            k
            for k, (m, i) in self.funcs.items()
            if m.path == module.path
            and i.qualname.startswith(prefix)
            and ".<locals>." not in i.qualname[len(prefix):]
        ]


@register_checker
class ExactnessChecker(Checker):
    code = "XD01"
    name = "unguarded-exactness-remap"
    description = (
        "public entry point reaches the int->f32 exactness remap (INF_I32 + "
        ".astype(float32)) without a dominating 1 << 24 guard on the path"
    )
    severity = "error"
    scope = "project"

    def check_project(self, modules, report) -> None:
        g = _Graph(modules)
        remap = {k for k, (m, i) in g.funcs.items() if _is_remap(i.node, g.imports[m.path])}
        guard = {k for k, (_, i) in g.funcs.items() if _is_guard(i.node)}
        edges = {
            k: g.callees(k, ast.walk(info.node)) + g.nested(k)
            for k, (_, info) in g.funcs.items()
        }

        def reaches(key, targets, seen=None) -> bool:
            if seen is None:
                seen = set()
            if key in seen:
                return False
            seen.add(key)
            if key in targets:
                return True
            return any(reaches(c, targets, seen) for c in edges.get(key, ()))

        guarded_memo: dict = {}

        def guarded(key) -> bool:
            if key in guarded_memo:
                return guarded_memo[key]
            guarded_memo[key] = True  # cycle default: lenient
            guarded_memo[key] = self._guarded(key, g, remap, guard, reaches, guarded)
            return guarded_memo[key]

        for key in sorted(g.funcs, key=lambda k: (k[0], k[1])):
            module, info = g.funcs[key]
            if not info.is_public or ".<locals>." in info.qualname:
                continue
            if not reaches(key, remap):
                continue
            if guarded(key):
                continue
            report(
                module.path,
                info.node.lineno,
                info.node.col_offset,
                f"`{info.qualname}` reaches the int->f32 exactness remap without a "
                "dominating 1 << 24 guard; call a check_int32_kernel_gid- or "
                "check_int32_kernel_values-style guard before the remap on every path",
                anchor=info.qualname,
            )

    def _guarded(self, key, g, remap, guard, reaches, guarded) -> bool:
        if key in guard:
            return True
        module, info = g.funcs[key]
        imports = g.imports[module.path]
        body = getattr(info.node, "body", [])
        guard_anywhere = any(reaches(c, guard) for c in g.callees(key, ast.walk(info.node)))

        # Direct (non-nested) remap-reaching calls — and this function's own
        # remap casts, if it is itself a remap site — need a guard-reaching
        # call in an earlier-or-same top-level statement.
        guard_seen = False
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue  # closures are judged by the nested-def rule below
            own = [stmt] + list(_own_nodes(stmt))
            callees = g.callees(key, own)
            if any(reaches(c, guard) for c in callees):
                guard_seen = True
            for c in callees:
                if reaches(c, remap) and not guarded(c) and not guard_seen:
                    return False
            if key in remap and not guard_seen and any(_is_f32_cast(n, imports) for n in own):
                return False

        # Remap work inside nested defs (closures returned/registered out of
        # order) needs a guard-reaching call anywhere in this function.
        for n in g.nested(key):
            if reaches(n, remap) and not guarded(n) and not guard_anywhere:
                return False
        return True
