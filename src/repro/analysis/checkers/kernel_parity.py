"""KP01 — kernel impl-pair parity for `ops._resolve_impl` entry points.

Every public wrapper in the ops module that dispatches through
`_resolve_impl` must keep BOTH implementations alive and call-compatible:

  - a **ref branch**: a call into the ref oracle module (`ref.*`), checked
    for call-compatibility against the oracle's actual signature (arity,
    unknown/missing keywords) — a drifted oracle signature is exactly the
    parity bug the runtime A/B suites would catch one release later;
  - a **pallas branch**: a call to a `*_pallas` implementation that
    forwards an explicit `interpret=` (so compiled-vs-interpreter stays
    caller-forceable off-TPU), equally signature-checked when the impl's
    defining module is in the analyzed set;
  - **block padding**: an entry point taking a `block_*` parameter must
    either pad the stream itself (`_pad_to_block` or a `% block` length
    computation) or forward the parameter to the pallas impl, which then
    owns the granularity contract.

Pure delegators (entry points that don't call `_resolve_impl`, like
`segment_max` riding on `segment_min_plus`) are exempt — their parity is
the delegate's.
"""
from __future__ import annotations

import ast

from repro.analysis.astutil import build_import_map, dotted_name, qualify, unparse
from repro.analysis.core import Checker, register_checker

RESOLVER = "_resolve_impl"
PAD_HELPER = "_pad_to_block"


def _call_names(fn: ast.AST):
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            yield node, dotted_name(node.func)


def _signature_issue(call: ast.Call, impl: ast.FunctionDef):
    """Call-compatibility of `call` against def `impl`; None when fine."""
    if any(isinstance(a, ast.Starred) for a in call.args) or any(
        kw.arg is None for kw in call.keywords
    ):
        return None  # *args/**kwargs forwarding: not statically checkable
    a = impl.args
    pos = [p.arg for p in a.posonlyargs + a.args]
    kwonly = [p.arg for p in a.kwonlyargs]
    npos_given = len(call.args)
    if a.vararg is None and npos_given > len(pos):
        return f"passes {npos_given} positional args but `{impl.name}` takes {len(pos)}"
    given_kw = {kw.arg for kw in call.keywords}
    if a.kwarg is None:
        unknown = given_kw - set(pos) - set(kwonly)
        if unknown:
            return f"passes unknown keyword(s) {sorted(unknown)} to `{impl.name}`"
    n_defaults = len(a.defaults)
    required_pos = pos[: len(pos) - n_defaults]
    missing = [
        p for p in required_pos[npos_given:] if p not in given_kw
    ] + [
        p
        for p, d in zip(kwonly, a.kw_defaults)
        if d is None and p not in given_kw
    ]
    if missing:
        return f"misses required parameter(s) {missing} of `{impl.name}`"
    return None


@register_checker
class KernelParityChecker(Checker):
    code = "KP01"
    name = "kernel-impl-parity"
    description = (
        "ops._resolve_impl entry points must keep matching ref and pallas "
        "implementations (call-compatible signatures, interpret= forwarding, "
        "block padding)"
    )
    severity = "error"
    scope = "project"

    def check_project(self, modules, report) -> None:
        by_dotted = {m.dotted: m for m in modules}
        for m in modules:
            has_resolver = any(
                isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)) and n.name == RESOLVER
                for n in m.tree.body
            )
            if has_resolver:
                self._check_ops_module(m, by_dotted, report)

    def _check_ops_module(self, module, by_dotted, report) -> None:
        imports = build_import_map(module.tree)
        # Defs reachable through imports: "ref.segment_min_plus_ref" and the
        # directly-imported *_pallas names.
        def find_def(name: str):
            target = qualify(name, imports)
            if target is None:
                return None
            mod_dotted, _, fname = target.rpartition(".")
            defmod = by_dotted.get(mod_dotted)
            if defmod is None:
                return None
            for n in defmod.tree.body:
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)) and n.name == fname:
                    return n
            return None

        for fn in module.tree.body:
            if not isinstance(fn, ast.FunctionDef) or fn.name.startswith("_"):
                continue
            calls = list(_call_names(fn))
            if not any(name == RESOLVER for _, name in calls):
                continue  # pure delegator — parity owned by the delegate

            ref_calls = [
                (c, name) for c, name in calls if name and "." in name and name.startswith("ref.")
            ]
            pallas_calls = [(c, name) for c, name in calls if name and name.endswith("_pallas")]

            if not ref_calls:
                report(
                    module.path, fn.lineno, fn.col_offset,
                    f"`{fn.name}` dispatches through {RESOLVER} but has no ref-oracle "
                    "branch (no `ref.*` call)",
                    anchor=fn.name,
                )
            if not pallas_calls:
                report(
                    module.path, fn.lineno, fn.col_offset,
                    f"`{fn.name}` dispatches through {RESOLVER} but has no pallas "
                    "branch (no `*_pallas` call)",
                    anchor=fn.name,
                )
            for call, name in pallas_calls:
                if not any(kw.arg == "interpret" for kw in call.keywords):
                    report(
                        module.path, call.lineno, call.col_offset,
                        f"`{unparse(call.func)}` call in `{fn.name}` does not forward "
                        "`interpret=` — compiled-vs-interpreter must stay caller-forceable",
                        anchor=fn.name,
                    )
            for call, name in ref_calls + pallas_calls:
                impl = find_def(name)
                if impl is None:
                    continue
                issue = _signature_issue(call, impl)
                if issue:
                    report(
                        module.path, call.lineno, call.col_offset,
                        f"impl-pair signature drift in `{fn.name}`: call {issue}",
                        anchor=fn.name,
                    )
            self._check_padding(module, fn, pallas_calls, report)

    def _check_padding(self, module, fn: ast.FunctionDef, pallas_calls, report) -> None:
        a = fn.args
        block_params = [
            p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs) if p.arg.startswith("block_")
        ]
        for block in block_params:
            pads_locally = any(
                name == PAD_HELPER for _, name in _call_names(fn)
            ) or any(
                isinstance(n, ast.BinOp)
                and isinstance(n.op, ast.Mod)
                and block in {d for d in [dotted_name(n.right), dotted_name(n.left)] if d}
                for n in ast.walk(fn)
            )
            forwarded = any(
                any(kw.arg == block for kw in call.keywords) for call, _ in pallas_calls
            )
            if not pads_locally and not forwarded:
                report(
                    module.path, fn.lineno, fn.col_offset,
                    f"`{fn.name}` takes `{block}` but neither pads the stream "
                    f"({PAD_HELPER} / `% {block}`) nor forwards it to the pallas impl",
                    anchor=fn.name,
                )
