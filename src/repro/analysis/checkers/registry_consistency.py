"""RC01/RC02 — registry capability flags and frozen-config purity.

RC01 cross-checks declared capabilities against what the decorated /
registered code actually implements:

  - `@register_partitioner(...)`: `compute_backends` must be a subset of
    ("xla", "ref", "pallas"); declaring a kernel backend ("ref"/"pallas")
    requires the partitioner function to accept a `compute_backend`
    parameter (and vice versa — an accepted knob must be declared);
    `chunked=True` requires a `block` parameter (and vice versa); a
    literal `scorer=` name must be registered somewhere in the analyzed
    set via `EdgeScorer(name=...)`.
  - `register_program(VertexProgram(...))`: literal field values must be
    drawn from the engine's closed vocabularies (dtype/combine/local/
    weight/apply/message_policy/convergence), combine="sum" programs must
    run local="sweep" (there is no sum fixpoint kernel), apply="pagerank"
    requires combine="sum", and names/aliases must be project-unique.

RC02 keeps frozen config dataclasses pure: a `@dataclass(frozen=True)`
class must not carry mutable defaults (list/dict/set literals — breaks
hashability and shares state across instances) and must not mutate itself
after construction (`object.__setattr__(self, ...)` anywhere in the class
— the frozen contract exists so jit caches can key on config identity).
"""
from __future__ import annotations

import ast

from repro.analysis.astutil import build_import_map, const_value, dotted_name, qualify
from repro.analysis.core import Checker, register_checker

VALID_BACKENDS = ("xla", "ref", "pallas")
KERNEL_BACKENDS = ("ref", "pallas")
PROGRAM_VOCAB = {
    "dtype": ("int32", "float32"),
    "combine": ("min", "max", "sum"),
    "local": ("fixpoint", "sweep"),
    "weight": ("none", "edge", "unit"),
    "apply": ("none", "pagerank"),
    "message_policy": ("delta", "always"),
    "convergence": ("no_change", "tol"),
}
MUTABLE_LITERALS = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp, ast.SetComp)


def _kw(call: ast.Call, name: str):
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _literal(node):
    return None if node is None else const_value(node)


def _fn_params(fn: ast.FunctionDef) -> set:
    a = fn.args
    return {p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)}


@register_checker
class RegistryConsistencyChecker(Checker):
    code = "RC01"
    name = "registry-consistency"
    description = (
        "PartitionerSpec capability flags (compute_backends/chunked/scorer) and "
        "VertexProgram registry fields must match what the code implements"
    )
    severity = "error"
    scope = "project"

    def check_project(self, modules, report) -> None:
        scorer_names = self._collect_scorer_names(modules)
        seen_programs: dict = {}
        for m in modules:
            imports = build_import_map(m.tree)
            for node in ast.walk(m.tree):
                if isinstance(node, ast.FunctionDef):
                    for dec in node.decorator_list:
                        if (
                            isinstance(dec, ast.Call)
                            and (qualify(dotted_name(dec.func), imports) or "").endswith(
                                "register_partitioner"
                            )
                        ):
                            self._check_partitioner(m, node, dec, scorer_names, report)
                elif isinstance(node, ast.Call):
                    qn = qualify(dotted_name(node.func), imports) or ""
                    if qn.endswith("register_program") and node.args:
                        inner = node.args[0]
                        if isinstance(inner, ast.Call) and (
                            dotted_name(inner.func) or ""
                        ).endswith("VertexProgram"):
                            self._check_program(m, inner, seen_programs, report)

    def _collect_scorer_names(self, modules) -> set:
        names = set()
        for m in modules:
            for node in ast.walk(m.tree):
                if isinstance(node, ast.Call) and (dotted_name(node.func) or "").endswith(
                    "EdgeScorer"
                ):
                    name = _literal(_kw(node, "name"))
                    if isinstance(name, str):
                        names.add(name)
        return names

    def _check_partitioner(self, module, fn, dec, scorer_names, report) -> None:
        where = (module.path, dec.lineno, dec.col_offset)
        params = _fn_params(fn)
        backends = _literal(_kw(dec, "compute_backends"))
        if backends is None and _kw(dec, "compute_backends") is None:
            backends = ("xla",)  # registry default
        if isinstance(backends, (tuple, list)):
            bad = [b for b in backends if b not in VALID_BACKENDS]
            if bad:
                report(
                    *where,
                    f"partitioner `{fn.name}` declares unknown compute_backends {bad}; "
                    f"valid: {VALID_BACKENDS}",
                    anchor=fn.name,
                )
            declares_kernels = any(b in KERNEL_BACKENDS for b in backends)
            if declares_kernels and "compute_backend" not in params:
                report(
                    *where,
                    f"partitioner `{fn.name}` declares kernel backends "
                    f"{tuple(backends)} but takes no `compute_backend` parameter",
                    anchor=fn.name,
                )
            if not declares_kernels and "compute_backend" in params:
                report(
                    *where,
                    f"partitioner `{fn.name}` accepts `compute_backend` but only "
                    "declares ('xla',) — declare the kernel backends it implements",
                    anchor=fn.name,
                )
        chunked = _literal(_kw(dec, "chunked"))
        if chunked is True and "block" not in params:
            report(
                *where,
                f"partitioner `{fn.name}` declares chunked=True but takes no "
                "`block` parameter",
                anchor=fn.name,
            )
        if chunked in (False, None) and "block" in params:
            report(
                *where,
                f"partitioner `{fn.name}` accepts `block` but is not declared "
                "chunked=True",
                anchor=fn.name,
            )
        scorer = _literal(_kw(dec, "scorer"))
        if isinstance(scorer, str) and scorer_names and scorer not in scorer_names:
            report(
                *where,
                f"partitioner `{fn.name}` declares scorer={scorer!r} but no "
                f"EdgeScorer(name={scorer!r}) is registered (known: "
                f"{sorted(scorer_names)})",
                anchor=fn.name,
            )

    def _check_program(self, module, call: ast.Call, seen: dict, report) -> None:
        where = (module.path, call.lineno, call.col_offset)
        fields = {kw.arg: _literal(kw.value) for kw in call.keywords if kw.arg}
        name = fields.get("name")
        anchor = name if isinstance(name, str) else "VertexProgram"
        for field, vocab in PROGRAM_VOCAB.items():
            value = fields.get(field)
            if field in fields and isinstance(value, str) and value not in vocab:
                report(
                    *where,
                    f"program {anchor!r}: {field}={value!r} is not in {vocab}",
                    anchor=anchor,
                )
        combine = fields.get("combine", "min")
        local = fields.get("local", "fixpoint")
        if combine == "sum" and local != "sweep":
            report(
                *where,
                f"program {anchor!r}: combine='sum' requires local='sweep' "
                "(no sum-fixpoint kernel exists)",
                anchor=anchor,
            )
        if fields.get("apply") == "pagerank" and combine != "sum":
            report(
                *where,
                f"program {anchor!r}: apply='pagerank' requires combine='sum'",
                anchor=anchor,
            )
        claimed = [name] if isinstance(name, str) else []
        aliases = fields.get("aliases")
        if isinstance(aliases, (tuple, list)):
            claimed += [a for a in aliases if isinstance(a, str)]
        for n in claimed:
            if n in seen:
                report(
                    *where,
                    f"program name/alias {n!r} already registered at "
                    f"{seen[n][0]}:{seen[n][1]}",
                    anchor=anchor,
                )
            else:
                seen[n] = (module.path, call.lineno)


@register_checker
class FrozenConfigChecker(Checker):
    code = "RC02"
    name = "frozen-config-purity"
    description = (
        "frozen dataclasses must stay pure: no mutable defaults, no "
        "object.__setattr__ self-mutation after construction"
    )
    severity = "error"
    scope = "module"

    def check_module(self, module, report) -> None:
        for node in ast.walk(module.tree):
            if isinstance(node, ast.ClassDef) and self._is_frozen_dataclass(node):
                self._check_class(module, node, report)

    def _is_frozen_dataclass(self, cls: ast.ClassDef) -> bool:
        for dec in cls.decorator_list:
            if isinstance(dec, ast.Call) and (dotted_name(dec.func) or "").endswith("dataclass"):
                frozen = _kw(dec, "frozen")
                if frozen is not None and const_value(frozen) is True:
                    return True
        return False

    def _check_class(self, module, cls: ast.ClassDef, report) -> None:
        for stmt in cls.body:
            if isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                default = stmt.value
                bad = isinstance(default, MUTABLE_LITERALS) or (
                    isinstance(default, ast.Call)
                    and (dotted_name(default.func) or "") in ("list", "dict", "set")
                )
                if bad:
                    field = dotted_name(stmt.target) or "<field>"
                    report(
                        module.path, stmt.lineno, stmt.col_offset,
                        f"frozen dataclass `{cls.name}` field `{field}` has a mutable "
                        "default — use dataclasses.field(default_factory=...) or a tuple",
                        anchor=f"{cls.name}.{field}",
                    )
        for node in ast.walk(cls):
            if (
                isinstance(node, ast.Call)
                and (dotted_name(node.func) or "") == "object.__setattr__"
                and node.args
                and isinstance(node.args[0], ast.Name)
                and node.args[0].id == "self"
            ):
                report(
                    module.path, node.lineno, node.col_offset,
                    f"frozen dataclass `{cls.name}` mutates itself via "
                    "object.__setattr__ — frozen configs must be pure values "
                    "(derive in properties or validate without rewriting fields)",
                    anchor=cls.name,
                )
