"""`python -m repro.analysis` — the CI lint gate.

Default target is the installed `repro` package source (so the no-arg CI
invocation analyzes `src/repro` wherever the checkout lives); pass files
or directories to narrow the run. Exit status is 0 unless
`--fail-on-findings` is set and error-severity findings survive
suppressions and the baseline.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.core import (
    all_checkers,
    analyze_paths,
    apply_baseline,
    load_baseline,
    write_baseline,
)

DEFAULT_BASELINE = "analysis_baseline.json"


def _default_target() -> Path:
    return Path(__file__).resolve().parent.parent  # src/repro


def _repo_root() -> Path:
    return _default_target().parent.parent  # src/repro -> repo checkout


def build_parser() -> argparse.ArgumentParser:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static analysis over the repro package (tracer safety, "
        "kernel contracts, registry consistency, hygiene).",
    )
    parser.add_argument(
        "paths", nargs="*",
        help="files/directories to analyze (default: the repro package source)",
    )
    parser.add_argument(
        "--fail-on-findings", action="store_true",
        help="exit 1 if any finding (error or warning) survives suppressions/baseline",
    )
    parser.add_argument(
        "--json", metavar="FILE", default=None,
        help="write the full findings report as JSON (use '-' for stdout)",
    )
    parser.add_argument(
        "--baseline", metavar="FILE", default=None,
        help=f"baseline file of accepted fingerprints (default: {DEFAULT_BASELINE} "
        "at the repo root, when present)",
    )
    parser.add_argument(
        "--write-baseline", action="store_true",
        help="write current findings to the baseline file and exit 0",
    )
    parser.add_argument(
        "--select", metavar="CODES", default=None,
        help="comma-separated checker codes to run (e.g. HS01,XD01)",
    )
    parser.add_argument(
        "--list-checkers", action="store_true",
        help="print the registered checkers and exit",
    )
    return parser


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)

    if args.list_checkers:
        for c in all_checkers():
            print(f"{c.code}  {c.severity:7s}  {c.name}: {c.description}")
        return 0

    root = _repo_root()
    targets = args.paths or [_default_target()]
    select = None if args.select is None else [c.strip() for c in args.select.split(",")]
    findings = analyze_paths(targets, root=root, select=select)

    baseline_path = Path(args.baseline) if args.baseline else root / DEFAULT_BASELINE
    if args.write_baseline:
        write_baseline(findings, baseline_path)
        print(f"wrote {len(findings)} fingerprint(s) to {baseline_path}")
        return 0
    baselined = len(findings)
    findings = apply_baseline(findings, load_baseline(baseline_path))
    baselined -= len(findings)

    if args.json:
        payload = json.dumps(
            {
                "checkers": {c.code: c.description for c in all_checkers()},
                "findings": [f.to_dict() for f in findings],
                "baselined": baselined,
            },
            indent=2,
        )
        if args.json == "-":
            print(payload)
        else:
            Path(args.json).write_text(payload + "\n")

    for f in findings:
        print(f.render())
    errors = sum(1 for f in findings if f.severity == "error")
    warnings = len(findings) - errors
    tail = f" ({baselined} baselined)" if baselined else ""
    print(f"repro.analysis: {errors} error(s), {warnings} warning(s){tail}")

    if args.fail_on_findings and findings:
        return 1
    return 0


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
