"""`GraphQueryServer` — the persistent graph-query serving loop.

One server wraps one partitioned `GraphPipeline` and answers point
queries over its shared subgraph structure:

  submit → admission queue (per-program lanes, full/deadline flush) →
  pad to bucket → warm `BatchExecutable` (compiled once per
  (program, bucket) key) → one fused batched BSP dispatch →
  per-query results + `BSPStats`.

Per-query answers are bit-identical to single-source `run_bsp` calls:
padding lanes repeat a real query and are discarded after execution, and
convergence masking means each query's stats report the supersteps IT
paid, not the batch max.

Time is explicit rather than wall-clock-implicit so the server is
drivable both live (`submit()` + `pump()` with real timestamps) and in
simulation (`run_trace` replays a synthetic trace on a virtual clock,
charging real execution walls against it) — the same single-server
queueing discipline either way.

The resilient path (docs/api.md "Fault tolerance"): per-query deadlines
drop expired work with a named timeout failure; a bounded admission
queue sheds the newest query under overload (`LoadShedError` →
`QueryFailure("load_shed")`); transient backend failures (injected by a
seeded `FaultPlan`, replayable bit-for-bit) are retried with bounded
exponential backoff + deterministic jitter, the waits charged to the
virtual clock; and a `CircuitBreaker` walks the degradation ladder
(pallas → xla compute backend, fused batch → per-query host driver)
after consecutive failures — every rung computes bit-identical answers
(the repo's parity suites pin fused≡host, batch≡singles, xla≡ref≡pallas)
so degradation trades latency, never correctness. Every admitted query
terminates as either a `QueryResult` or a named `QueryFailure`; no
injected fault escapes the pump.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Optional, Union

import numpy as np

from repro.api.config import check_compute_backend
from repro.graph.engine import (
    BSPStats,
    batch_init,
    check_source,
    compile_batch_executable,
    get_program,
    run_bsp,
)
from repro.resilience.faults import (
    FaultPlan,
    LoadShedError,
    MalformedBatchError,
    TransientBackendError,
)
from repro.resilience.retry import CircuitBreaker, RetryPolicy
from repro.serve.cache import ExecutableCache
from repro.serve.padding import DEFAULT_BUCKETS, bucket_size, pad_items, padding_waste
from repro.serve.queue import AdmissionQueue, Query

log = logging.getLogger("repro.resilience")

# The retryable fault vocabulary: anything else raised by execution is a
# real bug and propagates (chaos tests assert ZERO unhandled exceptions
# from the injected kinds, not a blanket except).
_RETRYABLE = (TransientBackendError, MalformedBatchError)


@dataclasses.dataclass
class QueryResult:
    """One answered query: values are [p, max_v] (dump slot stripped),
    stats are THIS query's BSPStats under masking (its own superstep
    count). `batch`/`bucket` record the micro-batch it rode in."""

    qid: int
    program: str
    source: Optional[int]
    values: np.ndarray
    stats: BSPStats
    t_arrival: float
    t_done: float
    batch: int
    bucket: int

    ok = True

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_arrival

    @property
    def supersteps(self) -> int:
        return self.stats.supersteps


@dataclasses.dataclass
class QueryFailure:
    """One terminated-without-answer query. `error` is the named reason:
    "load_shed" (bounded queue rejected admission), "deadline_exceeded"
    (the deadline passed before execution), or "retries_exhausted" (every
    retry hit a fault). `retries` counts the backoff rounds paid."""

    qid: int
    program: str
    source: Optional[int]
    error: str
    t_arrival: float
    t_done: float
    retries: int = 0

    ok = False

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_arrival


@dataclasses.dataclass
class ServerReport:
    """Aggregate serving metrics over everything the server answered.
    `resilience` carries the fault-path counters (retries, sheds,
    timeouts, injected faults, degraded batches, breaker state) — all
    zero on a fault-free run."""

    queries: int
    wall_s: float
    throughput_qps: float
    latency_p50_s: float
    latency_p99_s: float
    batches: int
    mean_batch: float
    padding_waste: float
    supersteps_mean: float
    cache: dict
    resilience: dict = dataclasses.field(default_factory=dict)

    def row(self) -> dict:
        return {
            "queries": self.queries,
            "wall_s": round(self.wall_s, 4),
            "throughput_qps": round(self.throughput_qps, 1),
            "latency_p50_s": round(self.latency_p50_s, 5),
            "latency_p99_s": round(self.latency_p99_s, 5),
            "batches": self.batches,
            "mean_batch": round(self.mean_batch, 2),
            "padding_waste": round(self.padding_waste, 4),
            "supersteps_mean": round(self.supersteps_mean, 2),
            "cache": self.cache,
            "resilience": self.resilience,
        }


class GraphQueryServer:
    """See module docstring. Knobs:

    max_batch / max_delay_s — the admission queue's flush policy (full
    batch fires immediately; a lone query waits at most max_delay_s).
    buckets — padded-batch ladder; defaults to the shared power-of-two
    ladder truncated at max_batch's bucket.
    max_supersteps / inner_cap / tol / compute_backend — engine knobs
    baked into every compiled executable (part of the cache key).

    Resilience knobs: max_queue bounds the backlog (overflow load-sheds
    the arriving query); deadline_s is the default per-query deadline
    from arrival (submit can override); retry is the bounded-backoff
    policy for transient faults; breaker drives backend degradation
    (default: 3 consecutive failures drop one rung of
    [compute_backend batch] -> ["xla" batch] -> ["xla" host]);
    fault_plan injects deterministic chaos (tests/CI).
    """

    def __init__(
        self,
        pipeline,
        *,
        max_batch: int = 8,
        max_delay_s: float = 0.005,
        buckets=None,
        compute_backend: str = "xla",
        max_supersteps: Optional[int] = None,
        inner_cap: int = 10_000,
        tol: float = 0.0,
        max_queue: Optional[int] = None,
        deadline_s: Optional[float] = None,
        retry: Optional[RetryPolicy] = None,
        breaker: Optional[CircuitBreaker] = None,
        fault_plan: Optional[FaultPlan] = None,
    ):
        if pipeline.graph is None:
            raise RuntimeError("abstract (from_spec) pipelines cannot serve queries")
        pipeline._stage()  # require a partition stage up front
        top = bucket_size(max_batch, DEFAULT_BUCKETS if buckets is None else buckets)
        self.buckets = (
            tuple(b for b in DEFAULT_BUCKETS if b <= top) if buckets is None else tuple(buckets)
        )
        if bucket_size(max_batch, self.buckets) > max_batch:
            raise ValueError(
                f"buckets {self.buckets} cannot hold a full batch of {max_batch} "
                "without padding — include max_batch's bucket"
            )
        self.pipeline = pipeline
        self.compute_backend = check_compute_backend(compute_backend)
        self.max_supersteps = max_supersteps
        self.inner_cap = inner_cap
        self.tol = tol
        self.deadline_s = None if deadline_s is None else float(deadline_s)
        self.retry = RetryPolicy() if retry is None else retry
        self.fault_plan = fault_plan
        # Degradation ladder: every rung computes bit-identical answers.
        self.levels: tuple = ((self.compute_backend, "batch"),)
        if self.compute_backend != "xla":
            self.levels += (("xla", "batch"),)
        self.levels += (("xla", "host"),)
        self.breaker = (
            CircuitBreaker(threshold=3, max_level=len(self.levels) - 1)
            if breaker is None else breaker
        )
        self.queue = AdmissionQueue(
            max_batch=max_batch, max_delay_s=max_delay_s, max_queue=max_queue
        )
        self.cache = ExecutableCache()
        self._results: dict[int, QueryResult] = {}
        self._failures: dict[int, QueryFailure] = {}
        self._batch_log: list[tuple] = []  # (program, n_real, bucket, exec_wall_s)
        self._next_qid = 0
        self._clock = 0.0
        self._attempt = 0  # global execution-attempt counter (fault draws)
        self._batch_seq = 0  # global batch counter (straggler draws)
        self._counters = {
            "load_shed": 0, "deadline_exceeded": 0, "retries": 0,
            "retries_exhausted": 0, "faults_injected": 0, "malformed_batches": 0,
            "stragglers": 0, "degraded_batches": 0,
        }

    # ------------------------------------------------------------ admission

    def submit(
        self,
        program,
        source: Optional[int] = None,
        *,
        at: Optional[float] = None,
        deadline_s: Optional[float] = None,
    ) -> int:
        """Admit one query; returns its qid. Source-rooted programs
        validate `source` HERE — a bad source rejects this query alone,
        before it can join (and poison) a micro-batch. A full bounded
        queue sheds the query (reject-newest): the qid still resolves,
        to a `QueryFailure` named "load_shed"."""
        prog = get_program(program)
        sub = self._sub_for(prog)
        if prog.needs_source:
            source = check_source(sub, source, self.pipeline.graph.num_vertices)
        elif source is not None:
            raise ValueError(
                f"program {prog.name!r} is a whole-graph query; source= does not apply"
            )
        at = self._clock if at is None else float(at)
        self._clock = max(self._clock, at)
        qid = self._next_qid
        self._next_qid += 1
        budget = self.deadline_s if deadline_s is None else float(deadline_s)
        query = Query(
            qid=qid, program=prog.name, source=source, t_arrival=at,
            deadline=None if budget is None else at + budget,
        )
        try:
            self.queue.push(query)
        except LoadShedError:
            self._counters["load_shed"] += 1
            self._fail(query, "load_shed", at)
        return qid

    def pump(self, now: Optional[float] = None) -> int:
        """Execute every micro-batch due at `now` (full lanes plus lanes
        past their deadline). Returns the number of queries terminated
        (answered or failed with a named reason)."""
        now = self._clock if now is None else float(now)
        self._clock = max(self._clock, now)
        done = 0
        for batch in self.queue.pop_due(self._clock):
            self._clock = self._execute(batch, self._clock)
            done += len(batch)
        return done

    def drain(self) -> int:
        """Force-flush everything still queued."""
        done = 0
        for batch in self.queue.pop_all():
            self._clock = self._execute(batch, self._clock)
            done += len(batch)
        return done

    def result(self, qid: int) -> Union[QueryResult, QueryFailure]:
        """The query's terminal outcome: a `QueryResult` answer or a
        named `QueryFailure` (check `.ok`)."""
        if qid in self._results:
            return self._results[qid]
        if qid in self._failures:
            return self._failures[qid]
        raise KeyError(f"query {qid} has no result yet (still queued? call pump/drain)")

    # ------------------------------------------------------------ execution

    def _sub_for(self, prog):
        """The program's build of the shared partition (bidirectional
        programs run the symmetrized build), cached by the pipeline."""
        return self.pipeline.subgraphs_for(**self.pipeline._build_params_for(prog, None, None))

    def _key_for(self, prog, sub, bucket: int, backend: str) -> tuple:
        return (
            prog.name, int(bucket), sub.num_parts, sub.max_v, sub.max_e, sub.max_msg,
            prog.dtype, backend, self.max_supersteps, self.inner_cap, self.tol,
        )

    def _executable(self, prog, sub, bucket: int, backend: Optional[str] = None):
        backend = self.compute_backend if backend is None else backend
        return self.cache.get(
            self._key_for(prog, sub, bucket, backend),
            lambda: compile_batch_executable(
                sub, prog, bucket,
                max_supersteps=self.max_supersteps, inner_cap=self.inner_cap, tol=self.tol,
                num_vertices=self.pipeline.graph.num_vertices,
                compute_backend=backend,
            ),
        )

    def warm(self, programs, buckets=None) -> float:
        """Precompile executables for `programs` × `buckets` (default: the
        server's whole ladder) so live traffic never pays a compile.
        Returns total compile seconds."""
        t0 = time.perf_counter()
        for program in programs:
            prog = get_program(program)
            sub = self._sub_for(prog)
            for b in (self.buckets if buckets is None else buckets):
                self._executable(prog, sub, int(b))
        return time.perf_counter() - t0

    def _fail(self, query, error: str, now: float, retries: int = 0) -> None:
        self._failures[query.qid] = QueryFailure(
            qid=query.qid, program=query.program, source=query.source, error=error,
            t_arrival=query.t_arrival, t_done=now, retries=retries,
        )

    def _drop_expired(self, queries: list, now: float, retries: int = 0) -> list:
        live = []
        for q in queries:
            if q.deadline is not None and now >= q.deadline:
                self._counters["deadline_exceeded"] += 1
                self._fail(q, "deadline_exceeded", now, retries)
            else:
                live.append(q)
        return live

    def _run_batch(self, prog, sub, queries: list, backend: str, path: str):
        """One execution attempt at a degradation rung. Returns
        (per-query values, per-query stats, wall_s, bucket)."""
        nv = self.pipeline.graph.num_vertices
        if path == "host":
            # Deepest rung: per-query host-driver runs — one dispatch per
            # superstep, no batching, no kernels. Slowest, simplest,
            # bit-identical (driver-parity suites).
            t0 = time.perf_counter()
            vals, stats = [], []
            for q in queries:
                v, s = run_bsp(
                    sub, prog, driver="host", compute_backend=backend,
                    max_supersteps=self.max_supersteps, inner_cap=self.inner_cap,
                    tol=self.tol, num_vertices=nv, source=q.source,
                )
                vals.append(np.asarray(v)[:, :-1])  # strip the dump slot
                stats.append(s)
            return vals, stats, time.perf_counter() - t0, len(queries)
        bucket = bucket_size(len(queries), self.buckets)
        exe = self._executable(prog, sub, bucket, backend)
        t0 = time.perf_counter()
        if prog.needs_source:
            init = batch_init(
                prog, sub, pad_items([q.source for q in queries], bucket), num_vertices=nv
            )
        else:
            init = batch_init(prog, sub, batch=bucket, num_vertices=nv)
        vals, stats = exe.run(init)
        wall = time.perf_counter() - t0
        vals = np.asarray(vals[:, :, :-1])  # strip the dump slot; padding lanes dropped
        return [vals[i] for i in range(len(queries))], stats, wall, bucket

    def _execute(self, queries: list, t_start: float) -> float:
        """Run one micro-batch through the resilient path; returns its
        completion time (t_start plus injected straggler delay, backoff
        waits, and the real execution wall — the virtual clock is charged
        what the hardware actually took). Every query in the batch
        terminates: answered, or failed with a named reason."""
        prog = get_program(queries[0].program)
        sub = self._sub_for(prog)
        now = t_start
        batch_seq = self._batch_seq
        self._batch_seq += 1
        if self.fault_plan is not None:
            delay = self.fault_plan.straggler_delay(batch_seq)
            if delay:
                self._counters["stragglers"] += 1
                now += delay
        live = self._drop_expired(queries, now)
        if not live:
            return now
        retries = 0
        while True:
            probing = self.breaker.should_probe()
            level = self.breaker.level - 1 if probing else self.breaker.level
            backend, path = self.levels[min(max(level, 0), len(self.levels) - 1)]
            attempt = self._attempt
            self._attempt += 1
            try:
                if self.fault_plan is not None:
                    if self.fault_plan.malformed_batch(attempt):
                        self._counters["malformed_batches"] += 1
                        raise MalformedBatchError(
                            f"injected malformed batch (attempt {attempt})"
                        )
                    if self.fault_plan.transient_fault(attempt, backend=backend, driver=path):
                        self._counters["faults_injected"] += 1
                        raise TransientBackendError(
                            f"injected transient {backend}/{path} fault (attempt {attempt})"
                        )
                vals, stats, wall, bucket = self._run_batch(prog, sub, live, backend, path)
            except _RETRYABLE as e:
                self.breaker.record_failure(probe=probing)
                if retries >= self.retry.max_retries:
                    log.warning("batch %d: %s; retry budget exhausted", batch_seq, e)
                    self._counters["retries_exhausted"] += len(live)
                    for q in live:
                        self._fail(q, "retries_exhausted", now, retries)
                    return now
                backoff = self.retry.backoff_s(
                    retries,
                    seed=0 if self.fault_plan is None else self.fault_plan.seed,
                    token=attempt,
                )
                log.info("batch %d: %s; retry %d in %.4fs", batch_seq, e, retries + 1, backoff)
                now += backoff
                retries += 1
                self._counters["retries"] += 1
                live = self._drop_expired(live, now, retries)
                if not live:
                    return now
            else:
                self.breaker.record_success(probe=probing)
                if level > 0:
                    self._counters["degraded_batches"] += 1
                break
        t_done = now + wall
        for i, q in enumerate(live):
            self._results[q.qid] = QueryResult(
                qid=q.qid, program=prog.name, source=q.source, values=vals[i],
                stats=stats[i], t_arrival=q.t_arrival, t_done=t_done,
                batch=len(live), bucket=bucket,
            )
        self._batch_log.append((prog.name, len(live), bucket, wall))
        return t_done

    # ------------------------------------------------------------- replay

    def run_trace(self, trace, *, warm: bool = True) -> ServerReport:
        """Replay [(t, program, source)] through the queueing discipline
        on a virtual clock: arrivals are admitted in time order, a full
        lane flushes on the admission that fills it, a partial lane
        flushes when its deadline passes, and each batch's REAL execution
        wall advances the clock (so queue latency includes waiting behind
        earlier batches). `warm=True` precompiles every (program, bucket)
        first — steady-state behaviour, no compile in the latency path."""
        events = sorted(trace, key=lambda e: e[0])
        if not events:
            raise ValueError("empty trace")
        if warm:
            self.warm({program for _, program, _ in events})
        t_first = float(events[0][0])
        self._clock = max(self._clock, t_first)
        i = 0
        while i < len(events) or len(self.queue):
            deadline = self.queue.next_deadline()
            if i < len(events) and (deadline is None or events[i][0] <= deadline):
                t, program, source = events[i]
                i += 1
                self._clock = max(self._clock, float(t))
                self.submit(program, source, at=float(t))
                for batch in self.queue.pop_full():
                    self._clock = self._execute(batch, self._clock)
            else:
                self._clock = max(self._clock, deadline)
                for batch in self.queue.pop_due(self._clock):
                    self._clock = self._execute(batch, self._clock)
        return self.report(wall_s=self._clock - t_first)

    def resilience_counters(self) -> dict:
        """Fault-path accounting: counters, breaker state, and the
        answered/failed split. `terminated` == answered + failed is the
        every-query-accounted-for invariant chaos CI asserts."""
        return {
            **self._counters,
            "breaker_level": self.breaker.level,
            "breaker_transitions": len(self.breaker.transitions),
            "answered": len(self._results),
            "failed": len(self._failures),
            "terminated": len(self._results) + len(self._failures),
        }

    def report(self, wall_s: Optional[float] = None) -> ServerReport:
        results = list(self._results.values())
        if not results and not self._failures:
            raise RuntimeError("no answered queries to report on")
        lat = np.asarray([r.latency_s for r in results]) if results else np.zeros((1,))
        if wall_s is None:
            done = [r.t_done for r in results] or [f.t_done for f in self._failures.values()]
            arr = [r.t_arrival for r in results] or [f.t_arrival for f in self._failures.values()]
            wall_s = float(max(done) - min(arr))
        reals = sum(n for _, n, _, _ in self._batch_log)
        pads = sum(b for _, _, b, _ in self._batch_log)
        nbatches = max(len(self._batch_log), 1)
        return ServerReport(
            queries=len(results),
            wall_s=float(wall_s),
            throughput_qps=len(results) / wall_s if wall_s > 0 else float("inf"),
            latency_p50_s=float(np.percentile(lat, 50)),
            latency_p99_s=float(np.percentile(lat, 99)),
            batches=len(self._batch_log),
            mean_batch=reals / nbatches,
            padding_waste=padding_waste(reals, pads) if pads else 0.0,
            supersteps_mean=float(np.mean([r.supersteps for r in results])) if results else 0.0,
            cache=self.cache.stats(),
            resilience=self.resilience_counters(),
        )
