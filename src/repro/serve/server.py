"""`GraphQueryServer` — the persistent graph-query serving loop.

One server wraps one partitioned `GraphPipeline` and answers point
queries over its shared subgraph structure:

  submit → admission queue (per-program lanes, full/deadline flush) →
  pad to bucket → warm `BatchExecutable` (compiled once per
  (program, bucket) key) → one fused batched BSP dispatch →
  per-query results + `BSPStats`.

Per-query answers are bit-identical to single-source `run_bsp` calls:
padding lanes repeat a real query and are discarded after execution, and
convergence masking means each query's stats report the supersteps IT
paid, not the batch max.

Time is explicit rather than wall-clock-implicit so the server is
drivable both live (`submit()` + `pump()` with real timestamps) and in
simulation (`run_trace` replays a synthetic trace on a virtual clock,
charging real execution walls against it) — the same single-server
queueing discipline either way.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.api.config import check_compute_backend
from repro.graph.engine import (
    BSPStats,
    batch_init,
    check_source,
    compile_batch_executable,
    get_program,
)
from repro.serve.cache import ExecutableCache
from repro.serve.padding import DEFAULT_BUCKETS, bucket_size, pad_items, padding_waste
from repro.serve.queue import AdmissionQueue, Query


@dataclasses.dataclass
class QueryResult:
    """One answered query: values are [p, max_v] (dump slot stripped),
    stats are THIS query's BSPStats under masking (its own superstep
    count). `batch`/`bucket` record the micro-batch it rode in."""

    qid: int
    program: str
    source: Optional[int]
    values: np.ndarray
    stats: BSPStats
    t_arrival: float
    t_done: float
    batch: int
    bucket: int

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_arrival

    @property
    def supersteps(self) -> int:
        return self.stats.supersteps


@dataclasses.dataclass
class ServerReport:
    """Aggregate serving metrics over everything the server answered."""

    queries: int
    wall_s: float
    throughput_qps: float
    latency_p50_s: float
    latency_p99_s: float
    batches: int
    mean_batch: float
    padding_waste: float
    supersteps_mean: float
    cache: dict

    def row(self) -> dict:
        return {
            "queries": self.queries,
            "wall_s": round(self.wall_s, 4),
            "throughput_qps": round(self.throughput_qps, 1),
            "latency_p50_s": round(self.latency_p50_s, 5),
            "latency_p99_s": round(self.latency_p99_s, 5),
            "batches": self.batches,
            "mean_batch": round(self.mean_batch, 2),
            "padding_waste": round(self.padding_waste, 4),
            "supersteps_mean": round(self.supersteps_mean, 2),
            "cache": self.cache,
        }


class GraphQueryServer:
    """See module docstring. Knobs:

    max_batch / max_delay_s — the admission queue's flush policy (full
    batch fires immediately; a lone query waits at most max_delay_s).
    buckets — padded-batch ladder; defaults to the shared power-of-two
    ladder truncated at max_batch's bucket.
    max_supersteps / inner_cap / tol / compute_backend — engine knobs
    baked into every compiled executable (part of the cache key).
    """

    def __init__(
        self,
        pipeline,
        *,
        max_batch: int = 8,
        max_delay_s: float = 0.005,
        buckets=None,
        compute_backend: str = "xla",
        max_supersteps: Optional[int] = None,
        inner_cap: int = 10_000,
        tol: float = 0.0,
    ):
        if pipeline.graph is None:
            raise RuntimeError("abstract (from_spec) pipelines cannot serve queries")
        pipeline._stage()  # require a partition stage up front
        top = bucket_size(max_batch, DEFAULT_BUCKETS if buckets is None else buckets)
        self.buckets = (
            tuple(b for b in DEFAULT_BUCKETS if b <= top) if buckets is None else tuple(buckets)
        )
        if bucket_size(max_batch, self.buckets) > max_batch:
            raise ValueError(
                f"buckets {self.buckets} cannot hold a full batch of {max_batch} "
                "without padding — include max_batch's bucket"
            )
        self.pipeline = pipeline
        self.compute_backend = check_compute_backend(compute_backend)
        self.max_supersteps = max_supersteps
        self.inner_cap = inner_cap
        self.tol = tol
        self.queue = AdmissionQueue(max_batch=max_batch, max_delay_s=max_delay_s)
        self.cache = ExecutableCache()
        self._results: dict[int, QueryResult] = {}
        self._batch_log: list[tuple] = []  # (program, n_real, bucket, exec_wall_s)
        self._next_qid = 0
        self._clock = 0.0

    # ------------------------------------------------------------ admission

    def submit(self, program, source: Optional[int] = None, *, at: Optional[float] = None) -> int:
        """Admit one query; returns its qid. Source-rooted programs
        validate `source` HERE — a bad source rejects this query alone,
        before it can join (and poison) a micro-batch."""
        prog = get_program(program)
        sub = self._sub_for(prog)
        if prog.needs_source:
            source = check_source(sub, source, self.pipeline.graph.num_vertices)
        elif source is not None:
            raise ValueError(
                f"program {prog.name!r} is a whole-graph query; source= does not apply"
            )
        at = self._clock if at is None else float(at)
        self._clock = max(self._clock, at)
        qid = self._next_qid
        self._next_qid += 1
        self.queue.push(Query(qid=qid, program=prog.name, source=source, t_arrival=at))
        return qid

    def pump(self, now: Optional[float] = None) -> int:
        """Execute every micro-batch due at `now` (full lanes plus lanes
        past their deadline). Returns the number of queries answered."""
        now = self._clock if now is None else float(now)
        self._clock = max(self._clock, now)
        done = 0
        for batch in self.queue.pop_due(self._clock):
            self._clock = self._execute(batch, self._clock)
            done += len(batch)
        return done

    def drain(self) -> int:
        """Force-flush everything still queued."""
        done = 0
        for batch in self.queue.pop_all():
            self._clock = self._execute(batch, self._clock)
            done += len(batch)
        return done

    def result(self, qid: int) -> QueryResult:
        if qid not in self._results:
            raise KeyError(f"query {qid} has no result yet (still queued? call pump/drain)")
        return self._results[qid]

    # ------------------------------------------------------------ execution

    def _sub_for(self, prog):
        """The program's build of the shared partition (bidirectional
        programs run the symmetrized build), cached by the pipeline."""
        return self.pipeline.subgraphs_for(**self.pipeline._build_params_for(prog, None, None))

    def _key_for(self, prog, sub, bucket: int) -> tuple:
        return (
            prog.name, int(bucket), sub.num_parts, sub.max_v, sub.max_e, sub.max_msg,
            prog.dtype, self.compute_backend, self.max_supersteps, self.inner_cap, self.tol,
        )

    def _executable(self, prog, sub, bucket: int):
        return self.cache.get(
            self._key_for(prog, sub, bucket),
            lambda: compile_batch_executable(
                sub, prog, bucket,
                max_supersteps=self.max_supersteps, inner_cap=self.inner_cap, tol=self.tol,
                num_vertices=self.pipeline.graph.num_vertices,
                compute_backend=self.compute_backend,
            ),
        )

    def warm(self, programs, buckets=None) -> float:
        """Precompile executables for `programs` × `buckets` (default: the
        server's whole ladder) so live traffic never pays a compile.
        Returns total compile seconds."""
        t0 = time.perf_counter()
        for program in programs:
            prog = get_program(program)
            sub = self._sub_for(prog)
            for b in (self.buckets if buckets is None else buckets):
                self._executable(prog, sub, int(b))
        return time.perf_counter() - t0

    def _execute(self, queries: list, t_start: float) -> float:
        """Run one micro-batch; returns its completion time (t_start plus
        the real execution wall — the virtual clock is charged what the
        hardware actually took)."""
        prog = get_program(queries[0].program)
        sub = self._sub_for(prog)
        bucket = bucket_size(len(queries), self.buckets)
        exe = self._executable(prog, sub, bucket)
        nv = self.pipeline.graph.num_vertices
        t0 = time.perf_counter()
        if prog.needs_source:
            init = batch_init(
                prog, sub, pad_items([q.source for q in queries], bucket), num_vertices=nv
            )
        else:
            init = batch_init(prog, sub, batch=bucket, num_vertices=nv)
        vals, stats = exe.run(init)
        wall = time.perf_counter() - t0
        vals = np.asarray(vals[:, :, :-1])  # strip the dump slot; padding lanes dropped below
        t_done = t_start + wall
        for i, q in enumerate(queries):
            self._results[q.qid] = QueryResult(
                qid=q.qid, program=prog.name, source=q.source, values=vals[i],
                stats=stats[i], t_arrival=q.t_arrival, t_done=t_done,
                batch=len(queries), bucket=bucket,
            )
        self._batch_log.append((prog.name, len(queries), bucket, wall))
        return t_done

    # ------------------------------------------------------------- replay

    def run_trace(self, trace, *, warm: bool = True) -> ServerReport:
        """Replay [(t, program, source)] through the queueing discipline
        on a virtual clock: arrivals are admitted in time order, a full
        lane flushes on the admission that fills it, a partial lane
        flushes when its deadline passes, and each batch's REAL execution
        wall advances the clock (so queue latency includes waiting behind
        earlier batches). `warm=True` precompiles every (program, bucket)
        first — steady-state behaviour, no compile in the latency path."""
        events = sorted(trace, key=lambda e: e[0])
        if not events:
            raise ValueError("empty trace")
        if warm:
            self.warm({program for _, program, _ in events})
        t_first = float(events[0][0])
        self._clock = max(self._clock, t_first)
        i = 0
        while i < len(events) or len(self.queue):
            deadline = self.queue.next_deadline()
            if i < len(events) and (deadline is None or events[i][0] <= deadline):
                t, program, source = events[i]
                i += 1
                self._clock = max(self._clock, float(t))
                self.submit(program, source, at=float(t))
                for batch in self.queue.pop_full():
                    self._clock = self._execute(batch, self._clock)
            else:
                self._clock = max(self._clock, deadline)
                for batch in self.queue.pop_due(self._clock):
                    self._clock = self._execute(batch, self._clock)
        return self.report(wall_s=self._clock - t_first)

    def report(self, wall_s: Optional[float] = None) -> ServerReport:
        results = list(self._results.values())
        if not results:
            raise RuntimeError("no answered queries to report on")
        lat = np.asarray([r.latency_s for r in results])
        if wall_s is None:
            wall_s = float(max(r.t_done for r in results) - min(r.t_arrival for r in results))
        reals = sum(n for _, n, _, _ in self._batch_log)
        pads = sum(b for _, _, b, _ in self._batch_log)
        return ServerReport(
            queries=len(results),
            wall_s=float(wall_s),
            throughput_qps=len(results) / wall_s if wall_s > 0 else float("inf"),
            latency_p50_s=float(np.percentile(lat, 50)),
            latency_p99_s=float(np.percentile(lat, 99)),
            batches=len(self._batch_log),
            mean_batch=reals / len(self._batch_log),
            padding_waste=padding_waste(reals, pads) if pads else 0.0,
            supersteps_mean=float(np.mean([r.supersteps for r in results])),
            cache=self.cache.stats(),
        )
