"""Warm compiled-executable cache for the serving tier.

Keys are (program, padded batch, num_workers, padded shapes, value dtype,
compute backend, engine knobs) tuples — everything that changes the
compiled program. `get` builds on first miss and replays the stored
executable forever after, counting hits/misses and compiles per key so
the benchmark can assert the steady-state claim: at most ONE compile per
(program, bucket), and a hit rate that approaches 1 as traffic flows.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable


@dataclasses.dataclass
class _Entry:
    value: object
    build_s: float
    hits: int = 0


class ExecutableCache:
    def __init__(self):
        self._entries: dict[tuple, _Entry] = {}
        self._compiles: collections.Counter = collections.Counter()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: tuple) -> bool:
        return key in self._entries

    def get(self, key: tuple, build: Callable[[], object]):
        """Cached value for `key`, calling `build` exactly once per key."""
        entry = self._entries.get(key)
        if entry is not None:
            entry.hits += 1
            self.hits += 1
            return entry.value
        self.misses += 1
        self._compiles[key] += 1
        t0 = time.perf_counter()
        value = build()
        self._entries[key] = _Entry(value=value, build_s=time.perf_counter() - t0)
        return value

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    @property
    def compile_s(self) -> float:
        return sum(e.build_s for e in self._entries.values())

    def stats(self) -> dict:
        """Machine-readable cache section for benchmark reports."""
        return {
            "keys": len(self._entries),
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": round(self.hit_rate, 4),
            "compiles_per_key_max": max(self._compiles.values(), default=0),
            "compile_s": round(self.compile_s, 3),
        }
