"""repro.serve — the graph-query serving tier.

Turns the one-shot partition → build → run pipeline into a persistent
query server over a shared partitioned graph: an admission queue
micro-batches point queries per program (`repro.serve.queue`), batches are
padded to a small set of bucket sizes (`repro.serve.padding`, shared with
the LM serving loop in `repro.launch.serve`), and each (program, bucket)
executes through a warm AOT-compiled batched BSP executable
(`repro.serve.cache` + `repro.graph.engine.compile_batch_executable`) so
steady-state traffic never recompiles. Per-query results and `BSPStats`
are bit-identical to single-source `run_bsp` calls — convergence masking
means a query pays only its own supersteps, not the batch max.

The serving path is resilient (`repro.resilience`): per-query deadlines,
a bounded admission queue with reject-newest load shedding, bounded
retry with deterministic backoff for transient backend faults, and a
circuit breaker that degrades pallas → xla and fused batch → host driver
under consecutive failures — bit-identical answers at every rung. Every
admitted query terminates as a `QueryResult` or a named `QueryFailure`.

Entry points: `GraphPipeline.serve()` returns a `GraphQueryServer`;
`GraphPipeline.run_batch()` is the one-shot batched call; the
`repro.launch.graph_serve` CLI replays a synthetic power-law trace.
"""
from repro.serve.cache import ExecutableCache
from repro.serve.padding import DEFAULT_BUCKETS, bucket_size, pad_batch_rows, padding_waste
from repro.serve.queue import AdmissionQueue, Query
from repro.serve.server import GraphQueryServer, QueryFailure, QueryResult, ServerReport
from repro.serve.trace import synthetic_trace

__all__ = [
    "AdmissionQueue",
    "DEFAULT_BUCKETS",
    "ExecutableCache",
    "GraphQueryServer",
    "Query",
    "QueryFailure",
    "QueryResult",
    "ServerReport",
    "bucket_size",
    "pad_batch_rows",
    "padding_waste",
    "synthetic_trace",
]
