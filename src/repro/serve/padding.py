"""Shared padded-batch policy for BOTH serving tiers.

A ragged request batch is padded up to the smallest member of a fixed
bucket ladder before it reaches a jitted/AOT-compiled executable, so the
number of distinct compiled shapes stays bounded: steady-state traffic
hits a warm executable for its (program, bucket) key instead of
recompiling per batch size. The graph-query server
(`repro.serve.server`) and the LM batched-serving driver
(`repro.launch.serve`) share this one policy — same ladder, same
rounding, same waste accounting.
"""
from __future__ import annotations

import numpy as np

# Powers of two: each bucket at most doubles the work of the batch it
# rounds up, so padding waste is bounded at 50% while the executable
# count stays logarithmic in the largest batch.
DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32, 64)


def bucket_size(n: int, buckets=DEFAULT_BUCKETS) -> int:
    """Smallest bucket >= n — the padded batch size for a batch of n.

    >>> bucket_size(1)
    1
    >>> bucket_size(2)
    2
    >>> bucket_size(3)
    4
    >>> bucket_size(4)
    4
    >>> bucket_size(5)
    8
    >>> bucket_size(8)
    8
    >>> bucket_size(9)
    16
    >>> bucket_size(64)
    64
    >>> bucket_size(6, buckets=(2, 8))
    8
    >>> bucket_size(0)
    Traceback (most recent call last):
        ...
    ValueError: batch size must be >= 1, got 0
    >>> bucket_size(65)
    Traceback (most recent call last):
        ...
    ValueError: batch of 65 exceeds the largest bucket 64
    """
    if n < 1:
        raise ValueError(f"batch size must be >= 1, got {n}")
    for b in sorted(buckets):
        if n <= b:
            return int(b)
    raise ValueError(f"batch of {n} exceeds the largest bucket {max(buckets)}")


def padding_waste(n: int, bucket: int) -> float:
    """Fraction of the padded batch that is padding.

    >>> padding_waste(3, 4)
    0.25
    >>> padding_waste(8, 8)
    0.0
    """
    if not 1 <= n <= bucket:
        raise ValueError(f"need 1 <= n <= bucket, got n={n}, bucket={bucket}")
    return float(bucket - n) / float(bucket)


def pad_items(items: list, bucket: int) -> list:
    """Pad a request list to its bucket by repeating the last item.

    The repeats are discarded after execution; repeating a REAL request
    (instead of a sentinel) keeps padded lanes on the same convergence
    trajectory as a live lane, so they never become the batch straggler.

    >>> pad_items([10, 11, 12], 4)
    [10, 11, 12, 12]
    >>> pad_items([7], 1)
    [7]
    """
    if not 1 <= len(items) <= bucket:
        raise ValueError(f"need 1 <= len(items) <= bucket, got {len(items)}, bucket={bucket}")
    return list(items) + [items[-1]] * (bucket - len(items))


def pad_batch_rows(x: np.ndarray, bucket: int) -> np.ndarray:
    """Pad a [B, ...] array to [bucket, ...] by repeating the last row
    (the LM serving loop's view of `pad_items`: prompts are token rows).

    >>> pad_batch_rows(np.array([[1, 2], [3, 4]]), 4).tolist()
    [[1, 2], [3, 4], [3, 4], [3, 4]]
    >>> pad_batch_rows(np.array([[1, 2]]), 1).tolist()
    [[1, 2]]
    """
    x = np.asarray(x)
    if not 1 <= x.shape[0] <= bucket:
        raise ValueError(f"need 1 <= rows <= bucket, got {x.shape[0]}, bucket={bucket}")
    if x.shape[0] == bucket:
        return x
    return np.concatenate([x, np.repeat(x[-1:], bucket - x.shape[0], axis=0)], axis=0)
