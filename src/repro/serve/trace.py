"""Synthetic power-law query trace for serving benchmarks.

Production graph services see power-law QUERY traffic on top of their
power-law graphs: a few hub entities are asked about constantly, the
long tail rarely. We model that by sampling source vertices proportional
to degree (the graph's own skew becomes the query popularity skew),
Poisson arrivals at `rate_qps`, and a program mix over the registered
`VertexProgram`s (point queries: BFS hops, s-t distance via SSSP, plus
whole-graph refreshes like CC/PageRank if the mix asks for them).
"""
from __future__ import annotations

import numpy as np

from repro.core.types import Graph
from repro.graph.engine import get_program


def synthetic_trace(
    graph: Graph,
    num_queries: int,
    *,
    rate_qps: float = 1000.0,
    mix=(("bfs", 0.5), ("sssp", 0.5)),
    seed: int = 0,
    t0: float = 0.0,
) -> list:
    """[(t, program, source)] sorted by arrival time.

    `mix` is ((program_name, weight), ...); weights are normalized.
    Source-rooted programs get a degree-proportional source draw;
    source-free programs get source=None.
    """
    if num_queries < 1:
        raise ValueError(f"num_queries must be >= 1, got {num_queries}")
    if rate_qps <= 0:
        raise ValueError(f"rate_qps must be > 0, got {rate_qps}")
    progs = [get_program(name) for name, _ in mix]
    weights = np.asarray([w for _, w in mix], np.float64)
    weights = weights / weights.sum()
    rng = np.random.default_rng(seed)
    cov = graph.covered_vertices()
    deg = graph.degrees()[cov].astype(np.float64)
    popularity = deg / deg.sum()
    times = t0 + np.cumsum(rng.exponential(1.0 / rate_qps, num_queries))
    picks = rng.choice(len(progs), size=num_queries, p=weights)
    sources = rng.choice(cov, size=num_queries, p=popularity)
    return [
        (
            float(times[i]),
            progs[picks[i]].name,
            int(sources[i]) if progs[picks[i]].needs_source else None,
        )
        for i in range(num_queries)
    ]
