"""Admission queue + micro-batching flush policy for the query server.

Each registered program gets one lane; a query joins its program's lane
at admission. A lane flushes as a micro-batch when either

  * it holds `max_batch` queries (FULL flush — fires immediately on the
    admission that filled it), or
  * its oldest query has waited `max_delay_s` (DEADLINE flush — bounds
    the queue latency a lone query can pay waiting for batch-mates).

This is the standard throughput-vs-latency knob pair of batched serving
(the LM loop in `repro.launch.serve` plays the same game with prompt
batches); the server pads each flushed batch to its bucket
(`repro.serve.padding`) before execution.

`max_queue` bounds the total queued backlog: admission past the bound is
load-shed with a named `LoadShedError` (reject-newest — queued queries
keep their place; the arriving one is refused). The server records the
shed query as a `QueryFailure` instead of letting the backlog grow
without bound under overload.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.resilience.faults import LoadShedError


@dataclasses.dataclass(frozen=True)
class Query:
    """One admitted point query. `source` is None for source-free
    (whole-graph) programs; `t_arrival` is the admission timestamp the
    flush deadline and the latency accounting run on; `deadline` (when
    set) is the absolute instant after which the answer is worthless —
    the server drops the query with a named timeout result instead of
    executing it."""

    qid: int
    program: str
    source: Optional[int]
    t_arrival: float
    deadline: Optional[float] = None


class AdmissionQueue:
    def __init__(
        self, *, max_batch: int = 8, max_delay_s: float = 0.005,
        max_queue: Optional[int] = None,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_delay_s < 0:
            raise ValueError(f"max_delay_s must be >= 0, got {max_delay_s}")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.max_batch = int(max_batch)
        self.max_delay_s = float(max_delay_s)
        self.max_queue = None if max_queue is None else int(max_queue)
        self._lanes: dict[str, list[Query]] = {}

    def __len__(self) -> int:
        return sum(len(lane) for lane in self._lanes.values())

    def push(self, query: Query) -> None:
        if self.max_queue is not None and len(self) >= self.max_queue:
            raise LoadShedError(
                f"admission queue is full ({self.max_queue} queued): query "
                f"{query.qid} shed (reject-newest)"
            )
        self._lanes.setdefault(query.program, []).append(query)

    def next_deadline(self) -> Optional[float]:
        """Earliest instant any lane's oldest query exhausts its wait
        budget (None when the queue is empty)."""
        heads = [lane[0].t_arrival for lane in self._lanes.values() if lane]
        return min(heads) + self.max_delay_s if heads else None

    def pop_full(self) -> list[list[Query]]:
        """Pop every full micro-batch (len == max_batch), oldest first."""
        batches = []
        for lane in self._lanes.values():
            while len(lane) >= self.max_batch:
                batches.append(lane[: self.max_batch])
                del lane[: self.max_batch]
        return batches

    def pop_due(self, now: float) -> list[list[Query]]:
        """Pop full batches plus every lane whose oldest query has waited
        past the deadline at time `now` (deadline batches may be partial —
        that is the padding the bucket policy absorbs)."""
        batches = self.pop_full()
        for lane in self._lanes.values():
            if lane and now >= lane[0].t_arrival + self.max_delay_s:
                batches.append(lane[:])
                lane.clear()
        return batches

    def pop_all(self) -> list[list[Query]]:
        """Drain everything (forced flush), chunked at max_batch."""
        batches = self.pop_full()
        for lane in self._lanes.values():
            if lane:
                batches.append(lane[:])
                lane.clear()
        return batches
