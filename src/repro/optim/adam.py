"""AdamW with dtype-configurable sharded states (ZeRO-1 by construction:
optimizer-state leaves inherit the parameter sharding, which is already
FSDP/TP-sharded) + global-norm clipping + linear-warmup cosine schedule +
optional bf16 gradient compression for the data-parallel all-reduce.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    state_dtype: Any = jnp.float32  # bf16 halves optimizer HBM (big-MoE configs)
    compress_grads: Optional[str] = None  # None | "bf16"


def init_opt_state(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return dict(
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    prog = jnp.clip((step - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * prog))


def _global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """One AdamW step. Returns (params, state, metrics)."""
    if cfg.compress_grads == "bf16":
        # Gradient compression: cast BEFORE the DP all-reduce boundary — with
        # sharded grads XLA reduces in bf16, halving the collective term.
        grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state["step"]
    lr = _schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * g * g
        mhat = mu32 / bc1
        vhat = nu32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, mu32.astype(cfg.state_dtype), nu32.astype(cfg.state_dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    new_state = dict(mu=new_mu, nu=new_nu, step=step + 1)
    return new_p, new_state, dict(grad_norm=gnorm, lr=lr)
