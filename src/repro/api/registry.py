"""Partitioner protocol + decorator registry.

Partitioner modules self-register at import time:

    @register_partitioner("ebg", config=EBGConfig, jit_compatible=True)
    def ebg_partition(graph, num_parts, *, alpha=1.0, ...): ...

The registry is the single source of truth for enumeration: the legacy
`repro.core.PARTITIONERS` mapping (`RegistryFunctionView`), the benchmark
suite's partitioner list (`benchmark_partitioners`), and the CLI name
validation (`partitioner_names`) are all derived views.

This module deliberately imports nothing from `repro.core` at module
scope — core partitioner modules import *us* to register themselves, and
`_ensure_builtins` imports `repro.core` lazily the first time the
registry is queried.
"""
from __future__ import annotations

import dataclasses
import inspect
from collections.abc import Mapping
from typing import Callable, Optional, Protocol, runtime_checkable

from repro.api.config import PartitionerConfig, check_compute_backend


def check_num_parts(num_parts) -> None:
    if not isinstance(num_parts, int) or isinstance(num_parts, bool) or num_parts < 1:
        raise ValueError(f"num_parts must be a positive int, got {num_parts!r}")


@runtime_checkable
class Partitioner(Protocol):
    """Anything that maps (graph, num_parts, **knobs) -> PartitionResult."""

    def __call__(self, graph, num_parts: int, **kwargs):  # pragma: no cover
        ...


@dataclasses.dataclass(frozen=True)
class PartitionerSpec:
    """A registered partitioner: callable + config schema + capabilities."""

    name: str
    fn: Callable
    config_cls: type
    deterministic: bool = True  # same inputs (incl. seed) -> same partition
    chunked: bool = False  # processes edges in vectorized blocks
    jit_compatible: bool = False  # core loop runs under jax.jit
    benchmark_default: bool = True  # included in the paper benchmark suite
    compute_backends: tuple = ("xla",)  # hot-path impls the algorithm accepts
    scorer: Optional[str] = None  # streaming EdgeScorer name, if on that core
    description: str = ""

    @property
    def accepted_kwargs(self) -> frozenset:
        """Keyword parameters of `fn` beyond (graph, num_parts)."""
        sig = inspect.signature(self.fn)
        return frozenset(
            n
            for n, p in sig.parameters.items()
            if p.kind in (p.KEYWORD_ONLY, p.POSITIONAL_OR_KEYWORD)
            and n not in ("graph", "num_parts")
        )

    def make_config(self, config: Optional[PartitionerConfig] = None, **overrides) -> PartitionerConfig:
        """Build (or update) this spec's config; raises on bad values."""
        if config is not None:
            if not isinstance(config, self.config_cls):
                raise TypeError(
                    f"partitioner {self.name!r} expects {self.config_cls.__name__}, "
                    f"got {type(config).__name__}"
                )
            return config.replace(**overrides) if overrides else config
        return self.config_cls(**overrides)

    def check_overrides(self, overrides: dict) -> None:
        """Explicitly-passed knobs must actually reach this algorithm.

        Config *fields* the fn ignores are fine (config classes are shared
        across variants), but a caller who names a knob deserves an error
        rather than a silent no-op — e.g. `block` on the unblocked scan.
        """
        unused = set(overrides) - self.accepted_kwargs
        if unused:
            raise ValueError(
                f"partitioner {self.name!r} does not use {sorted(unused)}; "
                f"its knobs are {sorted(self.accepted_kwargs)}"
            )

    def partition(self, graph, num_parts: int, config: Optional[PartitionerConfig] = None, **overrides):
        """Run the partitioner under a validated config."""
        check_num_parts(num_parts)
        cfg = self.make_config(config, **overrides)
        self.check_overrides(overrides)
        accepted = self.accepted_kwargs
        kwargs = {k: v for k, v in cfg.to_kwargs().items() if k in accepted}
        return self.fn(graph, num_parts, **kwargs)


_REGISTRY: dict[str, PartitionerSpec] = {}


def register_partitioner(
    name: str,
    *,
    config: type = PartitionerConfig,
    deterministic: bool = True,
    chunked: bool = False,
    jit_compatible: bool = False,
    benchmark_default: bool = True,
    compute_backends: tuple = ("xla",),
    scorer: Optional[str] = None,
    description: str = "",
):
    """Decorator: register `fn` under `name`. Returns `fn` unchanged, so
    legacy direct imports (`from repro.core import ebg_partition`) keep
    working bit-for-bit."""

    def deco(fn: Callable) -> Callable:
        if name in _REGISTRY:
            raise ValueError(f"partitioner {name!r} already registered ({_REGISTRY[name].fn})")
        desc = description
        if not desc and fn.__doc__:
            desc = fn.__doc__.strip().splitlines()[0]
        for b in compute_backends:
            check_compute_backend(b)
        _REGISTRY[name] = PartitionerSpec(
            name=name,
            fn=fn,
            config_cls=config,
            deterministic=deterministic,
            chunked=chunked,
            jit_compatible=jit_compatible,
            benchmark_default=benchmark_default,
            compute_backends=tuple(compute_backends),
            scorer=scorer,
            description=desc,
        )
        return fn

    return deco


def _ensure_builtins() -> None:
    """Importing repro.core registers all built-in partitioners."""
    import repro.core  # noqa: F401


def get_partitioner(name: str) -> PartitionerSpec:
    _ensure_builtins()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown partitioner {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def list_partitioners() -> tuple[PartitionerSpec, ...]:
    """All registered specs in registration order."""
    _ensure_builtins()
    return tuple(_REGISTRY.values())


def partitioner_names() -> tuple[str, ...]:
    return tuple(s.name for s in list_partitioners())


def benchmark_partitioners() -> tuple[str, ...]:
    """Names enumerated by the paper benchmark suite (derived, not hand-kept)."""
    return tuple(s.name for s in list_partitioners() if s.benchmark_default)


class RegistryFunctionView(Mapping):
    """LIVE `{name: fn}` view of the registry — backs the legacy
    `repro.core.PARTITIONERS` so partitioners registered after import are
    still visible through the old entry point."""

    def __getitem__(self, name: str) -> Callable:
        return get_partitioner(name).fn

    def __iter__(self):
        _ensure_builtins()
        return iter(list(_REGISTRY))

    def __len__(self) -> int:
        _ensure_builtins()
        return len(_REGISTRY)

    def __repr__(self) -> str:
        return f"RegistryFunctionView({list(self)})"
