"""Frozen per-algorithm partitioner configs for the `repro.api` registry.

Each config is an immutable dataclass validated at construction time
(`ValueError` on bad values). `PartitionerSpec.partition` maps a config
onto the underlying algorithm's keyword arguments, dropping fields the
algorithm does not accept — e.g. `block` is consumed only by the chunked
EBG variant, so `EBGConfig` can be shared by both EBG entry points.
"""
from __future__ import annotations

import dataclasses
import math


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


# Engine/partitioner hot-path implementations: "xla" = generic XLA segment
# ops (the historical path), "ref" = the pure-jnp kernel oracles in
# repro.kernels.ref, "pallas" = the Pallas TPU kernels (interpreted
# off-TPU). Canonical definition lives here so the registry, configs, and
# CLI drivers can validate names without importing jax.
COMPUTE_BACKENDS = ("xla", "ref", "pallas")


def check_compute_backend(backend) -> str:
    _require(
        backend in COMPUTE_BACKENDS,
        f"compute_backend must be one of {COMPUTE_BACKENDS}, got {backend!r}",
    )
    return backend


# Chunked-commit semantics: "frozen" scores every edge of a block against
# block-start membership (the classic chunked staleness trade); "window"
# is the speculative window commit — blocks are scored in one shot but
# conflicted edges replay against live state, making the assignments
# bit-identical to the unblocked scan at every block size.
COMMIT_MODES = ("frozen", "window")


def check_commit_mode(commit) -> str:
    _require(
        commit in COMMIT_MODES,
        f"commit must be one of {COMMIT_MODES}, got {commit!r}",
    )
    return commit


def _validate_seed(seed) -> None:
    _require(
        isinstance(seed, int) and not isinstance(seed, bool) and seed >= 0,
        f"seed must be a non-negative int, got {seed!r}",
    )


@dataclasses.dataclass(frozen=True)
class PartitionerConfig:
    """Base config. Subclasses override `validate` to raise ValueError."""

    def __post_init__(self) -> None:
        self.validate()

    def validate(self) -> None:  # pragma: no cover - overridden
        pass

    def replace(self, **changes) -> "PartitionerConfig":
        """Validated functional update (dataclasses.replace re-validates)."""
        return dataclasses.replace(self, **changes)

    def to_kwargs(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class EBGConfig(PartitionerConfig):
    """EBG knobs (paper Algorithm 1; the paper names the algorithm EBV).

    alpha/beta weight the edge/vertex balance terms of the evaluation
    function; `block` sizes the chunked variant's vectorized score block
    (ignored by the unblocked scan); `sort_edges` toggles the §IV-C
    degree-sum edge ordering; `compute_backend` selects the chunked
    variant's score-phase implementation ("xla" dense bool membership,
    "ref"/"pallas" packed-bitset membership via repro.kernels); `commit`
    picks the chunked commit semantics (see COMMIT_MODES — "window" makes
    any block size bit-identical to the faithful scan).
    """

    alpha: float = 1.0
    beta: float = 1.0
    block: int = 256
    sort_edges: bool = True
    compute_backend: str = "xla"
    commit: str = "frozen"

    def validate(self) -> None:
        _require(
            isinstance(self.alpha, (int, float)) and math.isfinite(self.alpha) and self.alpha > 0,
            f"alpha must be finite and > 0, got {self.alpha!r}",
        )
        _require(
            isinstance(self.beta, (int, float)) and math.isfinite(self.beta) and self.beta > 0,
            f"beta must be finite and > 0, got {self.beta!r}",
        )
        _require(
            isinstance(self.block, int) and not isinstance(self.block, bool) and self.block >= 1,
            f"block must be a positive int, got {self.block!r}",
        )
        _require(isinstance(self.sort_edges, bool), f"sort_edges must be a bool, got {self.sort_edges!r}")
        check_compute_backend(self.compute_backend)
        check_commit_mode(self.commit)


# The paper calls the algorithm EBV; the repo's modules call it EBG.
EBVConfig = EBGConfig


def _validate_streaming_knobs(cfg) -> None:
    """Shared validation for the chunked streaming-scorer knobs."""
    _require(
        isinstance(cfg.eps, (int, float)) and math.isfinite(cfg.eps) and cfg.eps > 0,
        f"eps must be finite and > 0, got {cfg.eps!r}",
    )
    _require(
        isinstance(cfg.block, int) and not isinstance(cfg.block, bool) and cfg.block >= 1,
        f"block must be a positive int, got {cfg.block!r}",
    )
    _require(isinstance(cfg.sort_edges, bool), f"sort_edges must be a bool, got {cfg.sort_edges!r}")
    check_compute_backend(cfg.compute_backend)
    check_commit_mode(cfg.commit)


@dataclasses.dataclass(frozen=True)
class HDRFConfig(PartitionerConfig):
    """HDRF knobs [Petroni et al., CIKM'15] on the streaming EdgeScorer core.

    `lam` weights the balance term against the degree-weighted replication
    term; `eps` is the balance normalizer's epsilon (1/(eps + max-min));
    `block`/`compute_backend` size and route the chunked commit loop
    (block=1 is the faithful sequential stream); `sort_edges` optionally
    applies the EBV degree-sum ordering (off by default — HDRF streams in
    input order).
    """

    lam: float = 1.0
    eps: float = 1.0
    block: int = 256
    sort_edges: bool = False
    compute_backend: str = "xla"
    commit: str = "frozen"

    def validate(self) -> None:
        _require(
            isinstance(self.lam, (int, float)) and math.isfinite(self.lam) and self.lam > 0,
            f"lam must be finite and > 0, got {self.lam!r}",
        )
        _validate_streaming_knobs(self)


@dataclasses.dataclass(frozen=True)
class GreedyConfig(PartitionerConfig):
    """PowerGraph Greedy knobs [Gonzalez et al., OSDI'12] on the streaming
    EdgeScorer core. Same knobs as HDRF minus the degree term's lambda."""

    eps: float = 1.0
    block: int = 256
    sort_edges: bool = False
    compute_backend: str = "xla"
    commit: str = "frozen"

    def validate(self) -> None:
        _validate_streaming_knobs(self)


@dataclasses.dataclass(frozen=True)
class HashConfig(PartitionerConfig):
    """Hash-family baselines (random edge hash, DBH, CVC)."""

    seed: int = 0

    def validate(self) -> None:
        _validate_seed(self.seed)


@dataclasses.dataclass(frozen=True)
class NEConfig(PartitionerConfig):
    """Neighbor Expansion [Zhang et al., KDD'17]."""

    seed: int = 0

    def validate(self) -> None:
        _validate_seed(self.seed)


@dataclasses.dataclass(frozen=True)
class MetisLikeConfig(PartitionerConfig):
    """Multilevel METIS-style stand-in."""

    seed: int = 0
    coarsen_to: int = 4096
    refine_passes: int = 6

    def validate(self) -> None:
        _validate_seed(self.seed)
        _require(
            isinstance(self.coarsen_to, int) and self.coarsen_to >= 2,
            f"coarsen_to must be an int >= 2, got {self.coarsen_to!r}",
        )
        _require(
            isinstance(self.refine_passes, int) and self.refine_passes >= 0,
            f"refine_passes must be a non-negative int, got {self.refine_passes!r}",
        )
