"""repro.api — the stable seam every scaling PR builds on.

Two pieces (see docs/api.md):

  * a decorator-based partitioner registry with per-algorithm frozen
    configs and capability flags (`register_partitioner`,
    `get_partitioner`, `list_partitioners`), and
  * the `GraphPipeline` facade owning the partition → SubgraphSet →
    engine → stats/metrics lifecycle with lazy, cached stages.

`GraphPipeline` (and friends) are imported lazily: `repro.core` modules
import the registry at definition time to self-register, and the
pipeline imports `repro.core` — the lazy hop breaks that cycle.
"""
from repro.api.config import (
    COMPUTE_BACKENDS,
    EBGConfig,
    EBVConfig,
    GreedyConfig,
    HashConfig,
    HDRFConfig,
    MetisLikeConfig,
    NEConfig,
    PartitionerConfig,
    check_compute_backend,
)
from repro.api.registry import (
    Partitioner,
    PartitionerSpec,
    RegistryFunctionView,
    benchmark_partitioners,
    get_partitioner,
    list_partitioners,
    partitioner_names,
    register_partitioner,
)

_LAZY = ("GraphPipeline", "PipelineRun", "BatchRun", "SubgraphSpec", "LoweredBSP")

__all__ = [
    "COMPUTE_BACKENDS",
    "check_compute_backend",
    "EBGConfig",
    "EBVConfig",
    "GreedyConfig",
    "HDRFConfig",
    "HashConfig",
    "MetisLikeConfig",
    "NEConfig",
    "PartitionerConfig",
    "Partitioner",
    "PartitionerSpec",
    "RegistryFunctionView",
    "benchmark_partitioners",
    "get_partitioner",
    "list_partitioners",
    "partitioner_names",
    "register_partitioner",
    *_LAZY,
]


def __getattr__(name):
    if name in _LAZY:
        from repro.api import pipeline as _pipeline

        val = getattr(_pipeline, name)
        globals()[name] = val  # cache for subsequent lookups
        return val
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
