"""`GraphPipeline` — the end-to-end facade over the paper's stack.

    run = GraphPipeline(graph).partition("ebg", parts=8).build(symmetrize=True).run("cc")
    run.stats.total_messages, run.metrics.replication_factor, run.to_global()

Stages are lazy and cached on a shared partition-stage state, so fluent
views are cheap: `.partition(...)` starts a fresh stage; `.build(...)`
and repeated `.run(...)` calls on the same stage reuse the cached
`PartitionResult`, `PartitionMetrics`, and per-(symmetrize, pad) built
`SubgraphSet`s. If `.build` is never called, `.run` picks the build the
program needs (bidirectional programs symmetrize; the rest keep edge
direction).

`.run` executes ANY registered `VertexProgram` (or a custom instance with
an `init_fn`) in BOTH modes — `mode="sim"` batches all workers on one
device, `mode="dist"` shard_maps one subgraph per mesh device through the
same generic distributed stepper.

Distributed execution shares the same facade: `GraphPipeline.from_spec`
makes an abstract (shape-only) pipeline, and `.lower(mesh=...)` AOT-lowers
the shard_map'd BSP stepper for either an abstract spec or a concretely
built subgraph set — this is what the production dry-run drives.
"""
from __future__ import annotations

import dataclasses
import time
import weakref
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.api.config import PartitionerConfig, check_compute_backend
from repro.api.registry import PartitionerSpec, check_num_parts, get_partitioner
from repro.core.metrics import PartitionMetrics, partition_metrics
from repro.core.types import Graph, PartitionResult
from repro.graph import algorithms as alg
from repro.graph.build import SubgraphSet, build_subgraphs
from repro.graph.engine import (
    BSPStats,
    VertexProgram,
    _kernel_value_boundary,
    check_driver,
    check_int32_kernel_labels,
    get_program,
    make_distributed_stepper,
    run_bsp_batch,
    subgraphs_to_arrays,
)

ProgramLike = Union[str, VertexProgram]


def _resolve_program(program: ProgramLike) -> VertexProgram:
    """Normalize a program handle to a runnable `VertexProgram`.

    Strings go through the engine registry; instances are accepted as long
    as they carry an `init_fn` (the facade needs initial values to run —
    register custom programs with `repro.graph.engine.register_program` or
    pass the instance directly)."""
    prog = get_program(program)
    if prog.init_fn is None:
        raise ValueError(
            f"program {prog.name!r} has no init_fn: GraphPipeline cannot build its "
            "initial values — set VertexProgram.init_fn, or drive it through "
            "repro.graph.engine.run_bsp with an explicit init_val"
        )
    return prog


def _translate_engine_kwargs(prog: VertexProgram, kw: dict) -> tuple[VertexProgram, dict]:
    """Facade-level conveniences: `num_iters` is the PageRank-speak alias of
    `max_supersteps`, and `damping` specializes the program instance."""
    if "num_iters" in kw:
        kw = dict(kw)
        kw["max_supersteps"] = kw.pop("num_iters")
    if "damping" in kw:
        kw = dict(kw)
        prog = dataclasses.replace(prog, damping=float(kw.pop("damping")))
    return prog, kw


def _normalize_axes(mesh, axes) -> tuple:
    if axes is None:
        return tuple(mesh.axis_names)
    return (axes,) if isinstance(axes, str) else tuple(axes)


# Default source (SSSP/BFS) depends only on the graph, not the partition —
# cache per graph object so a suite running 5 partitioners over one graph
# scans the edge list once. Keyed by id() with a liveness check (Graph holds
# jax arrays, so it is not hashable).
_SOURCE_CACHE: dict[int, tuple] = {}


def _default_source_for(graph: Graph) -> int:
    key = id(graph)
    ent = _SOURCE_CACHE.get(key)
    if ent is not None and ent[0]() is graph:
        return ent[1]
    cov = graph.covered_vertices()
    src_v = int(cov[np.argmax(graph.degrees()[cov])])
    _SOURCE_CACHE[key] = (weakref.ref(graph, lambda _: _SOURCE_CACHE.pop(key, None)), src_v)
    return src_v


# --------------------------------------------------------------- dry-run spec


@dataclasses.dataclass(frozen=True)
class SubgraphSpec:
    """Shape-only description of a padded SubgraphSet (for AOT lowering)."""

    num_parts: int
    max_v: int
    max_e: int
    max_msg: int = 2048
    addressing: str = "two_level"

    @classmethod
    def of(cls, sub: SubgraphSet) -> "SubgraphSpec":
        return cls(sub.num_parts, sub.max_v, sub.max_e, sub.max_msg, sub.addressing)

    def array_specs(self) -> tuple[dict, dict]:
        """ShapeDtypeStructs + statics matching `subgraphs_to_arrays`."""
        f32, i32, b = jnp.float32, jnp.int32, jnp.bool_
        p = self.num_parts
        e2 = lambda dt: jax.ShapeDtypeStruct((p, self.max_e), dt)
        v2 = lambda dt: jax.ShapeDtypeStruct((p, self.max_v), dt)
        m3 = lambda dt: jax.ShapeDtypeStruct((p, p, self.max_msg), dt)
        arrays = dict(
            lsrc=e2(i32), ldst=e2(i32), weight=e2(f32), edge_mask=e2(b),
            lsrc_s=e2(i32), ldst_s=e2(i32), weight_s=e2(f32), edge_mask_s=e2(b),
            gid=v2(i32), vmask=v2(b), is_master=v2(b), out_degree=v2(f32),
            send_idx=m3(i32), recv_idx=m3(i32), msg_mask=m3(b), recv_mask=m3(b),
        )
        statics = dict(num_parts=p, max_v=self.max_v, max_e=self.max_e, max_msg=self.max_msg,
                       addressing=self.addressing)
        return arrays, statics

    def value_spec(self, prog: VertexProgram) -> jax.ShapeDtypeStruct:
        dt = jnp.int32 if prog.dtype == "int32" else jnp.float32
        return jax.ShapeDtypeStruct((self.num_parts, self.max_v + 1), dt)


@dataclasses.dataclass
class LoweredBSP:
    """AOT-lowered shard_map'd BSP stepper + its shardings."""

    spec: SubgraphSpec
    program: str
    mesh: object
    axes: tuple
    lowered: object
    compiled: object
    compile_s: float
    in_shardings: tuple


# ------------------------------------------------------------------ pipeline


class GraphPipeline:
    """Fluent partition → build → engine → metrics session (see module doc)."""

    def __init__(self, graph: Optional[Graph], *, weights: Optional[np.ndarray] = None):
        self.graph = graph
        self._weights = weights
        self._spec: Optional[SubgraphSpec] = None
        self._state: Optional[dict] = None  # partition-stage caches, shared by views
        self._build_params: Optional[dict] = None

    @classmethod
    def from_spec(cls, spec: SubgraphSpec) -> "GraphPipeline":
        """Abstract pipeline (shapes only) — supports `.lower` but not `.run`."""
        pipe = cls(None)
        pipe._spec = spec
        return pipe

    def _clone(self, *, state=None, build_params=None) -> "GraphPipeline":
        pipe = GraphPipeline(self.graph, weights=self._weights)
        pipe._spec = self._spec
        pipe._state = self._state if state is None else state
        pipe._build_params = self._build_params if build_params is None else build_params
        return pipe

    # ----------------------------------------------------------- partition

    def partition(
        self,
        partitioner: Union[str, PartitionerSpec] = "ebg",
        parts: int = 8,
        *,
        config: Optional[PartitionerConfig] = None,
        **overrides,
    ) -> "GraphPipeline":
        """Select a registered partitioner; returns a new pipeline view whose
        downstream stages are computed lazily and cached."""
        if self.graph is None:
            raise RuntimeError("abstract (from_spec) pipelines cannot partition a graph")
        spec = partitioner if isinstance(partitioner, PartitionerSpec) else get_partitioner(partitioner)
        check_num_parts(parts)  # fail fast here; spec.partition re-checks on the lazy path
        cfg = spec.make_config(config, **overrides)
        spec.check_overrides(overrides)
        state = dict(spec=spec, config=cfg, parts=parts, result=None, metrics=None, builds={})
        return self._clone(state=state, build_params={})

    def _stage(self) -> dict:
        if self._state is None:
            raise RuntimeError("no partition stage: call .partition(name, parts=...) first")
        return self._state

    @property
    def partitioner(self) -> PartitionerSpec:
        return self._stage()["spec"]

    @property
    def config(self) -> PartitionerConfig:
        return self._stage()["config"]

    @property
    def num_parts(self) -> int:
        return self._stage()["parts"]

    @property
    def result(self) -> PartitionResult:
        st = self._stage()
        if st["result"] is None:
            st["result"] = st["spec"].partition(self.graph, st["parts"], config=st["config"])
        return st["result"]

    @property
    def metrics(self) -> PartitionMetrics:
        st = self._stage()
        if st["metrics"] is None:
            st["metrics"] = partition_metrics(self.graph, self.result)
        return st["metrics"]

    # --------------------------------------------------------------- build

    def build(self, *, symmetrize: bool = False, pad_multiple: int = 8) -> "GraphPipeline":
        """Pin build parameters for subsequent `.run`/`.subgraphs` access."""
        self._stage()
        return self._clone(build_params=dict(symmetrize=symmetrize, pad_multiple=pad_multiple))

    def subgraphs_for(self, *, symmetrize: bool, pad_multiple: int = 8) -> SubgraphSet:
        st = self._stage()
        key = (bool(symmetrize), int(pad_multiple))
        if key not in st["builds"]:
            st["builds"][key] = build_subgraphs(
                self.graph,
                self.result,
                weights=self._weights,
                symmetrize=symmetrize,
                pad_multiple=pad_multiple,
            )
        return st["builds"][key]

    @property
    def subgraphs(self) -> SubgraphSet:
        bp = self._build_params or {}
        return self.subgraphs_for(
            symmetrize=bp.get("symmetrize", False), pad_multiple=bp.get("pad_multiple", 8)
        )

    # ----------------------------------------------------------------- run

    def default_source(self) -> int:
        """SSSP/BFS source: highest-degree covered vertex (benchmark convention)."""
        return _default_source_for(self.graph)

    def _build_params_for(self, prog: VertexProgram, symmetrize, pad_multiple) -> dict:
        # Explicit per-call arguments (not None) win over params pinned by
        # `.build`, which win over program defaults.
        bp = dict(self._build_params or {})
        if symmetrize is not None:
            bp["symmetrize"] = symmetrize
        if pad_multiple is not None:
            bp["pad_multiple"] = pad_multiple
        # Bidirectional programs (CC/REACH) treat the graph as undirected.
        bp.setdefault("symmetrize", bool(prog.bidirectional))
        bp.setdefault("pad_multiple", 8)
        return bp

    def _source_for(self, prog: VertexProgram, source) -> Optional[int]:
        if source is not None:
            return int(source)
        return self.default_source() if prog.needs_source else None

    def clear_builds(self) -> None:
        """Drop cached SubgraphSets (the partition result and metrics stay).
        Long-lived pipelines over several graphs can reclaim the padded
        build tensors once a benchmark section is done with them."""
        if self._state is not None:
            self._state["builds"].clear()

    def prepare(self, program: ProgramLike = "cc", *, symmetrize=None, pad_multiple: Optional[int] = None) -> "GraphPipeline":
        """Force partition + build (+ default source) caches, so a subsequent
        `.run` timing measures only the engine."""
        prog = _resolve_program(program)
        bp = self._build_params_for(prog, symmetrize, pad_multiple)
        self.subgraphs_for(**bp)
        if prog.needs_source:
            self.default_source()
        return self

    def run(
        self,
        program: ProgramLike = "cc",
        *,
        mode: str = "sim",
        symmetrize: Optional[bool] = None,
        pad_multiple: Optional[int] = None,
        source: Optional[int] = None,
        compute_backend: Optional[str] = None,
        driver: Optional[str] = None,
        **kw,
    ) -> "PipelineRun":
        """Execute any registered program over the partitioned graph and
        collect stats.

        mode="sim" batches all workers on one device (tests/benchmarks);
        mode="dist" shard_maps one subgraph per device (pass mesh=...) —
        BOTH modes run every program through the same generic engine.
        compute_backend routes the engine hot paths ("xla" | "ref" |
        "pallas"; default "xla"); driver selects the sim step loop
        ("fused" single-dispatch while_loop, the default, or "host" —
        one dispatch per superstep, kept for A/B). Extra kwargs flow to
        the engine (max_supersteps, inner_cap, exchange_period, tol,
        num_iters — the PageRank alias of max_supersteps — damping,
        block_e — the megakernel edge-block size for kernel backends,
        see docs/api.md "Performance guide" — ...),
        including the fault-tolerance knobs (checkpoint_every + ckpt_dir
        for superstep snapshots resumable via repro.resilience.resume_bsp,
        and fault_plan for deterministic fault injection — docs/api.md
        "Fault tolerance").
        """
        prog = _resolve_program(program)
        prog, kw = _translate_engine_kwargs(prog, kw)
        if compute_backend is not None:
            kw["compute_backend"] = check_compute_backend(compute_backend)
        if driver is not None:
            check_driver(driver)
            if mode != "sim":
                raise ValueError(
                    "driver= applies to mode='sim' only; mode='dist' always runs "
                    "the fused while_loop stepper"
                )
            kw["driver"] = driver
        sub = self.subgraphs_for(**self._build_params_for(prog, symmetrize, pad_multiple))
        src = self._source_for(prog, source)
        if mode == "sim":
            values, stats = alg.run_program(
                sub, prog, num_vertices=self.graph.num_vertices, source=src, **kw
            )
        elif mode == "dist":
            values, stats = self._run_distributed(prog, sub, source=src, **kw)
        else:
            raise ValueError(f"unknown mode {mode!r}; expected 'sim' or 'dist'")
        return PipelineRun(pipeline=self, program=prog.name, values=values, stats=stats, subgraphs=sub)

    def run_batch(
        self,
        program: ProgramLike = "cc",
        sources=None,
        *,
        batch: Optional[int] = None,
        symmetrize: Optional[bool] = None,
        pad_multiple: Optional[int] = None,
        compute_backend: Optional[str] = None,
        **kw,
    ) -> "BatchRun":
        """Run a [B] batch of point queries of ONE program in a single
        fused dispatch over the shared subgraph structure.

        Source-rooted programs (SSSP/BFS) take `sources` — a [B] sequence
        of vertex ids, each validated before anything runs; source-free
        programs take `batch` (B identical whole-graph queries). Each
        query's values and `BSPStats` are bit-identical to a one-source
        `.run` call: convergence masking freezes finished queries while
        stragglers run, and per-query stats report the supersteps that
        query actually paid. For a persistent admission-queue/cache
        serving loop over the same machinery, use `.serve()`.
        """
        prog = _resolve_program(program)
        prog, kw = _translate_engine_kwargs(prog, kw)
        if compute_backend is not None:
            kw["compute_backend"] = check_compute_backend(compute_backend)
        sub = self.subgraphs_for(**self._build_params_for(prog, symmetrize, pad_multiple))
        vals, stats = run_bsp_batch(
            sub, prog, sources, batch=batch, num_vertices=self.graph.num_vertices, **kw
        )
        return BatchRun(
            pipeline=self,
            program=prog.name,
            values=np.asarray(vals[:, :, :-1]),
            stats=stats,
            subgraphs=sub,
            sources=tuple(int(s) for s in sources) if sources is not None else None,
        )

    def serve(self, **server_kwargs) -> "GraphQueryServer":
        """Open a persistent query-serving session over this pipeline's
        partitioned graph (admission queue, micro-batching, warm compiled
        executables — see `repro.serve.GraphQueryServer` for the knobs)."""
        from repro.serve import GraphQueryServer

        return GraphQueryServer(self, **server_kwargs)

    def _run_distributed(
        self,
        prog: VertexProgram,
        sub: SubgraphSet,
        *,
        mesh,
        axes=None,
        num_supersteps: Optional[int] = None,
        max_supersteps: Optional[int] = None,
        inner_cap: int = 10_000,
        tol: float = 0.0,
        source: Optional[int] = None,
        compute_backend: str = "xla",
        block_e: int = 512,
    ) -> tuple[np.ndarray, BSPStats]:
        check_int32_kernel_labels(prog, sub, compute_backend)
        if max_supersteps is not None:  # sim-speak (and the num_iters alias)
            num_supersteps = max_supersteps
        if num_supersteps is None:
            num_supersteps = prog.default_steps or 30
        axes = _normalize_axes(mesh, axes)
        ndev = int(np.prod([mesh.shape[a] for a in axes]))
        if ndev != sub.num_parts:
            raise ValueError(f"mesh axes {axes} span {ndev} devices but partition has {sub.num_parts} parts")
        arrays, statics = subgraphs_to_arrays(sub)
        stepper = make_distributed_stepper(
            mesh, axes, prog, statics, num_supersteps=num_supersteps, inner_cap=inner_cap,
            tol=tol, num_vertices=self.graph.num_vertices, compute_backend=compute_backend,
            block_e=block_e,
        )
        init = prog.init(sub, num_vertices=self.graph.num_vertices, source=source)
        # Two-level value boundary (host-side, before tracing): label-domain
        # programs run on dense ranks so kernels never see raw global ids.
        # Rank compression is order-preserving, so it commutes with the
        # runner's internal max→min negation; output decodes below.
        init, codec = _kernel_value_boundary(prog, sub, init, compute_backend)
        with mesh:
            val, msgs, steps, msgs_steps, iters_steps = jax.jit(stepper)(arrays, init)
        if codec is not None:
            val = codec.decode(val)
        steps = int(steps)
        msgs_sw = np.asarray(msgs_steps, np.int64)[:steps]
        iters_sw = np.asarray(iters_steps, np.int64)[:steps]
        # Per-worker compute work from the returned inner-iteration buffer ×
        # per-worker edge counts — the same formula the sim drivers use, so
        # sim and dist stats agree exactly.
        edges = np.asarray(sub.edge_mask.sum(axis=1), np.int64)
        stats = BSPStats(
            supersteps=steps,
            messages_per_worker=np.asarray(msgs, np.int64),
            messages_per_step=msgs_sw.sum(axis=1),
            comp_work_per_worker=(iters_sw * edges[None, :]).sum(axis=0),
            inner_iters_per_step=iters_sw,
            messages_per_step_worker=msgs_sw,
        )
        return np.asarray(val[:, :-1]), stats

    # --------------------------------------------------------------- lower

    def lower(
        self,
        *,
        mesh,
        axes=None,
        program: ProgramLike = "cc",
        num_supersteps: int = 4,
        inner_cap: int = 64,
        tol: float = 0.0,
        symmetrize: Optional[bool] = None,
        pad_multiple: Optional[int] = None,
        num_vertices: Optional[int] = None,
        compute_backend: str = "xla",
        block_e: int = 512,
    ) -> LoweredBSP:
        """AOT-lower the distributed BSP stepper (abstract or concrete) for
        ANY registered program.

        Kernel backends ("ref"/"pallas") run int32 programs (CC/BFS/REACH)
        through f32 — exact only for vertex ids below 2^24. Concrete
        pipelines are checked here; an abstract (from_spec) pipeline has no
        labels to check, so the CALLER must enforce the <2^24 precondition
        on the arrays eventually fed to the compiled stepper. Programs whose
        apply step renormalizes (PageRank) need `num_vertices=` when
        lowering from an abstract spec.
        """
        prog = get_program(program)
        check_compute_backend(compute_backend)
        axes = _normalize_axes(mesh, axes)
        nv = self.graph.num_vertices if self.graph is not None else int(num_vertices or 0)
        if prog.apply == "pagerank" and nv <= 0:
            raise ValueError(
                "lowering a pagerank-apply program from an abstract spec needs num_vertices="
            )
        if self._spec is not None:
            spec = self._spec
        else:
            sub = self.subgraphs_for(**self._build_params_for(prog, symmetrize, pad_multiple))
            check_int32_kernel_labels(prog, sub, compute_backend)
            spec = SubgraphSpec.of(sub)
        arrays, statics = spec.array_specs()
        stepper = make_distributed_stepper(
            mesh, axes, prog, statics, num_supersteps=num_supersteps, inner_cap=inner_cap,
            tol=tol, num_vertices=nv, compute_backend=compute_backend, block_e=block_e,
        )
        spec2 = P(axes, None)
        spec3 = P(axes, None, None)
        in_sh = (
            {k: NamedSharding(mesh, spec3 if v.ndim == 3 else spec2) for k, v in arrays.items()},
            NamedSharding(mesh, spec2),
        )
        with mesh:
            t0 = time.time()
            lowered = jax.jit(stepper, in_shardings=in_sh).lower(arrays, spec.value_spec(prog))
            compiled = lowered.compile()
            compile_s = time.time() - t0
        return LoweredBSP(
            spec=spec,
            program=prog.name,
            mesh=mesh,
            axes=axes,
            lowered=lowered,
            compiled=compiled,
            compile_s=compile_s,
            in_shardings=in_sh,
        )


@dataclasses.dataclass
class PipelineRun:
    """Result of one `GraphPipeline.run`: values + BSP stats + context."""

    pipeline: GraphPipeline
    program: str
    values: np.ndarray  # [p, max_v] per-(part, local-vertex) values
    stats: BSPStats
    subgraphs: SubgraphSet

    @property
    def metrics(self) -> PartitionMetrics:
        return self.pipeline.metrics

    @property
    def edges_per_worker(self) -> np.ndarray:
        return np.asarray(self.subgraphs.edge_mask.sum(axis=1))

    def to_global(self, reduce: str = "min") -> np.ndarray:
        """Per-vertex values collected from master replicas."""
        return alg.scatter_to_global(
            self.subgraphs, self.values, self.pipeline.graph.num_vertices, reduce=reduce
        )

    def num_components(self) -> int:
        """Distinct CC labels over covered vertices."""
        cov = self.pipeline.graph.covered_vertices()
        return int(np.unique(self.to_global()[cov]).shape[0])


@dataclasses.dataclass
class BatchRun:
    """Result of one `GraphPipeline.run_batch`: [B] queries of one program
    answered in one fused dispatch. `query(i)` views query i as a normal
    `PipelineRun` (same `.to_global()`, `.stats`, ... surface)."""

    pipeline: GraphPipeline
    program: str
    values: np.ndarray  # [B, p, max_v]
    stats: list  # [B] per-query BSPStats (each query's OWN supersteps)
    subgraphs: SubgraphSet
    sources: Optional[tuple]

    def __len__(self) -> int:
        return self.values.shape[0]

    def query(self, i: int) -> PipelineRun:
        return PipelineRun(
            pipeline=self.pipeline, program=self.program,
            values=self.values[i], stats=self.stats[i], subgraphs=self.subgraphs,
        )

    @property
    def supersteps_per_query(self) -> np.ndarray:
        """Supersteps each query actually paid under convergence masking
        (NOT B copies of the batch max)."""
        return np.asarray([s.supersteps for s in self.stats])
