"""Version-compatibility shims for jax APIs that moved between releases.

The repo targets current jax but must degrade on older runtimes:
  * `jax.shard_map` (with `check_vma`) was `jax.experimental.shard_map.
    shard_map` (with `check_rep`) on 0.4.x;
  * `jax.make_mesh`'s `axis_types` / `jax.sharding.AxisType` only exist
    on newer releases.
"""
from __future__ import annotations

import jax


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """Unchecked shard_map across jax versions."""
    if hasattr(jax, "shard_map"):  # jax >= 0.6
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map  # jax 0.4.x

    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)


def cost_analysis_compat(compiled) -> dict:
    """`Compiled.cost_analysis()`: dict on new jax, [dict] on 0.4.x."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def make_mesh_compat(shape: tuple, axes: tuple):
    """jax.make_mesh with Auto axis types where the API supports them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)
