"""Kimi K2 1T (32B active) [arXiv:2501.kimi2; unverified, paper-table] —
384 experts top-8. Divergence note: the real model's dense first layer and
shared expert are folded into the uniform MoE stack."""
from repro.models.config import LayerSpec, ModelConfig, MoEConfig

config = ModelConfig(
    name="kimi_k2",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab=163840,
    head_dim=112,
    group=(LayerSpec(kind="attn", mlp="moe"),),
    moe=MoEConfig(num_experts=384, top_k=8, d_ff=2048),
)
