"""Llama-3.2-3B [hf:meta-llama/Llama-3.2-3B; unverified]."""
from repro.models.config import LayerSpec, ModelConfig

config = ModelConfig(
    name="llama3_2_3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab=128256,
    head_dim=128,
    group=(LayerSpec(kind="attn", mlp="dense"),),
    rope_theta=500000.0,
    tie_embeddings=True,
)
