"""Qwen3-4B [hf:Qwen/Qwen3-4B; hf] — QK-norm, GQA."""
from repro.models.config import LayerSpec, ModelConfig

config = ModelConfig(
    name="qwen3_4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=9728,
    vocab=151936,
    head_dim=128,
    group=(LayerSpec(kind="attn", mlp="dense"),),
    qk_norm=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
)
