"""Config registry: one module per assigned architecture (+ the paper's
graph-engine config). `get_config(name)` returns the full published config;
`reduced_config(name)` returns a tiny same-family config for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib

ARCHS = [
    "seamless_m4t_large_v2",
    "llama3_2_3b",
    "qwen2_72b",
    "gemma2_27b",
    "qwen3_4b",
    "phi3_5_moe",
    "kimi_k2",
    "jamba_1_5_large",
    "mamba2_780m",
    "qwen2_vl_2b",
]

# Shape cells (system prompt): name -> (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def get_config(name: str):
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.config


def reduced_config(name: str):
    """Tiny same-family config: same group pattern, small dims."""
    from repro.models.config import MoEConfig, SSMConfig

    cfg = get_config(name)
    kw: dict = dict(
        n_layers=len(cfg.group),
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=512,
        head_dim=16,
    )
    if cfg.moe is not None:
        # capacity_factor = E ⇒ cap = T·k: no token drops, so decode ≡ full
        # forward exactly (capacity dropping is shape-dependent otherwise).
        kw["moe"] = MoEConfig(num_experts=4, top_k=2, d_ff=64, capacity_factor=4.0)
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=16)
    if cfg.n_enc_layers:
        kw["n_enc_layers"] = 1
    if cfg.mrope_sections is not None:
        kw["mrope_sections"] = (2, 3, 3)
    return dataclasses.replace(cfg, **kw)


def runnable_shapes(name: str) -> list[str]:
    """Which shape cells run for this arch (DESIGN.md §4 skip rules)."""
    cfg = get_config(name)
    out = []
    for shape, (_, _, kind) in SHAPES.items():
        if kind == "decode" and not cfg.decoder:
            continue
        if shape == "long_500k" and not cfg.sub_quadratic:
            continue
        out.append(shape)
    return out
