"""Jamba-1.5-Large 398B [arXiv:2403.19887; hf] — 1:7 attention:mamba
interleave in period-8 blocks, MoE (16e top-2) every other layer.
Divergence note: mamba layers use our mamba2/SSD mixer (d_state=128)
instead of the original mamba1 (d_state=16)."""
from repro.models.config import LayerSpec, ModelConfig, MoEConfig, SSMConfig

_block = []
for i in range(8):
    kind = "attn" if i == 4 else "ssm"
    mlp = "moe" if i % 2 == 1 else "dense"
    _block.append(LayerSpec(kind=kind, mlp=mlp))

config = ModelConfig(
    name="jamba_1_5_large",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    head_dim=128,
    group=tuple(_block),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=24576),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=128, chunk=256),
    sub_quadratic=True,
)
