"""Mamba2-780m [arXiv:2405.21060; unverified] — attention-free SSD."""
from repro.models.config import LayerSpec, ModelConfig, SSMConfig

config = ModelConfig(
    name="mamba2_780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1,      # unused (attn-free)
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    head_dim=64,
    group=(LayerSpec(kind="ssm", mlp="none"),),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    tie_embeddings=True,
    sub_quadratic=True,
)
