"""Phi-3.5-MoE 42B (6.6B active) [hf:microsoft/Phi-3.5-MoE-instruct; hf] —
16 experts, top-2, every layer MoE."""
from repro.models.config import LayerSpec, ModelConfig, MoEConfig

config = ModelConfig(
    name="phi3_5_moe",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab=32064,
    head_dim=128,
    group=(LayerSpec(kind="attn", mlp="moe"),),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=6400),
)
