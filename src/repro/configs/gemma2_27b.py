"""Gemma-2 27B [arXiv:2408.00118; hf] — alternating local(4096)/global
attention, attention softcap 50, final-logit softcap 30, GeGLU."""
from repro.models.config import LayerSpec, ModelConfig

config = ModelConfig(
    name="gemma2_27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab=256000,
    head_dim=128,
    group=(
        LayerSpec(kind="attn", mlp="dense", sliding_window=4096),
        LayerSpec(kind="attn", mlp="dense"),
    ),
    attn_softcap=50.0,
    logit_softcap=30.0,
    act="gelu",
    tie_embeddings=True,
)
