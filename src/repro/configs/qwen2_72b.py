"""Qwen2-72B [arXiv:2407.10671; hf] — GQA with QKV bias."""
from repro.models.config import LayerSpec, ModelConfig

config = ModelConfig(
    name="qwen2_72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    head_dim=128,
    group=(LayerSpec(kind="attn", mlp="dense"),),
    qkv_bias=True,
    rope_theta=1000000.0,
)
