"""SeamlessM4T-large v2 backbone [arXiv:2308.11596; hf] — enc-dec, audio
frontend STUBBED (input_specs provides precomputed frame embeddings).
Divergence note (DESIGN.md): RoPE replaces the original relative-position
encoding; conformer encoder blocks simplified to transformer blocks."""
from repro.models.config import LayerSpec, ModelConfig

config = ModelConfig(
    name="seamless_m4t_large_v2",
    family="audio",
    n_layers=24,
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    group=(LayerSpec(kind="attn", mlp="dense", cross_attn=True),),
    frontend="audio",
    rope_theta=10000.0,
    sub_quadratic=False,
    decoder=True,
)
