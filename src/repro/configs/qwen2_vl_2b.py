"""Qwen2-VL-2B [arXiv:2409.12191; hf] — M-RoPE, dynamic-resolution vision
frontend STUBBED (input_specs provides precomputed patch embeddings)."""
from repro.models.config import LayerSpec, ModelConfig

config = ModelConfig(
    name="qwen2_vl_2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    head_dim=128,
    group=(LayerSpec(kind="attn", mlp="dense"),),
    qkv_bias=True,
    mrope_sections=(16, 24, 24),
    frontend="vision",
    rope_theta=1000000.0,
    tie_embeddings=True,
)
