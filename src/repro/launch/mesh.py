"""Production mesh definitions.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh_compat  # noqa: F401  (re-exported)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 v5e pod (data, model); 2 pods add a leading 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def dp_axes(mesh) -> tuple[str, ...]:
    """Data-parallel axes: ('pod','data') multi-pod, ('data',) single-pod."""
    names = mesh.axis_names
    return tuple(a for a in names if a != "model")


def make_host_mesh(n: int | None = None, name: str = "workers"):
    """Flat mesh over available devices (tests, examples, graph engine)."""
    n = n or len(jax.devices())
    return make_mesh_compat((n,), (name,))
