"""Dry-run of the paper's subgraph-centric BSP engine at production scale.

Lowers the shard_map'd CC stepper for p=512 subgraphs (one per chip across
2 pods, subgraphs sharded over the flattened (pod, data, model) axes) with
Friendster-scale padded sizes: |E|≈3.6B directed edges → ~8M edges per
subgraph, ~1M local vertices, 2048-slot pairwise message buffers. The EBG
balance guarantees (Theorems 1/2) are what make these fixed paddings safe.

The lowering itself goes through the `repro.api` facade: an abstract
`GraphPipeline.from_spec(SubgraphSpec(...)).lower(mesh=...)` — the same
entry a concretely partitioned pipeline uses for distributed execution.
"""
from __future__ import annotations

from repro.api import GraphPipeline, SubgraphSpec
from repro.compat import cost_analysis_compat
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import parse_collectives, roofline_terms

# Friendster |V| — abstract (shape-only) lowering has no graph to read it
# from, and renormalizing programs (PageRank) need it at trace time.
FRIENDSTER_NUM_VERTICES = 65_608_366


def friendster_spec(p: int, max_v: int = 1 << 20, max_e: int = 8 << 20, max_msg: int = 2048) -> SubgraphSpec:
    return SubgraphSpec(num_parts=p, max_v=max_v, max_e=max_e, max_msg=max_msg)


def run_graph_dryrun(
    *,
    multi_pod: bool = False,
    num_supersteps: int = 4,
    inner_cap: int = 64,
    compute_backend: str = "xla",
    program: str = "cc",
    partitioner: str = "ebg_chunked",
):
    """Lower the distributed stepper for any registered `VertexProgram`
    (`program="cc" | "sssp" | "pr" | "bfs" | "reach"`) at production scale.

    `partitioner` names the registered streaming partitioner whose balance
    behaviour the fixed paddings assume (any EdgeScorer instance: EBV
    guarantees them via Theorems 1/2; `hdrf`/`greedy` bound edge balance
    through their range term). The lowering itself is shape-only — the
    name is validated against the registry and recorded in the result.
    """
    from repro.api import get_partitioner

    spec_p = get_partitioner(partitioner)
    if spec_p.scorer is None:
        raise ValueError(
            f"partitioner {partitioner!r} is not a streaming EdgeScorer instance; "
            "the dry-run paddings assume a balance-bounded streaming partitioner"
        )
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = tuple(mesh.axis_names)  # subgraphs over ALL axes: p == #chips
    p = len(mesh.devices.reshape(-1))
    low = GraphPipeline.from_spec(friendster_spec(p)).lower(
        mesh=mesh, axes=axes, program=program, num_supersteps=num_supersteps,
        inner_cap=inner_cap, num_vertices=FRIENDSTER_NUM_VERTICES,
        compute_backend=compute_backend,
    )
    mem = low.compiled.memory_analysis()
    cost = cost_analysis_compat(low.compiled)
    coll = parse_collectives(low.compiled.as_text())
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    terms = roofline_terms(flops, hbm, coll.total_link_bytes)
    return dict(
        arch=f"graph_bsp_{low.program}",
        compute_backend=compute_backend,
        partitioner=spec_p.name,
        scorer=spec_p.scorer,
        shape=f"p{p}_friendster_scale",
        mesh="2x16x16" if multi_pod else "16x16",
        chips=p,
        compile_s=round(low.compile_s, 2),
        flops_per_device=flops,
        hbm_bytes_per_device=hbm,
        link_bytes_per_device=coll.total_link_bytes,
        collectives=coll.per_op,
        arg_bytes=mem.argument_size_in_bytes,
        temp_bytes=mem.temp_size_in_bytes,
        per_device_hbm_total=mem.argument_size_in_bytes + mem.temp_size_in_bytes,
        **terms,
    )
