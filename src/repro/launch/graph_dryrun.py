"""Dry-run of the paper's subgraph-centric BSP engine at production scale.

Lowers the shard_map'd CC stepper for p=512 subgraphs (one per chip across
2 pods, subgraphs sharded over the flattened (pod, data, model) axes) with
Friendster-scale padded sizes: |E|≈3.6B directed edges → ~8M edges per
subgraph, ~1M local vertices, 2048-slot pairwise message buffers. The EBG
balance guarantees (Theorems 1/2) are what make these fixed paddings safe.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.graph.engine import CC, make_distributed_stepper
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import parse_collectives, roofline_terms


def graph_input_specs(p: int, max_v: int = 1 << 20, max_e: int = 8 << 20, max_msg: int = 2048):
    f32, i32, b = jnp.float32, jnp.int32, jnp.bool_
    e2 = lambda dt: jax.ShapeDtypeStruct((p, max_e), dt)
    v2 = lambda dt: jax.ShapeDtypeStruct((p, max_v), dt)
    m3 = lambda dt: jax.ShapeDtypeStruct((p, p, max_msg), dt)
    arrays = dict(
        lsrc=e2(i32), ldst=e2(i32), weight=e2(f32), edge_mask=e2(b),
        lsrc_s=e2(i32), ldst_s=e2(i32), weight_s=e2(f32), edge_mask_s=e2(b),
        gid=v2(i32), vmask=v2(b), is_master=v2(b), out_degree=v2(f32),
        send_idx=m3(i32), recv_idx=m3(i32), msg_mask=m3(b), recv_mask=m3(b),
    )
    statics = dict(num_parts=p, max_v=max_v, max_e=max_e, max_msg=max_msg)
    val = jax.ShapeDtypeStruct((p, max_v + 1), jnp.int32)
    return arrays, statics, val


def run_graph_dryrun(*, multi_pod: bool = False, num_supersteps: int = 4, inner_cap: int = 64):
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = mesh.axis_names  # subgraphs over ALL axes: p == #chips
    p = len(mesh.devices.reshape(-1))
    arrays, statics, val = graph_input_specs(p)
    stepper = make_distributed_stepper(
        mesh, tuple(axes), CC, statics, num_supersteps=num_supersteps, inner_cap=inner_cap
    )
    spec2 = P(tuple(axes), None)
    spec3 = P(tuple(axes), None, None)
    in_sh = (
        {k: NamedSharding(mesh, spec3 if v.ndim == 3 else spec2) for k, v in arrays.items()},
        NamedSharding(mesh, spec2),
    )
    with mesh:
        t0 = time.time()
        lowered = jax.jit(stepper, in_shardings=in_sh).lower(arrays, val)
        compiled = lowered.compile()
        compile_s = time.time() - t0
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        text = compiled.as_text()
    coll = parse_collectives(text)
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    terms = roofline_terms(flops, hbm, coll.total_link_bytes)
    return dict(
        arch="graph_bsp_cc",
        shape=f"p{p}_friendster_scale",
        mesh="2x16x16" if multi_pod else "16x16",
        chips=p,
        compile_s=round(compile_s, 2),
        flops_per_device=flops,
        hbm_bytes_per_device=hbm,
        link_bytes_per_device=coll.total_link_bytes,
        collectives=coll.per_op,
        arg_bytes=mem.argument_size_in_bytes,
        temp_bytes=mem.temp_size_in_bytes,
        per_device_hbm_total=mem.argument_size_in_bytes + mem.temp_size_in_bytes,
        **terms,
    )
