"""ShapeDtypeStruct stand-ins for every model input — shardable, weak-type
correct, zero device allocation. This is what the dry-run lowers against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import SHAPES
from repro.models.config import ModelConfig
from repro.models.transformer import init_caches


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, seq: int, gb: int) -> dict:
    b: dict = {"targets": sds((gb, seq), jnp.int32)}
    if cfg.frontend:
        b["embeds"] = sds((gb, seq, cfg.d_model), jnp.bfloat16)
    else:
        b["tokens"] = sds((gb, seq), jnp.int32)
    if cfg.is_encdec:
        b["enc_embeds"] = sds((gb, seq, cfg.d_model), jnp.bfloat16)
    return b


def prefill_batch_specs(cfg: ModelConfig, seq: int, gb: int) -> dict:
    b: dict = {}
    if cfg.frontend:
        b["embeds"] = sds((gb, seq, cfg.d_model), jnp.bfloat16)
    else:
        b["tokens"] = sds((gb, seq), jnp.int32)
    if cfg.is_encdec:
        b["enc_embeds"] = sds((gb, seq, cfg.d_model), jnp.bfloat16)
    return b


def decode_batch_specs(cfg: ModelConfig, seq: int, gb: int) -> dict:
    b: dict = {"pos": sds((), jnp.int32)}
    if cfg.frontend:
        b["embed"] = sds((gb, 1, cfg.d_model), jnp.bfloat16)
    else:
        b["token"] = sds((gb, 1), jnp.int32)
    if cfg.is_encdec:
        # decode consumes the PREcomputed encoder output (from prefill);
        # re-running the encoder per token would waste ~all decode FLOPs.
        b["enc_out"] = sds((gb, seq, cfg.d_model), jnp.bfloat16)
    return b


def cache_specs_shapes(cfg: ModelConfig, gb: int, max_seq: int):
    """Shape pytree of the decode caches (eval_shape over init_caches)."""
    return jax.eval_shape(lambda: init_caches(cfg, gb, max_seq, jnp.bfloat16))


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """All ShapeDtypeStructs for one (arch × shape) cell."""
    seq, gb, kind = SHAPES[shape_name]
    if kind == "train":
        return dict(kind="train", batch=train_batch_specs(cfg, seq, gb))
    if kind == "prefill":
        return dict(kind="prefill", batch=prefill_batch_specs(cfg, seq, gb), max_seq=seq)
    # decode: KV cache of length `seq` already in memory, one new token.
    return dict(
        kind="decode",
        batch=decode_batch_specs(cfg, seq, gb),
        caches=cache_specs_shapes(cfg, gb, seq),
    )
