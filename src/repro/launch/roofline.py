"""Roofline-term extraction from compiled dry-run artifacts.

compute   = HLO_FLOPs(per-device) / peak_FLOPs
memory    = HLO_bytes(per-device) / HBM_bw
collective= per-device link bytes (HLO collective ops × ring factors) / link_bw

cost_analysis() runs on the SPMD-partitioned per-device module, so its
"flops"/"bytes accessed" are already per-chip; no further division needed.
Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s+(.[^=]*?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [G,N]<=[...] → N ranks per group
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


@dataclasses.dataclass
class CollectiveSummary:
    total_link_bytes: float  # per-device bytes crossing ICI (ring model)
    per_op: dict  # op kind → {count, bytes}

    def row(self):
        return dict(
            total_link_bytes=self.total_link_bytes,
            **{k: v["bytes"] for k, v in self.per_op.items()},
        )


def parse_collectives(hlo_text: str) -> CollectiveSummary:
    per_op: dict = {}
    total = 0.0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        out_bytes = _shape_bytes(type_str)
        g = _group_size(line)
        if g <= 1:
            continue
        ring = (g - 1) / g
        if kind == "all-gather":
            link = out_bytes * ring
        elif kind == "reduce-scatter":
            link = out_bytes * (g - 1)  # input = out*g; each rank ships in*(g-1)/g
        elif kind == "all-reduce":
            link = 2 * out_bytes * ring
        elif kind == "all-to-all":
            link = out_bytes * ring
        else:  # collective-permute
            link = out_bytes
        d = per_op.setdefault(kind, {"count": 0, "bytes": 0.0})
        d["count"] += 1
        d["bytes"] += link
        total += link
    return CollectiveSummary(total_link_bytes=total, per_op=per_op)


def roofline_terms(flops: float, hbm_bytes: float, link_bytes: float) -> dict:
    c = flops / PEAK_FLOPS
    m = hbm_bytes / HBM_BW
    n = link_bytes / LINK_BW
    dom = max(("compute", c), ("memory", m), ("collective", n), key=lambda kv: kv[1])
    return dict(
        compute_s=c,
        memory_s=m,
        collective_s=n,
        bottleneck=dom[0],
        bound_s=dom[1],
    )


def model_flops(cfg, shape_kind: str, seq: int, gb: int, *, chips: int) -> float:
    """MODEL_FLOPS per chip per step: 6·N·D train, 2·N·D prefill/decode."""
    n_active = cfg.num_active_params()
    if shape_kind == "train":
        tokens = seq * gb
        mult = 6
    elif shape_kind == "prefill":
        tokens = seq * gb
        mult = 2
    else:  # decode: one token per sequence
        tokens = gb
        mult = 2
    return mult * n_active * tokens / chips
