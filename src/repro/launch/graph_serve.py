"""Graph-query serving driver: replay a synthetic power-law query trace
through the persistent `GraphQueryServer` and report serving metrics
(throughput, p50/p99 queue latency, padding waste, executable-cache hit
rate) as one JSON line.

  PYTHONPATH=src python -m repro.launch.graph_serve --queries 200 --rate 2000

Chaos mode: `--transient-prob`/`--straggler-prob`/`--malformed-prob` (with
`--fault-seed`) inject a deterministic `FaultPlan` into the serving path;
`--max-retries`, `--deadline-ms`, and `--max-queue` exercise the retry/
timeout/load-shed machinery. The output row then carries the resilience
counters, and the driver asserts the every-query-accounted-for invariant:
answered + failed == submitted, zero unhandled exceptions.

  PYTHONPATH=src python -m repro.launch.graph_serve --queries 120 \
      --transient-prob 0.2 --fault-seed 7 --max-retries 4
"""
from __future__ import annotations

import argparse
import json

from repro.api import GraphPipeline
from repro.graph.generate import rmat
from repro.resilience import FaultPlan, RetryPolicy
from repro.serve.trace import synthetic_trace


def run_graph_serve(
    *,
    num_vertices: int = 1 << 12,
    num_edges: int = 40_000,
    parts: int = 8,
    partitioner: str = "ebg_chunked",
    queries: int = 200,
    rate_qps: float = 2000.0,
    max_batch: int = 8,
    max_delay_s: float = 0.005,
    programs: tuple = ("bfs", "sssp"),
    compute_backend: str = "xla",
    seed: int = 0,
    fault_seed: int = 0,
    transient_prob: float = 0.0,
    straggler_prob: float = 0.0,
    straggler_delay_s: float = 0.0,
    malformed_prob: float = 0.0,
    max_retries: int = 3,
    deadline_s=None,
    max_queue=None,
) -> dict:
    """Build graph → partition → serve a trace; returns the report row
    plus the setup facts (the `pipeline_smoke` serving section reuses the
    same path at smoke scale). Non-zero fault probabilities arm the
    deterministic chaos plan; the run must still terminate every query."""
    graph = rmat(num_vertices, num_edges, seed=seed, a=0.65, b=0.15, c=0.15)
    pipe = GraphPipeline(graph).partition(partitioner, parts=parts)
    chaos = transient_prob > 0 or straggler_prob > 0 or malformed_prob > 0
    fault_plan = FaultPlan(
        seed=fault_seed,
        transient_error_prob=transient_prob,
        straggler_prob=straggler_prob,
        straggler_delay_s=straggler_delay_s,
        malformed_batch_prob=malformed_prob,
    ) if chaos else None
    server = pipe.serve(
        max_batch=max_batch, max_delay_s=max_delay_s, compute_backend=compute_backend,
        fault_plan=fault_plan, retry=RetryPolicy(max_retries=max_retries),
        deadline_s=deadline_s, max_queue=max_queue,
    )
    trace = synthetic_trace(
        graph, queries, rate_qps=rate_qps,
        mix=tuple((p, 1.0) for p in programs), seed=seed,
    )
    report = server.run_trace(trace)
    counters = server.resilience_counters()
    # The resilience invariant: every admitted query terminated, answered
    # or failed with a named reason — nothing lost, nothing unhandled.
    if counters["terminated"] != queries:
        raise AssertionError(
            f"serving trace lost queries: {counters['terminated']} terminated "
            f"of {queries} submitted ({counters})"
        )
    return {
        "graph": {"num_vertices": graph.num_vertices, "num_edges": graph.num_edges,
                  "p": parts, "partitioner": partitioner},
        "trace": {"queries": queries, "rate_qps": rate_qps,
                  "programs": list(programs), "max_batch": max_batch,
                  "max_delay_s": max_delay_s},
        "faults": {"enabled": chaos, "seed": fault_seed,
                   "transient_prob": transient_prob, "straggler_prob": straggler_prob,
                   "malformed_prob": malformed_prob, "max_retries": max_retries},
        **report.row(),
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--vertices", type=int, default=1 << 12)
    ap.add_argument("--edges", type=int, default=40_000)
    ap.add_argument("--parts", type=int, default=8)
    ap.add_argument("--partitioner", default="ebg_chunked")
    ap.add_argument("--queries", type=int, default=200)
    ap.add_argument("--rate", type=float, default=2000.0, help="arrival rate (queries/s)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-delay-ms", type=float, default=5.0)
    ap.add_argument("--programs", default="bfs,sssp", help="comma-separated program mix")
    ap.add_argument("--backend", default="xla", choices=("xla", "ref", "pallas"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fault-seed", type=int, default=0, help="FaultPlan seed (chaos replay)")
    ap.add_argument("--transient-prob", type=float, default=0.0,
                    help="per-attempt injected transient backend error probability")
    ap.add_argument("--straggler-prob", type=float, default=0.0,
                    help="per-batch injected straggler probability")
    ap.add_argument("--straggler-delay-ms", type=float, default=10.0,
                    help="virtual delay charged per injected straggler")
    ap.add_argument("--malformed-prob", type=float, default=0.0,
                    help="per-attempt injected malformed-batch probability")
    ap.add_argument("--max-retries", type=int, default=3,
                    help="bounded retry budget per micro-batch")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-query deadline from arrival (default: none)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="admission queue bound (overflow load-sheds)")
    args = ap.parse_args(argv)
    out = run_graph_serve(
        num_vertices=args.vertices, num_edges=args.edges, parts=args.parts,
        partitioner=args.partitioner, queries=args.queries, rate_qps=args.rate,
        max_batch=args.max_batch, max_delay_s=args.max_delay_ms / 1000.0,
        programs=tuple(p.strip() for p in args.programs.split(",") if p.strip()),
        compute_backend=args.backend, seed=args.seed,
        fault_seed=args.fault_seed, transient_prob=args.transient_prob,
        straggler_prob=args.straggler_prob,
        straggler_delay_s=args.straggler_delay_ms / 1000.0,
        malformed_prob=args.malformed_prob, max_retries=args.max_retries,
        deadline_s=None if args.deadline_ms is None else args.deadline_ms / 1000.0,
        max_queue=args.max_queue,
    )
    print(json.dumps(out))
    return out


if __name__ == "__main__":
    main()
