"""Graph-query serving driver: replay a synthetic power-law query trace
through the persistent `GraphQueryServer` and report serving metrics
(throughput, p50/p99 queue latency, padding waste, executable-cache hit
rate) as one JSON line.

  PYTHONPATH=src python -m repro.launch.graph_serve --queries 200 --rate 2000
"""
from __future__ import annotations

import argparse
import json

from repro.api import GraphPipeline
from repro.graph.generate import rmat
from repro.serve.trace import synthetic_trace


def run_graph_serve(
    *,
    num_vertices: int = 1 << 12,
    num_edges: int = 40_000,
    parts: int = 8,
    partitioner: str = "ebg_chunked",
    queries: int = 200,
    rate_qps: float = 2000.0,
    max_batch: int = 8,
    max_delay_s: float = 0.005,
    programs: tuple = ("bfs", "sssp"),
    compute_backend: str = "xla",
    seed: int = 0,
) -> dict:
    """Build graph → partition → serve a trace; returns the report row
    plus the setup facts (the `pipeline_smoke` serving section reuses the
    same path at smoke scale)."""
    graph = rmat(num_vertices, num_edges, seed=seed, a=0.65, b=0.15, c=0.15)
    pipe = GraphPipeline(graph).partition(partitioner, parts=parts)
    server = pipe.serve(
        max_batch=max_batch, max_delay_s=max_delay_s, compute_backend=compute_backend
    )
    trace = synthetic_trace(
        graph, queries, rate_qps=rate_qps,
        mix=tuple((p, 1.0) for p in programs), seed=seed,
    )
    report = server.run_trace(trace)
    return {
        "graph": {"num_vertices": graph.num_vertices, "num_edges": graph.num_edges,
                  "p": parts, "partitioner": partitioner},
        "trace": {"queries": queries, "rate_qps": rate_qps,
                  "programs": list(programs), "max_batch": max_batch,
                  "max_delay_s": max_delay_s},
        **report.row(),
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--vertices", type=int, default=1 << 12)
    ap.add_argument("--edges", type=int, default=40_000)
    ap.add_argument("--parts", type=int, default=8)
    ap.add_argument("--partitioner", default="ebg_chunked")
    ap.add_argument("--queries", type=int, default=200)
    ap.add_argument("--rate", type=float, default=2000.0, help="arrival rate (queries/s)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-delay-ms", type=float, default=5.0)
    ap.add_argument("--programs", default="bfs,sssp", help="comma-separated program mix")
    ap.add_argument("--backend", default="xla", choices=("xla", "ref", "pallas"))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    out = run_graph_serve(
        num_vertices=args.vertices, num_edges=args.edges, parts=args.parts,
        partitioner=args.partitioner, queries=args.queries, rate_qps=args.rate,
        max_batch=args.max_batch, max_delay_s=args.max_delay_ms / 1000.0,
        programs=tuple(p.strip() for p in args.programs.split(",") if p.strip()),
        compute_backend=args.backend, seed=args.seed,
    )
    print(json.dumps(out))
    return out


if __name__ == "__main__":
    main()
