import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first: jax locks the device count at first
init, and the production meshes need 512 placeholder host devices.

Per cell this script:
  1. builds the production mesh (16x16 pod / 2x16x16 multi-pod),
  2. lowers the right step (train_4k → train_step; prefill_32k →
     prefill_step; decode_32k / long_500k → serve_step) against
     ShapeDtypeStruct inputs with explicit in/out shardings,
  3. compiles, prints memory_analysis() (proves fit) and cost_analysis()
     (FLOPs/bytes for §Roofline), parses collective bytes from the HLO,
  4. applies the scan-body correction (XLA counts a while-loop body once —
     a 2-group unrolled twin isolates the per-group cost exactly),
  5. writes a JSON record to --out.

Usage:
  python -m repro.launch.dryrun --arch llama3_2_3b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro import configs
from repro.compat import cost_analysis_compat
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.launch.roofline import model_flops, parse_collectives, roofline_terms
from repro.launch.shapes import cache_specs_shapes, input_specs
from repro.launch.sharding import (
    batch_shardings,
    cache_specs,
    opt_state_shardings,
    param_shardings,
)
from repro.models.pspec import activation_axes
from repro.models.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models.transformer import init_params
from repro.optim.adam import AdamWConfig, init_opt_state


def _lower_one(cfg, shape: str, mesh, overrides: dict, *, unroll_scan: bool = False):
    """Lower + compile one step for `cfg` on `mesh`. Returns compiled."""
    seq, gb, kind = configs.SHAPES[shape]
    params_shape = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), jnp.bfloat16)
    )
    fsdp = () if overrides.get("serve_repl") else None
    p_shard = param_shardings(cfg, params_shape, mesh, fsdp=fsdp)
    specs = input_specs(cfg, shape)
    b_shard = batch_shardings(specs["batch"], mesh)

    with mesh, activation_axes(mesh, dp=dp_axes(mesh), tp="model",
                               sp=overrides.get("sp"), unroll_scan=unroll_scan,
                               ep_shard_map=overrides.get("ep_shard_map", False)):
        if kind == "train":
            # >100B params: bf16 optimizer states (see EXPERIMENTS.md §Dry-run)
            state_dtype = jnp.bfloat16 if cfg.num_params() > 1e11 else jnp.float32
            opt = AdamWConfig(state_dtype=state_dtype,
                              compress_grads=overrides.get("compress_grads"))
            opt_shape = jax.eval_shape(lambda: init_opt_state(params_shape, opt))
            o_shard = opt_state_shardings(p_shard, mesh)
            step = make_train_step(cfg, opt, remat=True,
                                   vocab_parallel=overrides.get("vocab_parallel", False))
            lowered = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
            ).lower(params_shape, opt_shape, specs["batch"])
        elif kind == "prefill":
            step = make_prefill_step(cfg, specs["max_seq"])
            cshape = cache_specs_shapes(cfg, gb, specs["max_seq"])
            c_shard = cache_specs(cfg, cshape, mesh)
            lowered = jax.jit(
                step,
                in_shardings=(p_shard, b_shard),
                out_shardings=(None, c_shard),
            ).lower(params_shape, specs["batch"])
        else:  # decode
            step = make_serve_step(cfg)
            c_shard = cache_specs(cfg, specs["caches"], mesh)
            donate = (1,) if overrides.get("donate_cache") else ()
            lowered = jax.jit(
                step,
                in_shardings=(p_shard, c_shard, b_shard),
                out_shardings=(None, c_shard),
                donate_argnums=donate,
            ).lower(params_shape, specs["caches"], specs["batch"])
        compiled = lowered.compile()
    return compiled


def _cost_triple(compiled):
    cost = cost_analysis_compat(compiled)
    coll = parse_collectives(compiled.as_text())
    return (
        float(cost.get("flops", 0.0)),
        float(cost.get("bytes accessed", 0.0)),
        coll.total_link_bytes,
        coll,
    )


def lower_cell(arch: str, shape: str, *, multi_pod: bool, plan: str = "baseline",
               correct_scan: bool = True):
    cfg = configs.get_config(arch)
    if plan != "baseline":
        from repro.launch import plans

        cfg, overrides = plans.apply_plan(cfg, arch, shape, plan)
    else:
        overrides = {}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = len(mesh.devices.reshape(-1))
    seq, gb, kind = configs.SHAPES[shape]

    t0 = time.time()
    compiled = _lower_one(cfg, shape, mesh, overrides)
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    f_full, b_full, l_full, coll = _cost_triple(compiled)

    # Scan-body correction: XLA's cost_analysis counts a while-loop body
    # ONCE. A 2-group UNROLLED twin minus the full scanned program isolates
    # one group body exactly; full + (G-1)*body is the true per-step cost.
    G = cfg.n_groups
    flops, hbm, link = f_full, b_full, l_full
    corrected = False
    if correct_scan and G > 1:
        try:
            twin_cfg = dataclasses.replace(
                cfg,
                n_layers=2 * len(cfg.group),
                n_enc_layers=min(2, cfg.n_enc_layers),
            )
            twin = _lower_one(twin_cfg, shape, mesh, overrides, unroll_scan=True)
            f2, b2, l2, _ = _cost_triple(twin)
            scale = G - 1
            flops = f_full + scale * max(f2 - f_full, 0.0)
            hbm = b_full + scale * max(b2 - b_full, 0.0)
            link = l_full + scale * max(l2 - l_full, 0.0)
            corrected = True
        except Exception as e:  # keep raw HLO numbers
            print(f"     (scan correction failed: {e})")

    terms = roofline_terms(flops, hbm, link)
    mflops = model_flops(cfg, kind, seq, gb, chips=chips)
    rec = dict(
        arch=arch,
        shape=shape,
        mesh="2x16x16" if multi_pod else "16x16",
        plan=plan,
        chips=chips,
        kind=kind,
        compile_s=round(compile_s, 2),
        flops_per_device=flops,
        hbm_bytes_per_device=hbm,
        link_bytes_per_device=link,
        raw_flops_uncorrected=f_full,
        scan_corrected=corrected,
        collectives={k: v for k, v in coll.per_op.items()},
        model_flops_per_device=mflops,
        useful_flops_frac=(mflops / flops) if flops else None,
        arg_bytes=mem.argument_size_in_bytes,
        temp_bytes=mem.temp_size_in_bytes,
        output_bytes=mem.output_size_in_bytes,
        per_device_hbm_total=(
            mem.argument_size_in_bytes + mem.temp_size_in_bytes + mem.output_size_in_bytes
        ),
        **terms,
    )
    return rec, mem, cost_analysis_compat(compiled), None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="pod")
    ap.add_argument("--plan", default="baseline")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-correct", action="store_true",
                    help="skip the scan-body cost correction")
    ap.add_argument("--graph-engine", action="store_true",
                    help="also dry-run the subgraph-centric BSP engine (paper core)")
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    archs = configs.ARCHS if (args.all or args.arch is None) else [args.arch]
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    for arch in archs:
        shapes = configs.runnable_shapes(arch)
        if args.shape:
            if args.shape not in shapes:
                print(f"[skip] {arch} × {args.shape}: not runnable (DESIGN.md §4)")
                continue
            shapes = [args.shape]
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}__{args.plan}"
                try:
                    rec, mem, cost, _ = lower_cell(
                        arch, shape, multi_pod=mp, plan=args.plan,
                        correct_scan=not args.no_correct,
                    )
                    print(f"[ok] {tag}: compile={rec['compile_s']}s "
                          f"bottleneck={rec['bottleneck']} bound={rec['bound_s']:.4f}s "
                          f"hbm/dev={rec['per_device_hbm_total']/2**30:.2f}GiB "
                          f"useful={rec['useful_flops_frac']:.3f}")
                    print(f"     memory_analysis: {mem}")
                    (outdir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
                except Exception as e:
                    print(f"[FAIL] {tag}: {e}")
                    traceback.print_exc()
                    (outdir / f"{tag}.FAIL").write_text(str(e))

    if args.graph_engine:
        from repro.launch.graph_dryrun import run_graph_dryrun

        for mp in meshes:
            rec = run_graph_dryrun(multi_pod=mp)
            tag = f"graph_bsp__cc__{'mp' if mp else 'sp'}"
            (outdir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
            print(f"[ok] {tag}: {rec['bottleneck']} bound={rec['bound_s']:.6f}s")


if __name__ == "__main__":
    main()
