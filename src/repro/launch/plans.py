"""Named optimization plans for §Perf hillclimbing.

A plan transforms (cfg, overrides) before lowering — the mechanism the
hypothesis→change→measure loop uses. `baseline` is the paper-faithful
untouched configuration; EXPERIMENTS.md §Perf logs every iteration.

overrides keys consumed by launch/dryrun.py:
  sp:             mesh axis for sequence-parallel activations ("model")
  compress_grads: "bf16" gradient all-reduce compression
  vocab_parallel: one-hot vocab-parallel loss (kills the logits all-gather)
  serve_repl:     replicate weights over the DP axes for decode (kills the
                  per-step FSDP all-gathers; weights easily fit when serving)
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig


def apply_plan(cfg: ModelConfig, arch: str, shape: str, plan: str):
    """Returns (cfg, overrides) for a named plan."""
    overrides: dict = {}
    if plan == "baseline":
        return cfg, overrides

    parts = plan.split("+")
    for p in parts:
        if p == "vp":  # vocab-parallel loss
            overrides["vocab_parallel"] = True
        elif p == "sp":  # sequence-parallel activations over the model axis
            overrides["sp"] = "model"
        elif p == "bf16g":  # gradient compression
            overrides["compress_grads"] = "bf16"
        elif p == "cap1":  # MoE: capacity 1.0 — less dispatch padding
            assert cfg.moe is not None
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1.0)
            )
        elif p == "repl":  # serving: weights replicated over DP axes
            overrides["serve_repl"] = True
        elif p == "don":  # serving: donate the KV cache (in-place update)
            overrides["donate_cache"] = True
        elif p == "ep":  # MoE: manual shard_map EP dispatch + psum combine
            overrides["ep_shard_map"] = True
        else:
            raise ValueError(f"unknown plan component {p!r} in {plan!r}")
    return cfg, overrides
