"""End-to-end training driver.

On a real TPU cluster this runs under the production mesh; on the CPU
container it trains the preset models end-to-end (deliverable (b)):

  PYTHONPATH=src python -m repro.launch.train --preset tiny --steps 200

Features exercised: deterministic restartable data pipeline, AdamW with
sharded states, checkpoint/restart (--resume picks up the latest step),
async checkpoint I/O overlap, bf16 gradient compression flag.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import ckpt as CKPT
from repro.data.pipeline import DataConfig, shard_batch_at_step
from repro.models.config import LayerSpec, ModelConfig
from repro.models.steps import make_train_step
from repro.models.transformer import init_params
from repro.optim.adam import AdamWConfig, init_opt_state

PRESETS = {
    # name: (d_model, n_layers, n_heads, kv, d_ff, vocab)  ~params
    "tiny": (128, 4, 4, 2, 512, 2048),  # ~2M — quick demos
    "small": (256, 6, 8, 4, 1024, 8192),  # ~12M
    "base": (512, 12, 8, 4, 2048, 32768),  # ~100M
}


def preset_config(name: str) -> ModelConfig:
    d, L, H, kv, f, v = PRESETS[name]
    return ModelConfig(
        name=f"preset_{name}",
        family="dense",
        n_layers=L,
        d_model=d,
        n_heads=H,
        n_kv_heads=kv,
        d_ff=f,
        vocab=v,
        group=(LayerSpec(kind="attn", mlp="dense"),),
        tie_embeddings=True,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--arch", default=None, help="use a reduced assigned arch instead")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = configs.reduced_config(args.arch) if args.arch else preset_config(args.preset)
    opt = AdamWConfig(
        lr=args.lr,
        warmup_steps=20,
        total_steps=args.steps,
        compress_grads="bf16" if args.compress_grads else None,
    )
    data = DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)

    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    opt_state = init_opt_state(params, opt)
    start = 0
    ckpter = None
    if args.ckpt_dir:
        ckpter = CKPT.AsyncCheckpointer(args.ckpt_dir)
        if args.resume:
            last = CKPT.latest_step(args.ckpt_dir)
            if last is not None:
                state = CKPT.restore(args.ckpt_dir, last, dict(params=params, opt=opt_state))
                params, opt_state = state["params"], state["opt"]
                start = last
                print(f"[resume] restored step {last}")

    step_fn = jax.jit(make_train_step(cfg, opt, remat=False), donate_argnums=(0, 1))
    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = shard_batch_at_step(data, step, 0, 1)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"step {step:5d}  loss {losses[-1]:.4f}  ({dt:.1f}s)")
        if ckpter and (step + 1) % args.ckpt_every == 0:
            ckpter.save(step + 1, dict(params=params, opt=opt_state))
    if ckpter:
        ckpter.save(args.steps, dict(params=params, opt=opt_state))
        ckpter.wait()
    print(f"final loss {np.mean(losses[-10:]):.4f} (first10 {np.mean(losses[:10]):.4f})")
    return losses


if __name__ == "__main__":
    main()
