"""Elastic scaling: reshard a checkpoint across a different device count.

A checkpoint stores device-agnostic host arrays; resharding = restoring
with shardings derived from the NEW mesh. `reshard` is the library entry;
the CLI rewrites a checkpoint directory (e.g. after losing a pod, restart
on 256 chips from a 512-chip checkpoint — ZeRO/FSDP states follow the
parameter specs so nothing else changes).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt as CKPT
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import param_shardings


def reshard(tree, shardings):
    """Device-put every leaf to its new sharding (gather + rechunk)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(jnp.asarray(x), s), tree, shardings
    )


def reshard_checkpoint(cfg, ckpt_dir: str, step: int, new_mesh):
    """Restore a params checkpoint onto `new_mesh`'s shardings."""
    params_shape = jax.eval_shape(
        lambda: __import__("repro.models.transformer", fromlist=["init_params"]).init_params(
            cfg, jax.random.PRNGKey(0), jnp.bfloat16
        )
    )
    shardings = param_shardings(cfg, params_shape, new_mesh)
    like = jax.tree.map(
        lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh),
        params_shape, shardings,
    )
    return CKPT.restore(ckpt_dir, step, like)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--devices", type=int, default=None)
    args = ap.parse_args(argv)
    mesh = make_host_mesh(args.devices)
    step = CKPT.latest_step(args.ckpt_dir)
    print(f"resharding step {step} onto {mesh}")


if __name__ == "__main__":
    main()
