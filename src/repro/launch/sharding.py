"""Sharding rules: config + mesh → PartitionSpecs for params / opt state /
batches / caches.

Baseline layout (hillclimbed variants in launch/dryrun.py --plan):
  - 2D FSDP×TP: every big matrix shards its input-ish dim over the
    data-parallel axes and its output-ish dim over `model`;
  - MoE experts shard over `model` (expert parallelism), expert weights'
    d_model dim over FSDP;
  - KV caches: batch over DP; kv-heads over `model` when divisible, else
    the sequence dim when divisible, else replicated;
  - optimizer states inherit the parameter specs (ZeRO-1 for free).
A dim is only assigned a mesh axis when its size divides the axis size —
`_fit` degrades gracefully for the reduced smoke configs.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return math.prod(mesh.shape[a] for a in axes)


def _fit(mesh, axes, dim: int):
    """Return axes if dim divides their product size, else None."""
    return axes if axes is not None and dim % _axis_size(mesh, axes) == 0 else None


def param_spec(path: str, shape: tuple[int, ...], mesh, *, fsdp) -> P:
    """Spec for one parameter leaf; `path` is the '/'.joined key path."""
    name = path.split("/")[-1]
    nd = len(shape)

    def spec(*entries):
        # pad with None for any leading stacked-group dims
        return P(*([None] * (nd - len(entries)) + list(entries)))

    if name in ("embed", "unembed"):
        a, b = shape[-2], shape[-1]
        return P(_fit(mesh, fsdp, a), _fit(mesh, "model", b))
    if name in ("wq", "wk", "wv"):  # [.., d, H, hd]
        return spec(_fit(mesh, fsdp, shape[-3]), _fit(mesh, "model", shape[-2]), None)
    if name == "wo":  # [.., H, hd, d]
        return spec(_fit(mesh, "model", shape[-3]), None, _fit(mesh, fsdp, shape[-1]))
    if name in ("bq", "bk", "bv"):  # [.., H, hd]
        return spec(_fit(mesh, "model", shape[-2]), None)
    if name in ("w_gate", "w_in", "w_out"):
        if "moe" in path:
            # experts: EP over model on E; FSDP on the d_model dim
            if name == "w_out":  # [.., E, f, d]
                return spec(_fit(mesh, "model", shape[-3]), None, _fit(mesh, fsdp, shape[-1]))
            return spec(_fit(mesh, "model", shape[-3]), _fit(mesh, fsdp, shape[-2]), None)
        if name == "w_out":  # [.., f, d]
            return spec(_fit(mesh, "model", shape[-2]), _fit(mesh, fsdp, shape[-1]))
        return spec(_fit(mesh, fsdp, shape[-2]), _fit(mesh, "model", shape[-1]))
    if name == "router":  # [.., d, E]
        return spec(_fit(mesh, fsdp, shape[-2]), None)
    if name == "in_proj":  # [.., d, e]
        return spec(_fit(mesh, fsdp, shape[-2]), _fit(mesh, "model", shape[-1]))
    if name == "out_proj":  # [.., d_in, d]
        return spec(_fit(mesh, "model", shape[-2]), _fit(mesh, fsdp, shape[-1]))
    if name in ("conv_w",):  # [.., K, c]
        return spec(None, _fit(mesh, "model", shape[-1]))
    if name in ("conv_b", "out_norm"):
        return spec(_fit(mesh, "model", shape[-1]))
    if name in ("dt_bias", "A_log", "D"):
        return spec(_fit(mesh, "model", shape[-1]))
    # norms, scalars → replicated
    return P(*([None] * nd))


def _tree_specs(tree, mesh, fsdp, prefix=""):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        specs.append(param_spec(pstr, leaf.shape, mesh, fsdp=fsdp))
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(cfg: ModelConfig, params_shape, mesh, fsdp=None):
    """Pytree of NamedShardings matching a params (shape-)pytree.

    fsdp=() replicates weights over the DP axes (serving layout: no
    per-step parameter all-gathers, at the cost of HBM).
    """
    from repro.launch.mesh import dp_axes

    if fsdp is None:
        fsdp = dp_axes(mesh)
    specs = _tree_specs(params_shape, mesh, fsdp if fsdp else None)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def opt_state_shardings(param_shard, mesh):
    """mu/nu inherit parameter shardings; step replicated (ZeRO-1)."""
    return dict(
        mu=param_shard,
        nu=param_shard,
        step=NamedSharding(mesh, P()),
    )


def cache_specs(cfg: ModelConfig, cache_shape, mesh):
    """Shardings for the decode caches (leading dim = groups)."""
    from repro.launch.mesh import dp_axes

    fsdp = dp_axes(mesh)

    def one(path, leaf):
        name = str(getattr(path[-1], "key", ""))
        shape = leaf.shape
        nd = len(shape)
        if name in ("k", "v"):  # [G, B, S, Hkv, hd]
            b = _fit(mesh, fsdp, shape[1])
            heads = _fit(mesh, "model", shape[3])
            seq = None if heads else _fit(mesh, "model", shape[2])
            return P(None, b, seq, heads, None)
        if name == "state":  # [G, B, nh, p, n]
            return P(None, _fit(mesh, fsdp, shape[1]), _fit(mesh, "model", shape[2]), None, None)
        if name == "conv":  # [G, B, K-1, c]
            return P(None, _fit(mesh, fsdp, shape[1]), None, _fit(mesh, "model", shape[3]))
        if name == "pos":
            return P()
        return P(*([None] * nd))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    specs = [one(path, leaf) for path, leaf in flat]
    tree = jax.tree_util.tree_unflatten(treedef, specs)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_shardings(batch_shape, mesh):
    """Token/embed batches: leading batch dim over DP axes; scalars replicated."""
    from repro.launch.mesh import dp_axes

    fsdp = dp_axes(mesh)

    def one(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        b = _fit(mesh, fsdp, leaf.shape[0])
        return NamedSharding(mesh, P(*([b] + [None] * (leaf.ndim - 1))))

    return jax.tree.map(one, batch_shape)
