"""Batched serving driver: prefill + greedy decode with KV/SSM caches.

  PYTHONPATH=src python -m repro.launch.serve --preset tiny --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models.steps import make_prefill_step, make_serve_step
from repro.models.transformer import init_params
from repro.launch.train import preset_config, PRESETS
from repro.serve.padding import bucket_size, pad_batch_rows


def generate(cfg, params, prompt_tokens, max_new: int, max_seq: int):
    """prompt_tokens: [B, S0] → greedy continuation [B, max_new]."""
    B, S0 = prompt_tokens.shape
    prefill = jax.jit(make_prefill_step(cfg, max_seq))
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    logits, caches = prefill(params, dict(tokens=prompt_tokens))
    out = []
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for t in range(max_new):
        out.append(tok)
        logits, caches = serve(params, caches, dict(token=tok, pos=jnp.int32(S0 + t)))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--arch", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = configs.reduced_config(args.arch) if args.arch else preset_config(args.preset)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    # Same padded-shape policy as the graph-query server: pad the request
    # batch to its bucket so compiled prefill/decode shapes stay bounded,
    # run padded, return only the real rows.
    bucket = bucket_size(args.batch)
    prompt_np = pad_batch_rows(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), bucket
    )
    prompt = jnp.asarray(prompt_np, jnp.int32)
    t0 = time.time()
    out = generate(cfg, params, prompt, args.tokens, args.prompt_len + args.tokens)[: args.batch]
    dt = time.time() - t0
    total = args.batch * args.tokens
    print(
        f"generated {total} tokens in {dt:.2f}s ({total/dt:.1f} tok/s, "
        f"batch {args.batch} padded to bucket {bucket})"
    )
    print("sample:", np.asarray(out[0][:16]))
    assert np.isfinite(dt)
    return out


if __name__ == "__main__":
    main()
