"""Superstep checkpoint/resume for the BSP engine: segmented execution
with bit-identical recovery.

`run_bsp_resilient` runs the SAME programs as `engine.run_bsp` (it is
what `run_bsp(..., checkpoint_every=k, ckpt_dir=...)` delegates to) but
drives the loop in segments: every `checkpoint_every` supersteps the
value carry plus the per-step `BSPStats` buffers are snapshotted through
`repro.checkpoint.ckpt`, and an injected `FaultPlan` crash kills the run
mid-flight with a `WorkerCrashError`. `resume_bsp` restores the latest
checkpoint and continues — final values AND stats are bit-identical to
an uninterrupted run (tests/test_resilience.py pins this for cc/sssp/pr
on both drivers).

Why segments compose exactly: with exchange_period=1 the fused driver's
delta-message reference (`count_ref`) is always the step's entry value,
so a step's message counts depend only on the state it starts from — a
checkpoint boundary is indistinguishable from any other step boundary.
With bounded staleness (period>1), checkpoints are restricted to
exchange-period boundaries (`checkpoint_every % exchange_period == 0`),
where the last step exchanged and the carried `last_ex` snapshot equals
the value itself. The fused engine additionally returns its converged
flag (see `engine._fused_bsp`) so a run that converges exactly on a
segment boundary stops instead of paying a phantom extra superstep.

Checkpoints hold EXEC-domain values (max-combine programs store the
negated view the superstep body runs on; negation is exact for int32 and
f32, so the round-trip is bitwise). A side `resume.json` in `ckpt_dir`
records the program, driver, backend, engine knobs, and a subgraph
fingerprint; `resume_bsp` validates the fingerprint before continuing so
a checkpoint cannot silently resume onto the wrong build.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.graph import engine
from repro.resilience.faults import FaultPlan, WorkerCrashError

RESUME_META = "resume.json"


@dataclasses.dataclass
class _SegState:
    """Host-side carry between segments (and across crash/resume)."""

    val: np.ndarray  # [p, max_v+1] EXEC-domain value carry (rank-encoded
    # when a two-level label-domain run carries a codec)
    done: int  # supersteps completed
    msgs: list  # list of [k, p] int64 per-segment message blocks
    iters: list  # list of [k, p] int64 per-segment inner-iter blocks
    converged: bool
    codec: object = None  # engine._ValueCodec for two-level label programs

    def stack(self, p: int) -> tuple[np.ndarray, np.ndarray]:
        if not self.msgs:
            z = np.zeros((0, p), np.int64)
            return z, z.copy()
        return np.concatenate(self.msgs, axis=0), np.concatenate(self.iters, axis=0)


def _sub_fingerprint(sub) -> dict:
    return {
        "num_parts": int(sub.num_parts),
        "max_v": int(sub.max_v),
        "max_e": int(sub.max_e),
        "max_msg": int(sub.max_msg),
        "addressing": str(sub.addressing),
    }


def _ckpt_tree(state: _SegState, p: int) -> dict:
    msgs, iters = state.stack(p)
    # The rank codec's table rides in the snapshot: the carry holds ENCODED
    # values, and the codec may have been built from a caller-supplied
    # init_val that resume cannot re-derive.
    uniq = np.asarray(state.codec.uniq if state.codec is not None else (), np.int32)
    return {
        "val": np.asarray(state.val),
        "msgs": msgs,
        "iters": iters,
        "converged": np.int32(state.converged),
        "codec_uniq": uniq,
    }


def _write_meta(ckpt_dir, sub, prog, knobs: dict) -> None:
    meta = {"program": prog.name, "sub": _sub_fingerprint(sub), **knobs}
    d = Path(ckpt_dir)
    d.mkdir(parents=True, exist_ok=True)
    (d / RESUME_META).write_text(json.dumps(meta, indent=2))


def _run_fused_segment(sub, exec_prog, state: _SegState, seg: int, *, inner_cap,
                       exchange_period, tol, num_vertices, compute_backend,
                       block_e=512) -> None:
    # _fused_bsp donates its value arg: feed it a fresh device buffer per
    # segment (the host copy in `state` is the one we keep).
    val_dev = jnp.asarray(np.ascontiguousarray(state.val))
    val, steps, converged, msgs_buf, iters_buf, _ = engine._fused_bsp(
        sub, val_dev, prog=exec_prog, max_supersteps=seg, inner_cap=inner_cap,
        exchange_period=exchange_period, tol=tol, num_vertices=num_vertices,
        backend=compute_backend, block_e=block_e,
    )
    engine.DISPATCH_COUNTS["fused"] += 1
    val, steps, converged, msgs_sw, iters_sw = jax.device_get(
        (val, steps, converged, msgs_buf, iters_buf)
    )
    steps = int(steps)
    state.val = np.asarray(val)
    state.msgs.append(msgs_sw[:steps].astype(np.int64))
    state.iters.append(iters_sw[:steps].astype(np.int64))
    state.done += steps
    state.converged = bool(converged)


def _run_host_segment(sub, exec_prog, state: _SegState, seg: int, *, inner_cap,
                      exchange_period, tol, num_vertices, compute_backend,
                      block_e=512) -> None:
    val = jnp.asarray(state.val)
    # Segment boundaries are exchange-period boundaries, so the value IS
    # the last-exchanged snapshot the delta counter references.
    last_ex = val
    msg_steps, iters_steps = [], []
    for k in range(state.done, state.done + seg):
        do_exchange = (k % exchange_period) == exchange_period - 1
        before = val
        val, msgs, iters, delta = engine._jit_superstep_sim(
            exec_prog, sub, val, inner_cap, do_exchange, last_ex,
            num_vertices, compute_backend, block_e,
        )
        engine.DISPATCH_COUNTS["host"] += 1
        if do_exchange:
            last_ex = val
        msg_steps.append(np.asarray(msgs, np.int64))
        iters_steps.append(np.asarray(iters, np.int64))
        if exec_prog.convergence == "tol":
            if tol and float(delta) < tol:
                state.converged = True
        elif do_exchange and not bool(jnp.any(val != before)):
            state.converged = True
        if state.converged:
            break
    state.val = np.asarray(val)
    p = state.val.shape[0]
    state.msgs.append(np.asarray(msg_steps).reshape(len(msg_steps), p))
    state.iters.append(np.asarray(iters_steps).reshape(len(iters_steps), p))
    state.done += len(msg_steps)


def _run_segments(sub, exec_prog, negate, state: _SegState, *, max_supersteps,
                  inner_cap, exchange_period, tol, num_vertices, compute_backend,
                  driver, checkpoint_every, ckpt_dir, fault_plan, block_e=512):
    p = state.val.shape[0]
    run_seg = _run_fused_segment if driver == "fused" else _run_host_segment
    crash_at = None
    if fault_plan is not None and fault_plan.crash_at_superstep is not None:
        crash_at = int(fault_plan.crash_at_superstep)
    if checkpoint_every and ckpt_dir is not None and state.done == 0:
        ckpt.save(ckpt_dir, 0, _ckpt_tree(state, p))

    while not state.converged and state.done < max_supersteps:
        if crash_at is not None and state.done >= crash_at:
            # The doomed superstep is due: the worker dies before it can
            # complete (everything since the last checkpoint is lost —
            # resume_bsp recomputes it bit-identically).
            raise WorkerCrashError(superstep=state.done, ckpt_dir=ckpt_dir)
        stop = max_supersteps
        if checkpoint_every:
            stop = min(stop, (state.done // checkpoint_every + 1) * checkpoint_every)
        if crash_at is not None:
            stop = min(stop, crash_at)
        run_seg(
            sub, exec_prog, state, stop - state.done, inner_cap=inner_cap,
            exchange_period=exchange_period, tol=tol, num_vertices=num_vertices,
            compute_backend=compute_backend, block_e=block_e,
        )
        if checkpoint_every and ckpt_dir is not None and state.done % checkpoint_every == 0:
            ckpt.save(ckpt_dir, state.done, _ckpt_tree(state, p))

    msgs_sw, iters_sw = state.stack(p)
    edges = np.asarray(sub.edge_mask.sum(axis=1), np.int64)
    stats = engine._assemble_stats(state.done, msgs_sw, iters_sw, edges)
    val = jnp.asarray(state.val)
    if state.codec is not None:
        val = state.codec.decode(val)
    return (-val if negate else val), stats


def _check_ft_args(checkpoint_every, ckpt_dir, exchange_period) -> None:
    if checkpoint_every is not None:
        if int(checkpoint_every) < 1:
            raise ValueError(f"checkpoint_every must be >= 1, got {checkpoint_every!r}")
        if ckpt_dir is None:
            raise ValueError("checkpoint_every needs ckpt_dir= (where snapshots go)")
        if int(checkpoint_every) % int(exchange_period) != 0:
            raise ValueError(
                f"checkpoint_every={checkpoint_every} must be a multiple of "
                f"exchange_period={exchange_period}: segments only compose exactly "
                "at exchange boundaries (the delta-message reference is the "
                "exchanged snapshot)"
            )
    elif ckpt_dir is not None:
        raise ValueError("ckpt_dir needs checkpoint_every= (snapshot cadence)")


def run_bsp_resilient(
    sub,
    program,
    init_val=None,
    *,
    max_supersteps: Optional[int] = None,
    inner_cap: int = 10_000,
    exchange_period: int = 1,
    tol: float = 0.0,
    num_vertices: int = 0,
    source=None,
    compute_backend: str = "xla",
    driver: str = "fused",
    block_e: int = 512,
    checkpoint_every: Optional[int] = None,
    ckpt_dir=None,
    fault_plan: Optional[FaultPlan] = None,
):
    """`engine.run_bsp` with superstep checkpointing and deterministic
    fault injection — same (values, BSPStats) contract, bit-identical
    results (the non-checkpointed path IS run_bsp; this one runs the same
    loop in composable segments). Raises `WorkerCrashError` when the
    fault plan's crash comes due; `resume_bsp` continues from the last
    checkpoint in `ckpt_dir`."""
    prog = engine.get_program(program)
    engine.check_int32_kernel_labels(prog, sub, compute_backend)
    engine.check_pagerank_num_vertices(prog, num_vertices)
    engine.check_driver(driver)
    _check_ft_args(checkpoint_every, ckpt_dir, exchange_period)
    if max_supersteps is None:
        max_supersteps = prog.default_steps or 200
    if exchange_period > 1 and (prog.local != "fixpoint" or prog.convergence != "no_change"):
        raise ValueError(
            f"exchange_period>1 (bounded staleness) needs a fixpoint/no-change program; "
            f"{prog.name!r} is local={prog.local!r}, convergence={prog.convergence!r}"
        )
    if init_val is None:
        init_val = prog.init(sub, num_vertices=num_vertices, source=source)
    exec_prog, negate = engine._exec_view(prog)
    val = -init_val if negate else init_val
    # Same two-level value boundary as run_bsp: encode before the first
    # segment so every checkpoint holds kernel-ready (encoded) values.
    val, codec = engine._kernel_value_boundary(prog, sub, jnp.asarray(val), compute_backend)
    state = _SegState(
        val=np.asarray(val), done=0, msgs=[], iters=[], converged=False, codec=codec
    )
    if checkpoint_every and ckpt_dir is not None:
        _write_meta(ckpt_dir, sub, prog, {
            "driver": driver, "compute_backend": compute_backend,
            "max_supersteps": int(max_supersteps), "inner_cap": int(inner_cap),
            "exchange_period": int(exchange_period), "tol": float(tol),
            "num_vertices": int(num_vertices), "checkpoint_every": int(checkpoint_every),
            "block_e": int(block_e),
        })
    return _run_segments(
        sub, exec_prog, negate, state, max_supersteps=max_supersteps,
        inner_cap=inner_cap, exchange_period=exchange_period, tol=tol,
        num_vertices=num_vertices, compute_backend=compute_backend, driver=driver,
        checkpoint_every=checkpoint_every, ckpt_dir=ckpt_dir, fault_plan=fault_plan,
        block_e=block_e,
    )


def resume_bsp(
    sub,
    *,
    ckpt_dir,
    driver: Optional[str] = None,
    compute_backend: Optional[str] = None,
    fault_plan: Optional[FaultPlan] = None,
):
    """Restore the latest checkpoint in `ckpt_dir` and run the BSP loop to
    completion. Returns (values, BSPStats) bit-identical to the
    uninterrupted run — including the stats of the supersteps that ran
    BEFORE the crash (they are part of the snapshot).

    `driver` / `compute_backend` default to the crashed run's but may be
    overridden (driver/backend parity makes that answer-preserving —
    e.g. resume on the host driver after a fused-path crash)."""
    d = Path(ckpt_dir)
    meta_path = d / RESUME_META
    if not meta_path.exists():
        raise FileNotFoundError(
            f"no {RESUME_META} in {d} — was this run started with checkpoint_every=/ckpt_dir=?"
        )
    meta = json.loads(meta_path.read_text())
    prog = engine.get_program(meta["program"])
    backend = meta["compute_backend"] if compute_backend is None else compute_backend
    engine.check_int32_kernel_labels(prog, sub, backend)
    drv = engine.check_driver(meta["driver"] if driver is None else driver)
    fp = _sub_fingerprint(sub)
    if fp != meta["sub"]:
        raise ValueError(
            f"checkpoint in {d} was written for a different build: "
            f"checkpoint {meta['sub']} vs this SubgraphSet {fp}"
        )
    step = ckpt.latest_step(d)
    if step is None:
        raise FileNotFoundError(f"no published checkpoint under {d}")
    exec_prog, negate = engine._exec_view(prog)
    p = sub.gid.shape[0]
    dt = np.int32 if prog.dtype == "int32" else np.float32
    like = {
        "val": np.zeros((0,), dt),
        "msgs": np.zeros((0, 0), np.int64),
        "iters": np.zeros((0, 0), np.int64),
        "converged": np.int32(0),
        "codec_uniq": np.zeros((0,), np.int32),
    }
    tree = ckpt.restore(d, step, like)
    uniq = np.asarray(tree["codec_uniq"])
    codec = engine._ValueCodec(uniq=tuple(int(x) for x in uniq)) if uniq.size else None
    state = _SegState(
        val=np.asarray(tree["val"]),
        done=int(step),
        msgs=[np.asarray(tree["msgs"], np.int64)],
        iters=[np.asarray(tree["iters"], np.int64)],
        converged=bool(int(tree["converged"])),
        codec=codec,
    )
    if state.val.shape[0] != p:
        raise ValueError(
            f"checkpoint value carry has {state.val.shape[0]} workers, build has {p}"
        )
    if (
        codec is None
        and backend != "xla"
        and prog.dtype == "int32"
        and sub.addressing == "two_level"
    ):
        # No codec rode along (BFS-style unit-weight carries raw hop counts):
        # re-check the restored carry at the value boundary before resuming
        # onto an f32 kernel backend.
        mag = np.abs(state.val.astype(np.int64))
        finite = mag != int(engine.INF_I32)
        bound = int(mag[finite].max()) if finite.any() else 0
        if prog.weight == "unit":
            bound += int(np.asarray(sub.is_master).sum())
        engine.check_int32_kernel_values(prog, bound, backend)
    return _run_segments(
        sub, exec_prog, negate, state,
        max_supersteps=int(meta["max_supersteps"]), inner_cap=int(meta["inner_cap"]),
        exchange_period=int(meta["exchange_period"]), tol=float(meta["tol"]),
        num_vertices=int(meta["num_vertices"]), compute_backend=backend, driver=drv,
        checkpoint_every=int(meta["checkpoint_every"]), ckpt_dir=d, fault_plan=fault_plan,
        block_e=int(meta.get("block_e", 512)),
    )
