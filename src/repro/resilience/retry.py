"""Bounded retry with deterministic backoff, and the circuit breaker
driving graceful backend degradation.

`RetryPolicy` is frozen and pure: the exponential backoff jitter is a
seeded draw keyed on (seed, token), so a replayed trace charges the
exact same waits to the virtual clock. `CircuitBreaker` is the one
deliberately stateful piece: it counts consecutive failures per serving
process and walks a degradation ladder

    level 0: (compute_backend, batched fused executable)   — fastest
    level 1: ("xla",           batched fused executable)   — kernel-free
    level 2: ("xla",           per-query host driver)      — simplest

(level 1 is skipped when the server already runs xla). Every level
computes bit-identical results — the repo's driver/backend parity suites
pin fused≡host, batch≡singles, and xla≡ref≡pallas — so degradation
trades latency, never answers. Transitions are logged on
`repro.resilience` and recorded on `.transitions` for reports; recovery
is probe-based: after `probe_after` consecutive successes at a degraded
level the next batch probes one level up, and a probe success promotes.
"""
from __future__ import annotations

import dataclasses
import logging

from repro.resilience.faults import FaultPlan

log = logging.getLogger("repro.resilience")


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff with deterministic jitter.

    delay(attempt) = base_delay_s * multiplier**attempt * (1 + jitter*u)
    where u is a pure [0,1) draw keyed on (seed, token) — replayable."""

    max_retries: int = 3
    base_delay_s: float = 0.002
    multiplier: float = 2.0
    jitter: float = 0.1

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError(f"RetryPolicy.max_retries must be >= 0, got {self.max_retries!r}")
        if self.base_delay_s < 0:
            raise ValueError(f"RetryPolicy.base_delay_s must be >= 0, got {self.base_delay_s!r}")
        if self.multiplier < 1.0:
            raise ValueError(f"RetryPolicy.multiplier must be >= 1, got {self.multiplier!r}")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError(f"RetryPolicy.jitter must be in [0, 1], got {self.jitter!r}")

    def backoff_s(self, attempt: int, *, seed: int = 0, token: int = 0) -> float:
        """Seconds to wait before retry number `attempt` (0-based).
        `token` disambiguates concurrent backoff series under one seed
        (the server passes its global attempt counter)."""
        u = FaultPlan(seed=seed).draw("backoff", token)
        return float(self.base_delay_s * (self.multiplier ** int(attempt)) * (1.0 + self.jitter * u))


class CircuitBreaker:
    """Consecutive-failure breaker over a fixed degradation ladder.

    `level` indexes the ladder (0 = full speed). `threshold` consecutive
    failures degrade one level; `probe_after` consecutive successes at a
    degraded level arm a probe of the level above, and a probe success
    promotes back up (a probe failure stays put without re-degrading)."""

    def __init__(self, *, threshold: int = 3, max_level: int = 1, probe_after: int = 2):
        if threshold < 1:
            raise ValueError(f"CircuitBreaker.threshold must be >= 1, got {threshold!r}")
        if max_level < 0:
            raise ValueError(f"CircuitBreaker.max_level must be >= 0, got {max_level!r}")
        if probe_after < 1:
            raise ValueError(f"CircuitBreaker.probe_after must be >= 1, got {probe_after!r}")
        self.threshold = int(threshold)
        self.max_level = int(max_level)
        self.probe_after = int(probe_after)
        self.level = 0
        self.transitions: list[tuple[str, int, int]] = []  # (kind, from, to)
        self._failures = 0
        self._successes = 0

    def should_probe(self) -> bool:
        """Whether the next execution should probe one level up."""
        return self.level > 0 and self._successes >= self.probe_after

    def record_failure(self, *, probe: bool = False) -> None:
        self._successes = 0
        if probe:
            # A failed probe proves the upper level is still broken; the
            # current level keeps working, so don't degrade further.
            log.info("circuit breaker: probe of level %d failed, staying at %d",
                     self.level - 1, self.level)
            return
        self._failures += 1
        if self._failures >= self.threshold and self.level < self.max_level:
            old = self.level
            self.level += 1
            self._failures = 0
            self.transitions.append(("degrade", old, self.level))
            log.warning(
                "circuit breaker: %d consecutive failures, degrading level %d -> %d",
                self.threshold, old, self.level,
            )

    def record_success(self, *, probe: bool = False) -> None:
        self._failures = 0
        if probe and self.level > 0:
            old = self.level
            self.level -= 1
            self._successes = 0
            self.transitions.append(("recover", old, self.level))
            log.info("circuit breaker: probe succeeded, recovering level %d -> %d",
                     old, self.level)
        else:
            self._successes += 1
