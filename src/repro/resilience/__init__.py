"""repro.resilience — deterministic fault injection, superstep
checkpoint/resume, and the retry/backoff/circuit-breaker vocabulary the
serving tier degrades with.

Three layers (docs/api.md "Fault tolerance"):

  * `FaultPlan` — a seeded, frozen chaos schedule (worker crash at
    superstep s, transient backend errors, stragglers, malformed
    batches); every draw is a pure function of (seed, stream, index) so
    scenarios replay bit-for-bit.
  * `run_bsp_resilient` / `resume_bsp` — segmented BSP execution that
    snapshots the value carry + stats buffers through
    `repro.checkpoint.ckpt` and recovers from an injected crash to a
    final state bit-identical to an uninterrupted run. Reached from
    `run_bsp(..., checkpoint_every=k, ckpt_dir=...)` and therefore from
    `GraphPipeline.run`.
  * `RetryPolicy` / `CircuitBreaker` — bounded retry with deterministic
    backoff jitter and consecutive-failure degradation
    (pallas -> xla compute, fused batch -> host driver) wired into
    `GraphQueryServer`.
"""
from repro.resilience.bsp import resume_bsp, run_bsp_resilient
from repro.resilience.faults import (
    FaultError,
    FaultPlan,
    LoadShedError,
    MalformedBatchError,
    TransientBackendError,
    WorkerCrashError,
)
from repro.resilience.retry import CircuitBreaker, RetryPolicy

__all__ = [
    "CircuitBreaker",
    "FaultError",
    "FaultPlan",
    "LoadShedError",
    "MalformedBatchError",
    "RetryPolicy",
    "TransientBackendError",
    "WorkerCrashError",
    "resume_bsp",
    "run_bsp_resilient",
]
