"""Deterministic fault injection: a seeded `FaultPlan` plus the named
error vocabulary the fault-tolerant drivers raise.

Failure is a first-class, replayable INPUT here, not an accident: every
fault draw is a pure function of (seed, stream name, draw index), so a
chaos scenario replays bit-for-bit from its seed — no wall-clock or
process-state nondeterminism (clocks come from the serving tier's
explicit virtual time). Stream names are hashed with crc32, NOT Python's
`hash()` (which is salted by PYTHONHASHSEED and would break replay
across processes).

The plan vocabulary (docs/api.md "Fault tolerance"):

  * crash_at_superstep s  — the BSP run dies when about to execute
    superstep s (0-based: exactly s supersteps complete first), raising
    `WorkerCrashError`. Recovery is `resume_bsp` from the last
    checkpoint.
  * transient_error_prob q — an execution attempt in the serving tier
    fails with `TransientBackendError` with probability q, optionally
    targeted at one compute backend / driver path (so degradation to
    another level genuinely clears the fault). `max_transient_faults`
    bounds the total injected count — the deterministic way to script
    "fail twice, then succeed".
  * straggler_delay_s / straggler_prob — a micro-batch is charged an
    extra latency before executing (results unchanged; only time moves).
  * malformed_batch_prob — a micro-batch arrives corrupted and must be
    re-formed (`MalformedBatchError`, retryable).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Optional

import numpy as np


class FaultError(RuntimeError):
    """Base of every injected/named fault raised by repro.resilience."""


class WorkerCrashError(FaultError):
    """A BSP worker died; `superstep` counts the supersteps that
    completed before the crash. `ckpt_dir` (when checkpointing was on)
    names where `resume_bsp` can pick the run back up."""

    def __init__(self, superstep: int, ckpt_dir=None):
        self.superstep = int(superstep)
        self.ckpt_dir = ckpt_dir
        where = f" (resume from {ckpt_dir})" if ckpt_dir is not None else ""
        super().__init__(
            f"worker crashed after completing superstep {superstep}{where}"
        )


class TransientBackendError(FaultError):
    """A retryable backend failure (the injected stand-in for a flaky
    device, a preempted worker, or a lost RPC)."""


class MalformedBatchError(FaultError):
    """A message micro-batch arrived corrupted; re-forming it (a retry)
    clears the fault."""


class LoadShedError(FaultError):
    """Admission rejected a query: the bounded queue is full
    (reject-newest policy)."""


def _stream_entropy(stream: str) -> int:
    # crc32, not hash(): PYTHONHASHSEED salts str hashing per process,
    # which would make "deterministic" fault schedules unreplayable.
    return zlib.crc32(stream.encode("utf-8"))


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Frozen, seeded chaos schedule. Every method is a pure function of
    (seed, stream, index) — calling it twice with the same arguments
    returns the same answer, and two plans with the same seed are the
    same plan."""

    seed: int = 0
    crash_at_superstep: Optional[int] = None
    transient_error_prob: float = 0.0
    max_transient_faults: Optional[int] = None
    transient_target_backend: Optional[str] = None
    transient_target_driver: Optional[str] = None  # "batch" | "host"
    straggler_prob: float = 0.0
    straggler_delay_s: float = 0.0
    malformed_batch_prob: float = 0.0

    def __post_init__(self):
        for name in ("transient_error_prob", "straggler_prob", "malformed_batch_prob"):
            v = getattr(self, name)
            if not 0.0 <= float(v) <= 1.0:
                raise ValueError(f"FaultPlan.{name} must be in [0, 1], got {v!r}")
        if self.crash_at_superstep is not None and int(self.crash_at_superstep) < 0:
            raise ValueError(
                f"FaultPlan.crash_at_superstep must be >= 0, got {self.crash_at_superstep!r}"
            )
        if self.straggler_delay_s < 0:
            raise ValueError(
                f"FaultPlan.straggler_delay_s must be >= 0, got {self.straggler_delay_s!r}"
            )
        if self.max_transient_faults is not None and int(self.max_transient_faults) < 0:
            raise ValueError(
                f"FaultPlan.max_transient_faults must be >= 0, got {self.max_transient_faults!r}"
            )

    # ------------------------------------------------------------- draws

    def draw(self, stream: str, index: int) -> float:
        """Uniform [0, 1) draw `index` of `stream` — pure and replayable."""
        ss = np.random.SeedSequence((int(self.seed), _stream_entropy(stream), int(index)))
        return float(np.random.default_rng(ss).random())

    # ---------------------------------------------------------- schedule

    def should_crash(self, superstep: int) -> bool:
        """True when the run is about to execute the doomed superstep
        (i.e. `superstep` supersteps have already completed)."""
        return self.crash_at_superstep is not None and int(superstep) >= int(
            self.crash_at_superstep
        )

    def transient_fault(
        self, attempt: int, *, backend: Optional[str] = None, driver: Optional[str] = None
    ) -> bool:
        """Whether execution attempt `attempt` (a global counter the
        caller advances per attempt) fails with a transient error. A
        targeted plan only faults the named compute backend / driver
        path, so degrading away from the target genuinely recovers."""
        if self.transient_error_prob <= 0.0:
            return False
        if self.transient_target_backend is not None and backend != self.transient_target_backend:
            return False
        if self.transient_target_driver is not None and driver != self.transient_target_driver:
            return False
        if self.max_transient_faults is not None:
            # Count prior faults of this stream deterministically: the
            # draws are pure, so replaying them IS the fault ledger.
            fired = sum(
                1 for i in range(int(attempt))
                if self.draw("transient", i) < self.transient_error_prob
            )
            if fired >= int(self.max_transient_faults):
                return False
        return self.draw("transient", attempt) < self.transient_error_prob

    def malformed_batch(self, attempt: int) -> bool:
        if self.malformed_batch_prob <= 0.0:
            return False
        return self.draw("malformed", attempt) < self.malformed_batch_prob

    def straggler_delay(self, batch_index: int) -> float:
        """Extra seconds charged to the batch's clock (0.0 = no straggler)."""
        if self.straggler_prob <= 0.0 or self.straggler_delay_s <= 0.0:
            return 0.0
        if self.draw("straggler", batch_index) < self.straggler_prob:
            return float(self.straggler_delay_s)
        return 0.0
