"""Deterministic synthetic token pipeline (offline container: no corpora).

Produces a reproducible, shardable stream of (tokens, targets) with a
zipf-ish unigram distribution + a little n-gram structure so the LM loss
actually decreases during the example runs. Each global step's batch is a
pure function of (seed, step), so every data-parallel host can materialize
ITS OWN shard without coordination — and restart after preemption at any
step (fault tolerance: the pipeline has no state to checkpoint beyond the
step counter).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


def batch_at_step(cfg: DataConfig, step: int) -> dict:
    """Full global batch for `step` (tests / single host)."""
    return shard_batch_at_step(cfg, step, 0, 1)


def shard_batch_at_step(cfg: DataConfig, step: int, shard: int, num_shards: int) -> dict:
    """This host's slice of the global batch — pure function of inputs."""
    assert cfg.global_batch % num_shards == 0
    b = cfg.global_batch // num_shards
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    key = jax.random.fold_in(key, shard)
    k1, k2 = jax.random.split(key)
    # zipf-ish unigram: sample exponent-distributed ids.
    u = jax.random.uniform(k1, (b, cfg.seq_len + 1), minval=1e-6, maxval=1.0)
    ids = jnp.floor(cfg.vocab * u ** 3.0).astype(jnp.int32)
    # n-gram structure: every other token repeats its predecessor + 1.
    rep = jax.random.bernoulli(k2, 0.3, ids.shape)
    shifted = jnp.roll(ids, 1, axis=1) + 1
    ids = jnp.where(rep, jnp.clip(shifted, 0, cfg.vocab - 1), ids)
    return dict(tokens=ids[:, :-1], targets=ids[:, 1:])
