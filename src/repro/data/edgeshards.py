"""On-disk sharded edge store for out-of-core graph pipelines.

The paper's target graphs (LiveJournal/Twitter/Friendster, Table IV) do
not fit an in-memory int64 edge list on one host. This module is the
disk format + external passes that let generation, degree computation,
the §IV-C degree-sum ordering, and the streaming partitioner all run
shard by shard, never materializing more than O(shard) edges:

  - `EdgeShardStore` / `ShardWriter`: fixed-size int64 chunk files
    (`shard-NNNNN.bin`, raw little-endian [n, 2] (src, dst) pairs) plus a
    JSON manifest carrying per-shard edge counts and log2-bucketed
    degree histograms (`manifest.json`, format "edgeshards-v1").
  - `rmat_to_store`: shard-by-shard R-MAT writer — candidate edges are
    drawn chunk-major through the same bit-plane core as
    `repro.graph.generate.rmat`, deduplicated exactly with an external
    key-bucket pass, and streamed into shards in global key order.
  - `degrees_from_shards`: exact global total degrees in one pass.
  - `degree_sum_stream`: the §IV-C degree-sum edge order as an external
    sort — per-shard bucket sort into ascending key-range bucket files,
    then a k-way merge of the per-shard sorted runs inside each bucket.
    The emitted permutation is BIT-IDENTICAL to the in-memory
    `repro.core.order.degree_sum_order` (stable sort ≡ ascending
    disjoint buckets + stable within-bucket merge in stream order),
    which is what makes `out_of_core ≡ in_memory` partition parity exact
    rather than approximate.

Memory budget per pass (V vertices, E edges, shard size S):
  generation   O(chunk + E/num_buckets)   (candidate chunk + one dedup bucket)
  degrees      O(V)                        (one int64 degree array)
  order        O(V + bucket_edges)         (degrees + one bucket in flight)
  partition    O(V·p/32 + block)           (bitset state, see core.outofcore)
"""
from __future__ import annotations

import dataclasses
import json
import math
from pathlib import Path
from typing import Iterator, Optional

import numpy as np

from repro.core.types import Graph
from repro.graph.generate import _rmat_bitplane

MANIFEST_NAME = "manifest.json"
FORMAT_NAME = "edgeshards-v1"
_PAIR_DTYPE = np.dtype("<i8")  # on-disk: little-endian int64 (src, dst) pairs


def _degree_hist(src: np.ndarray, dst: np.ndarray) -> list[int]:
    """log2-bucketed histogram of within-shard endpoint multiplicities:
    hist[k] = #vertices whose incidence count inside this shard lies in
    [2^k, 2^(k+1)). Cheap per-shard skew fingerprint for the manifest."""
    if src.size == 0:
        return []
    _, cnt = np.unique(np.concatenate([src, dst]), return_counts=True)
    buckets = np.bincount(np.log2(cnt).astype(np.int64))
    return [int(x) for x in buckets]


def _validate_ids(src: np.ndarray, dst: np.ndarray, num_vertices: int) -> None:
    for name, arr in (("src", src), ("dst", dst)):
        if arr.size and (int(arr.min()) < 0 or int(arr.max()) >= num_vertices):
            bad = int(arr.min()) if int(arr.min()) < 0 else int(arr.max())
            raise ValueError(
                f"{name} has vertex id {bad} outside [0, num_vertices={num_vertices})"
            )


class ShardWriter:
    """Buffered writer for an edge-shard directory.

    Appends int64 (src, dst) edge arrays; full shards of `shard_edges`
    edges are flushed to disk as they fill, so the writer holds at most
    one shard of edges. `close()` writes the manifest and returns the
    opened `EdgeShardStore`. Usable as a context manager.
    """

    def __init__(self, path, num_vertices: int, *, shard_edges: int = 1 << 20):
        if shard_edges < 1:
            raise ValueError(f"shard_edges must be >= 1, got {shard_edges}")
        self.path = Path(path)
        self.path.mkdir(parents=True, exist_ok=True)
        self.num_vertices = int(num_vertices)
        self.shard_edges = int(shard_edges)
        self._buf_src: list[np.ndarray] = []
        self._buf_dst: list[np.ndarray] = []
        self._buffered = 0
        self._shards: list[dict] = []
        self._closed = False

    def __enter__(self) -> "ShardWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.close()

    def append(self, src: np.ndarray, dst: np.ndarray) -> None:
        src = np.asarray(src, dtype=np.int64).ravel()
        dst = np.asarray(dst, dtype=np.int64).ravel()
        if src.shape != dst.shape:
            raise ValueError(f"src/dst shapes differ: {src.shape} vs {dst.shape}")
        _validate_ids(src, dst, self.num_vertices)
        self._buf_src.append(src)
        self._buf_dst.append(dst)
        self._buffered += src.size
        while self._buffered >= self.shard_edges:
            self._flush_one()

    def _take(self, n: int) -> tuple[np.ndarray, np.ndarray]:
        src = np.concatenate(self._buf_src) if self._buf_src else np.zeros(0, np.int64)
        dst = np.concatenate(self._buf_dst) if self._buf_dst else np.zeros(0, np.int64)
        self._buf_src, self._buf_dst = [src[n:]], [dst[n:]]
        self._buffered = src.size - min(n, src.size)
        return src[:n], dst[:n]

    def _flush_one(self) -> None:
        n = min(self.shard_edges, self._buffered)
        if n == 0:
            return
        src, dst = self._take(n)
        idx = len(self._shards)
        fname = f"shard-{idx:05d}.bin"
        pairs = np.empty((n, 2), dtype=_PAIR_DTYPE)
        pairs[:, 0] = src
        pairs[:, 1] = dst
        pairs.tofile(self.path / fname)
        self._shards.append({
            "file": fname,
            "num_edges": int(n),
            "degree_hist": _degree_hist(src, dst),
        })

    def close(self) -> "EdgeShardStore":
        if self._closed:
            return EdgeShardStore.open(self.path)
        while self._buffered > 0:
            self._flush_one()
        manifest = {
            "format": FORMAT_NAME,
            "num_vertices": self.num_vertices,
            "num_edges": int(sum(s["num_edges"] for s in self._shards)),
            "shard_edges": self.shard_edges,
            "dtype": "int64",
            "shards": self._shards,
        }
        (self.path / MANIFEST_NAME).write_text(json.dumps(manifest, indent=1))
        self._closed = True
        return EdgeShardStore.open(self.path)


@dataclasses.dataclass(frozen=True)
class EdgeShardStore:
    """Read view of an edge-shard directory (see module docstring)."""

    path: Path
    num_vertices: int
    num_edges: int
    shard_edges: int
    shards: tuple[dict, ...]

    @classmethod
    def open(cls, path) -> "EdgeShardStore":
        path = Path(path)
        mpath = path / MANIFEST_NAME
        if not mpath.exists():
            raise FileNotFoundError(f"no {MANIFEST_NAME} in {path} — not an edge-shard store")
        m = json.loads(mpath.read_text())
        if m.get("format") != FORMAT_NAME:
            raise ValueError(f"unsupported edge-shard format {m.get('format')!r} in {mpath}")
        return cls(
            path=path,
            num_vertices=int(m["num_vertices"]),
            num_edges=int(m["num_edges"]),
            shard_edges=int(m["shard_edges"]),
            shards=tuple(m["shards"]),
        )

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    def read_shard(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        meta = self.shards[i]
        pairs = np.fromfile(self.path / meta["file"], dtype=_PAIR_DTYPE)
        pairs = pairs.reshape(-1, 2)
        if pairs.shape[0] != meta["num_edges"]:
            raise ValueError(
                f"shard {meta['file']} holds {pairs.shape[0]} edges, manifest says "
                f"{meta['num_edges']}"
            )
        return pairs[:, 0].astype(np.int64), pairs[:, 1].astype(np.int64)

    def iter_shards(self) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        for i in range(self.num_shards):
            yield self.read_shard(i)

    def iter_blocks(self, block: int) -> Iterator[tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Fixed-size (src, dst, orig_idx) blocks across shard boundaries,
        in store order; the final block may be short. orig_idx is the
        edge's global position in the store stream."""
        if block < 1:
            raise ValueError(f"block must be >= 1, got {block}")
        carry_s: list[np.ndarray] = []
        carry_d: list[np.ndarray] = []
        held = 0
        base = 0
        for src, dst in self.iter_shards():
            carry_s.append(src)
            carry_d.append(dst)
            held += src.size
            while held >= block:
                s = np.concatenate(carry_s)
                d = np.concatenate(carry_d)
                yield s[:block], d[:block], np.arange(base, base + block, dtype=np.int64)
                base += block
                carry_s, carry_d = [s[block:]], [d[block:]]
                held = s.size - block
        if held:
            s = np.concatenate(carry_s)
            d = np.concatenate(carry_d)
            yield s, d, np.arange(base, base + held, dtype=np.int64)


def write_graph(graph: Graph, path, *, shard_edges: int = 1 << 20) -> EdgeShardStore:
    """Shard an in-memory Graph out to disk (tests + small-graph twins)."""
    with ShardWriter(path, graph.num_vertices, shard_edges=shard_edges) as w:
        w.append(np.asarray(graph.src, np.int64), np.asarray(graph.dst, np.int64))
    return EdgeShardStore.open(path)


def load_graph(store: EdgeShardStore) -> Graph:
    """Materialize a store into an in-memory Graph (downscaled twins and
    parity oracles only — this is exactly the allocation the out-of-core
    pipeline exists to avoid)."""
    srcs, dsts = [], []
    for s, d in store.iter_shards():
        srcs.append(s)
        dsts.append(d)
    src = np.concatenate(srcs) if srcs else np.zeros(0, np.int64)
    dst = np.concatenate(dsts) if dsts else np.zeros(0, np.int64)
    if store.num_vertices <= np.iinfo(np.int32).max:
        src, dst = src.astype(np.int32), dst.astype(np.int32)
    return Graph(src=src, dst=dst, num_vertices=store.num_vertices)


def degrees_from_shards(store: EdgeShardStore) -> np.ndarray:
    """Exact global total (in+out) degrees in one streaming pass; int64
    [V]. Matches `Graph.degrees()` of the materialized store bit-for-bit."""
    deg = np.zeros(store.num_vertices, np.int64)
    for src, dst in store.iter_shards():
        deg += np.bincount(src, minlength=store.num_vertices)
        deg += np.bincount(dst, minlength=store.num_vertices)
    return deg


# ------------------------------------------------- shard-by-shard R-MAT


def _rmat_candidate_chunk(rng, n: int, scale: int, a: float, b: float, c: float):
    """n candidate edges, drawing (n, scale) uniforms chunk-major."""
    src = np.zeros(n, dtype=np.int64)
    dst = np.zeros(n, dtype=np.int64)
    r = rng.random((scale, n))
    for lvl in range(scale):
        src, dst = _rmat_bitplane(src, dst, r[lvl], a, b, c)
    return src, dst


def _bucket_thin(counts: list[int], target: int) -> list[int]:
    """Per-bucket keep counts summing exactly to `target`, proportional to
    bucket sizes (largest-remainder rounding) — deterministic thinning
    spread across the whole key space instead of truncating a tail."""
    total = sum(counts)
    if target >= total:
        return list(counts)
    exact = [ct * target / total for ct in counts]
    keep = [min(int(math.floor(x)), ct) for x, ct in zip(exact, counts)]
    rem = target - sum(keep)
    frac = sorted(
        range(len(counts)), key=lambda i: (exact[i] - math.floor(exact[i]), -i), reverse=True
    )
    for i in frac:
        if rem == 0:
            break
        if keep[i] < counts[i]:
            keep[i] += 1
            rem -= 1
    return keep


def rmat_to_store(
    path,
    num_vertices: int,
    num_edges: int,
    *,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
    shard_edges: int = 1 << 20,
    chunk: int = 1 << 20,
    oversample: float = 1.15,
    workdir=None,
) -> EdgeShardStore:
    """Shard-by-shard R-MAT writer: generation never holds the full edge
    list. Candidates are drawn in `chunk`-sized batches through the same
    bit-plane core as the in-memory generator, self-loops stripped and
    exact global dedup done externally: candidate keys (src·V + dst) are
    range-partitioned by src high bits into bucket files, each bucket is
    uniq'ed independently, and buckets are emitted in ascending key order
    — the same global key-sorted edge order `generate._finalize` produces.
    When dedup leaves more than `num_edges` edges, a deterministic
    proportional thinning (evenly spaced within each bucket) trims to the
    requested count. Peak memory is O(chunk + max bucket size).
    """
    if num_vertices & (num_vertices - 1) != 0:
        raise ValueError("num_vertices must be a power of 2")
    scale = int(np.log2(num_vertices))
    rng = np.random.default_rng(seed)
    n_cand = int(num_edges * oversample)
    work = Path(workdir) if workdir is not None else Path(path) / "_rmat_work"
    work.mkdir(parents=True, exist_ok=True)

    # Bucket by src high bits so bucket id is monotone in key = src*V + dst.
    n_buckets = max(1, 1 << max(0, int(np.ceil(np.log2(max(1, n_cand / (1 << 22)))))))
    n_buckets = min(n_buckets, num_vertices)
    shift = scale - int(np.log2(n_buckets))
    files = [open(work / f"bucket-{i:05d}.keys", "wb") for i in range(n_buckets)]
    try:
        left = n_cand
        while left > 0:
            m = min(chunk, left)
            left -= m
            src, dst = _rmat_candidate_chunk(rng, m, scale, a, b, c)
            keep = src != dst
            src, dst = src[keep], dst[keep]
            key = src * np.int64(num_vertices) + dst
            bucket = (src >> shift).astype(np.int64)
            o = np.argsort(bucket, kind="stable")
            key, bucket = key[o], bucket[o]
            bounds = np.searchsorted(bucket, np.arange(n_buckets + 1))
            for i in range(n_buckets):
                lo, hi = bounds[i], bounds[i + 1]
                if hi > lo:
                    key[lo:hi].astype(_PAIR_DTYPE).tofile(files[i])
    finally:
        for f in files:
            f.close()

    # Per-bucket exact dedup; ascending buckets = global key order.
    uniq_counts = []
    for i in range(n_buckets):
        keys = np.fromfile(work / f"bucket-{i:05d}.keys", dtype=_PAIR_DTYPE)
        keys = np.unique(keys)
        keys.astype(_PAIR_DTYPE).tofile(work / f"bucket-{i:05d}.keys")
        uniq_counts.append(int(keys.size))
    keep_counts = _bucket_thin(uniq_counts, num_edges)

    writer = ShardWriter(path, num_vertices, shard_edges=shard_edges)
    for i in range(n_buckets):
        bpath = work / f"bucket-{i:05d}.keys"
        keys = np.fromfile(bpath, dtype=_PAIR_DTYPE)
        if keep_counts[i] < keys.size:
            sel = np.linspace(0, keys.size - 1, keep_counts[i]).astype(np.int64)
            keys = keys[sel]
        writer.append(keys // num_vertices, keys % num_vertices)
        bpath.unlink()
    store = writer.close()
    return store


# ------------------------------------------- external degree-sum ordering


@dataclasses.dataclass(frozen=True)
class OrderedEdgeStream:
    """Re-iterable §IV-C degree-sum-ordered edge stream backed by bucket
    files on disk: ascending disjoint key-range buckets, each holding its
    per-shard sorted runs, merged stably on iteration. The emitted
    permutation equals `np.argsort(deg[src]+deg[dst], kind="stable")` over
    the store stream bit-for-bit: a stable sort orders by (key, original
    position), and ascending buckets + stable within-bucket merge in
    stream order produce exactly that order."""

    workdir: Path
    store: EdgeShardStore
    degrees: np.ndarray  # int64 [V] exact global total degrees
    num_buckets: int
    bucket_counts: tuple[int, ...]

    @property
    def num_edges(self) -> int:
        return self.store.num_edges

    @property
    def num_vertices(self) -> int:
        return self.store.num_vertices

    def _read_bucket(self, i: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Bucket i's edges in final (degree-sum, stream-position) order:
        the k per-shard sorted runs are concatenated in shard order and
        merged with ONE stable key sort — equal keys keep run order, and
        run order IS ascending original position."""
        tri = np.fromfile(self.workdir / f"bucket-{i:05d}.bin", dtype=_PAIR_DTYPE)
        tri = tri.reshape(-1, 3)
        src, dst, idx = tri[:, 0], tri[:, 1], tri[:, 2]
        key = self.degrees[src] + self.degrees[dst]
        o = np.argsort(key, kind="stable")
        return src[o], dst[o], idx[o]

    def iter_blocks(self, block: int) -> Iterator[tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """(src, dst, orig_idx) blocks of the ordered stream; the final
        block may be short. Holds at most one bucket plus one block."""
        carry: list[np.ndarray] = []
        held = 0
        for i in range(self.num_buckets):
            if self.bucket_counts[i] == 0:
                continue
            tri = np.stack(self._read_bucket(i), axis=1)
            carry.append(tri)
            held += tri.shape[0]
            while held >= block:
                t = np.concatenate(carry, axis=0)
                yield t[:block, 0], t[:block, 1], t[:block, 2]
                carry = [t[block:]]
                held = t.shape[0] - block
        if held:
            t = np.concatenate(carry, axis=0)
            yield t[:, 0], t[:, 1], t[:, 2]

    def permutation(self) -> np.ndarray:
        """Materialize the full order (int64 [E]) — parity tests only."""
        parts = [idx for _, _, idx in self.iter_blocks(1 << 20)]
        return np.concatenate(parts) if parts else np.zeros(0, np.int64)

    def cleanup(self) -> None:
        for i in range(self.num_buckets):
            f = self.workdir / f"bucket-{i:05d}.bin"
            if f.exists():
                f.unlink()


def degree_sum_stream(
    store: EdgeShardStore,
    degrees: Optional[np.ndarray] = None,
    *,
    workdir=None,
    bucket_edges: int = 1 << 22,
) -> OrderedEdgeStream:
    """External §IV-C degree-sum sort (see `OrderedEdgeStream`). Two
    passes over the store:

      1. an exact coarse histogram of degree-sum keys (keys quantized by a
         power-of-two shift so the histogram stays <= 2^22 bins) picks
         ascending key-range boundaries with <= `bucket_edges` edges per
         bucket (a single over-full quantized key keeps its own bucket);
      2. every shard is bucket-sorted: its edges are appended to the
         matching bucket files as (src, dst, stream-position) triples, in
         stream order — each bucket then holds per-shard sorted runs.

    Iteration merges the runs bucket by bucket (see `_read_bucket`).
    """
    if degrees is None:
        degrees = degrees_from_shards(store)
    degrees = np.asarray(degrees, np.int64)
    work = Path(workdir) if workdir is not None else store.path / "_order_work"
    work.mkdir(parents=True, exist_ok=True)

    # Pass 1: exact histogram over quantized keys -> bucket boundaries.
    max_key = int(2 * degrees.max(initial=0))
    shift = max(0, int(max_key).bit_length() - 22)
    nbins = (max_key >> shift) + 2
    hist = np.zeros(nbins, np.int64)
    for src, dst in store.iter_shards():
        q = (degrees[src] + degrees[dst]) >> shift
        hist += np.bincount(q, minlength=nbins)
    bounds = [0]  # bucket i covers quantized keys [bounds[i], bounds[i+1])
    acc = 0
    for q in range(nbins):
        if acc and acc + int(hist[q]) > bucket_edges:
            bounds.append(q)
            acc = 0
        acc += int(hist[q])
    bounds.append(nbins)
    n_buckets = len(bounds) - 1
    upper = np.asarray(bounds[1:], np.int64)

    # Pass 2: per-shard bucket sort into (src, dst, orig_idx) triple files.
    files = [open(work / f"bucket-{i:05d}.bin", "wb") for i in range(n_buckets)]
    counts = [0] * n_buckets
    try:
        base = 0
        for src, dst in store.iter_shards():
            idx = np.arange(base, base + src.size, dtype=np.int64)
            base += src.size
            q = (degrees[src] + degrees[dst]) >> shift
            bucket = np.searchsorted(upper, q, side="right")
            o = np.argsort(bucket, kind="stable")  # keeps stream order per bucket
            tri = np.stack([src[o], dst[o], idx[o]], axis=1)
            edges = np.searchsorted(bucket[o], np.arange(n_buckets + 1))
            for i in range(n_buckets):
                lo, hi = edges[i], edges[i + 1]
                if hi > lo:
                    tri[lo:hi].astype(_PAIR_DTYPE).tofile(files[i])
                    counts[i] += int(hi - lo)
    finally:
        for f in files:
            f.close()
    return OrderedEdgeStream(
        workdir=work,
        store=store,
        degrees=degrees,
        num_buckets=n_buckets,
        bucket_counts=tuple(counts),
    )
