"""Fault-tolerant checkpointing: sharded zstd-compressed leaves, atomic
manifest, latest-step discovery, async save thread.

Layout:  <dir>/step_000123/
            manifest.json   {step, leaves: [{path, shape, dtype, file, codec}]}
            L00000.bin.zst  raw little-endian bytes per leaf (zstd), or
            L00000.bin      uncompressed when zstandard is not installed
A checkpoint only "exists" once manifest.json is renamed into place, so a
killed writer never corrupts restart (tests/test_checkpoint.py kills a
training loop mid-save and restarts bitwise-identically).

`zstandard` is an optional dependency (the `ckpt` extra): without it,
saves degrade to uncompressed leaves and restores of compressed
checkpoints raise with an install hint.
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path

import jax
import numpy as np

try:  # optional dep — degrade to uncompressed leaves when absent
    import zstandard
except ModuleNotFoundError:
    zstandard = None

_KEY_SEP = "|"


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = _KEY_SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append((key, leaf))
    return out, treedef


def save(ckpt_dir: str | Path, step: int, tree) -> Path:
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, _ = _flatten_with_paths(tree)
    cctx = zstandard.ZstdCompressor(level=3) if zstandard is not None else None
    manifest = {"step": step, "leaves": []}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        payload = arr.tobytes()
        if cctx is None:
            fn, codec = f"L{i:05d}.bin", "raw"
        else:
            fn, codec = f"L{i:05d}.bin.zst", "zstd"
            payload = cctx.compress(payload)
        (tmp / fn).write_bytes(payload)
        manifest["leaves"].append(
            dict(path=key, shape=list(arr.shape), dtype=str(arr.dtype), file=fn, codec=codec)
        )
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic publish
    return final


def latest_step(ckpt_dir: str | Path) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for p in ckpt_dir.iterdir():
        if p.name.startswith("step_") and (p / "manifest.json").exists():
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, step: int, like_tree):
    """Restore into the structure (and shardings) of `like_tree`."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    dctx = zstandard.ZstdDecompressor() if zstandard is not None else None
    by_path = {m["path"]: m for m in manifest["leaves"]}
    leaves, treedef = _flatten_with_paths(like_tree)
    out = []
    for key, like in leaves:
        m = by_path[key]
        raw = (d / m["file"]).read_bytes()
        # Pre-codec manifests only ever wrote zstd leaves.
        codec = m.get("codec", "zstd")
        if codec == "zstd":
            if dctx is None:
                raise ModuleNotFoundError(
                    f"checkpoint leaf {m['file']} is zstd-compressed but 'zstandard' "
                    "is not installed (pip install zstandard, or the 'ckpt' extra)"
                )
            raw = dctx.decompress(raw)
        elif codec != "raw":
            raise ValueError(f"unknown checkpoint codec {codec!r} for leaf {m['file']}")
        arr = np.frombuffer(raw, dtype=np.dtype(m["dtype"])).reshape(m["shape"])
        if hasattr(like, "sharding"):
            arr = jax.device_put(arr.astype(like.dtype), like.sharding)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, [v for v in out])


class AsyncCheckpointer:
    """Overlaps checkpoint I/O with the next training step.

    A failure on the writer thread (disk full, bad path, permission)
    is captured and re-raised on the NEXT `save()` or on `wait()` —
    a failed checkpoint must never be silently treated as durable, or
    a later crash would "resume" from a snapshot that does not exist.
    """

    def __init__(self, ckpt_dir: str | Path):
        self.ckpt_dir = Path(ckpt_dir)
        self._thread: threading.Thread | None = None
        self._exc: BaseException | None = None

    def _save_guarded(self, step: int, tree):
        try:
            save(self.ckpt_dir, step, tree)
        except BaseException as e:  # captured; re-raised on wait()/next save()
            self._exc = e

    def save(self, step: int, tree):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before async
        self._thread = threading.Thread(target=self._save_guarded, args=(step, host_tree))
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise RuntimeError(
                f"async checkpoint save to {self.ckpt_dir} failed"
            ) from exc
