"""Streaming vertex-cut partitioner core: a pluggable `EdgeScorer` over
ONE `lax.scan` driver and ONE chunked block-commit driver.

The paper's EBV algorithm (our `ebg`) is one member of a family of
streaming greedy edge partitioners — HDRF [Petroni et al., CIKM'15] and
PowerGraph Greedy [Gonzalez et al., OSDI'12] are the baselines its
headline table compares against — that share one sequential state machine
and differ ONLY in the per-edge score they minimize. The shared machine:

    state: keep[i] ⊆ V  (endpoint membership per subgraph, a p×V bitset)
           e_count[i], v_count[i]  (running balance counters)
    per edge (u, v):
        i* = argmin_i score(u, v, i, state)   (ties -> lowest subgraph id)
        e_count[i*] += 1; v_count[i*] += #endpoints new to keep[i*]
        keep[i*] |= {u, v}

`EdgeScorer` is the frozen description of the score:

    score(u,v,i) = wu·1[u∉keep[i]] + wv·1[v∉keep[i]]          (replication)
                 + ce · e_count[i] · norm_e                   (edge balance)
                 + cv · v_count[i] · (p/|V|)                  (vertex balance)

where (wu, wv) are per-edge degree weights (1 unless the scorer has a
degree term), and norm_e is either the static p/|E| (EBV) or the dynamic
HDRF range normalizer 1/(eps + max(e_count) − min(e_count)). Stock
instances:

| scorer   | wu, wv            | norm_e            | ce, cv        |
|----------|-------------------|-------------------|---------------|
| `ebv`    | 1, 1              | p/|E| (static)    | alpha, beta   |
| `hdrf`   | 2−θ(u), 2−θ(v)    | 1/(eps+max−min)   | lambda, 0     |
| `greedy` | 1, 1              | 1/(eps+max−min)   | 1, 0          |

θ(u) = d(u)/(d(u)+d(v)) is HDRF's normalized degree; we use exact total
degrees (the offline variant — the graph is in memory), so the weights
are a precomputed per-edge stream and the state machine stays identical
across scorers. HDRF's published argmax of g(u,i)+g(v,i)+bal(i) with
g(u,i) = (2−θ(u))·1[u∈A(i)] is equivalent, term by constant term, to the
argmin above; Greedy is HDRF with the degree term dropped.

Both drivers are scorer-generic: the faithful `lax.scan` (one edge per
step) and the blocked commit loop (scores for B edges evaluated against
block-start membership, balance committed exactly and sequentially inside
the block — block=1 is exactly the faithful algorithm). The chunked
driver's "ref"/"pallas" backends route whole blocks through the fused
`repro.kernels.ops.ebg_commit_block` kernel, which takes the scorer's
coefficient vector and weight streams. `repro.core.streaming_np` runs the
same machine in pure numpy (the test oracle, bit-identical).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import EBGConfig, GreedyConfig, HDRFConfig, check_compute_backend
from repro.api.registry import register_partitioner
from repro.core.order import degree_sum_order
from repro.core.types import Graph, PartitionResult
from repro.kernels import ops

MEMBERSHIP_TERMS = ("miss",)  # penalize endpoints absent from keep[i]
DEGREE_TERMS = ("none", "hdrf_theta")  # per-edge miss weights: 1 | 2−θ
BALANCE_MODES = ("static", "range")  # norm_e: p/|E| | 1/(eps+max−min)
TIE_POLICIES = ("lowest",)  # argmin ties -> lowest subgraph id
UPDATE_RULES = ("standard",)  # commit counters + endpoint membership


def _check(value, valid, field: str) -> None:
    if value not in valid:
        raise ValueError(f"EdgeScorer.{field} must be one of {valid}, got {value!r}")


@dataclasses.dataclass(frozen=True)
class EdgeScorer:
    """Frozen description of a streaming greedy edge-partitioner score.

    Default coefficients (`ce`/`cv`/`eps`) are overridable per call —
    e.g. `ebg`'s alpha/beta knobs are the EBV scorer's ce/cv.
    """

    name: str
    membership: str = "miss"  # replication term (see MEMBERSHIP_TERMS)
    degree_term: str = "none"  # per-edge miss weighting (DEGREE_TERMS)
    balance: str = "static"  # edge-balance normalizer (BALANCE_MODES)
    ce: float = 1.0  # edge-balance coefficient (EBV alpha, HDRF lambda)
    cv: float = 0.0  # vertex-balance coefficient (EBV beta)
    eps: float = 1.0  # range-normalizer epsilon
    tie: str = "lowest"  # argmin tie policy (TIE_POLICIES)
    update: str = "standard"  # state-update rule (UPDATE_RULES)
    sort_edges: bool = True  # default §IV-C degree-sum edge ordering
    description: str = ""

    def __post_init__(self) -> None:
        _check(self.membership, MEMBERSHIP_TERMS, "membership")
        _check(self.degree_term, DEGREE_TERMS, "degree_term")
        _check(self.balance, BALANCE_MODES, "balance")
        _check(self.tie, TIE_POLICIES, "tie")
        _check(self.update, UPDATE_RULES, "update")
        for field in ("ce", "cv", "eps"):
            v = getattr(self, field)
            if not isinstance(v, (int, float)) or not np.isfinite(v) or v < 0:
                raise ValueError(f"EdgeScorer.{field} must be finite and >= 0, got {v!r}")

    @property
    def weighted(self) -> bool:
        """Whether the replication term carries per-edge degree weights."""
        return self.degree_term != "none"

    def coefficients(self, ce=None, cv=None, eps=None) -> tuple[float, float, float]:
        """Resolve per-call coefficient overrides against the defaults."""
        return (
            float(self.ce if ce is None else ce),
            float(self.cv if cv is None else cv),
            float(self.eps if eps is None else eps),
        )


_SCORERS: dict[str, EdgeScorer] = {}


def register_scorer(scorer: EdgeScorer) -> EdgeScorer:
    """Register a scorer instance; returns it unchanged (decorator-style)."""
    if scorer.name in _SCORERS:
        raise ValueError(f"scorer {scorer.name!r} already registered")
    _SCORERS[scorer.name] = scorer
    return scorer


def get_scorer(scorer: Union[str, EdgeScorer]) -> EdgeScorer:
    if isinstance(scorer, EdgeScorer):
        return scorer
    try:
        return _SCORERS[scorer]
    except KeyError:
        raise KeyError(f"unknown scorer {scorer!r}; registered: {sorted(_SCORERS)}") from None


def scorer_names() -> tuple[str, ...]:
    return tuple(_SCORERS)


def list_scorers() -> tuple[EdgeScorer, ...]:
    return tuple(_SCORERS.values())


EBV = register_scorer(EdgeScorer(
    name="ebv",
    ce=1.0,
    cv=1.0,
    description="Paper Algorithm 1: unit membership + static p/|E|, p/|V| balance",
))
HDRF = register_scorer(EdgeScorer(
    name="hdrf",
    degree_term="hdrf_theta",
    balance="range",
    ce=1.0,
    cv=0.0,
    sort_edges=False,
    description="HDRF [Petroni'15]: 2−θ degree-weighted membership + lambda range balance",
))
GREEDY = register_scorer(EdgeScorer(
    name="greedy",
    balance="range",
    ce=1.0,
    cv=0.0,
    sort_edges=False,
    description="PowerGraph Greedy [Gonzalez'12]: A(u)∩A(v) membership + range balance",
))


def validate_edge_stream(
    src: np.ndarray,
    dst: np.ndarray,
    *,
    num_vertices: int,
    weights: Optional[np.ndarray] = None,
) -> None:
    """Validate an edge stream at partitioner intake, following the
    `Graph.validate` convention: raise ValueError naming the offending
    FIELD and the first offending ROW (stream position, pre-reorder).

    Checks: matching 1-D shapes, vertex ids in [0, num_vertices),
    no self-loops (a self-loop contributes a spurious replication miss
    to every score and the generators strip them — one arriving here is
    corrupt input, not data), and finite non-negative per-edge weights.
    """
    src = np.asarray(src)
    dst = np.asarray(dst)
    if src.ndim != 1 or src.shape != dst.shape:
        raise ValueError(
            f"src/dst must be 1-D and the same shape; got src {src.shape}, dst {dst.shape}"
        )
    for name, arr in (("src", src), ("dst", dst)):
        if not np.issubdtype(arr.dtype, np.integer):
            raise ValueError(f"{name} must be an integer array, got dtype {arr.dtype}")
        bad = np.flatnonzero((arr < 0) | (arr >= num_vertices))
        if bad.size:
            row = int(bad[0])
            raise ValueError(
                f"{name}[{row}] = {int(arr[row])} out of range "
                f"[0, num_vertices={num_vertices})"
            )
    loops = np.flatnonzero(src == dst)
    if loops.size:
        row = int(loops[0])
        raise ValueError(
            f"self-loop at edge row {row}: src[{row}] == dst[{row}] == {int(src[row])} "
            "(streaming partitioners require loop-free streams; strip self-loops first)"
        )
    if weights is not None:
        w = np.asarray(weights)
        if w.shape != src.shape:
            raise ValueError(
                f"weights must match the edge stream shape {src.shape}, got {w.shape}"
            )
        bad = np.flatnonzero(~np.isfinite(w.astype(np.float64)) | (w.astype(np.float64) < 0))
        if bad.size:
            row = int(bad[0])
            raise ValueError(
                f"weights[{row}] = {float(w[row])!r} must be finite and >= 0"
            )


def edge_weights_np(
    scorer: EdgeScorer, graph: Graph, src: np.ndarray, dst: np.ndarray
) -> Optional[tuple[np.ndarray, np.ndarray]]:
    """Per-edge replication-term weights (wu, wv) as f32 numpy, or None.

    Computed host-side from exact total degrees, so the JAX drivers and the
    numpy oracle consume the SAME arrays — degree weighting can never be a
    parity hazard. `src`/`dst` are the (possibly reordered) edge streams.
    """
    if not scorer.weighted:
        return None
    deg = graph.degrees().astype(np.float32)
    du, dv = deg[src], deg[dst]
    tot = du + dv
    wu = np.float32(2.0) - du / tot
    wv = np.float32(2.0) - dv / tot
    return wu, wv


# ------------------------------------------------------------- scan driver


@functools.partial(
    jax.jit, static_argnames=("num_parts", "num_vertices", "weighted", "balance")
)
def _streaming_scan(
    src, dst, wu, wv, *, num_parts: int, num_vertices: int,
    weighted: bool, balance: str, ce: float, cv: float, eps: float,
):
    E = src.shape[0]
    p = num_parts
    inv_e = p / jnp.float32(E)  # 1/(|E|/p)
    inv_v = p / jnp.float32(num_vertices)

    keep0 = jnp.zeros((p, num_vertices), dtype=jnp.bool_)
    e0 = jnp.zeros((p,), dtype=jnp.float32)
    v0 = jnp.zeros((p,), dtype=jnp.float32)

    def step(state, x):
        keep, e_count, v_count = state
        if weighted:
            u, v, w_u, w_v = x
        else:
            u, v = x
        mu = (~keep[:, u]).astype(jnp.float32)
        mv = (~keep[:, v]).astype(jnp.float32)
        base = w_u * mu + w_v * mv if weighted else mu + mv
        if balance == "static":
            norm = inv_e
        else:
            norm = 1.0 / (eps + (jnp.max(e_count) - jnp.min(e_count)))
        score = base + ce * e_count * norm + cv * v_count * inv_v
        i = jnp.argmin(score).astype(jnp.int32)
        e_count = e_count.at[i].add(1.0)
        v_count = v_count.at[i].add(mu[i] + mv[i])
        keep = keep.at[i, u].set(True).at[i, v].set(True)
        return (keep, e_count, v_count), i

    xs = (src, dst, wu, wv) if weighted else (src, dst)
    (keep, e_count, v_count), part = jax.lax.scan(step, (keep0, e0, v0), xs)
    return part, keep, e_count, v_count


def streaming_scan_partition(
    graph: Graph,
    num_parts: int,
    scorer: Union[str, EdgeScorer],
    *,
    ce: Optional[float] = None,
    cv: Optional[float] = None,
    eps: Optional[float] = None,
    order: Optional[np.ndarray] = None,
    sort_edges: Optional[bool] = None,
) -> PartitionResult:
    """Faithful sequential stream (one `lax.scan` step per edge) for any
    registered scorer. `ebg` ≡ scorer="ebv" with ce=alpha, cv=beta."""
    sc = get_scorer(scorer)
    ce, cv, eps = sc.coefficients(ce, cv, eps)
    if sort_edges is None:
        sort_edges = sc.sort_edges
    src = np.asarray(graph.src, dtype=np.int32)
    dst = np.asarray(graph.dst, dtype=np.int32)
    # Validate BEFORE the degree-sum reorder (which itself assumes in-range
    # ids) so offending rows are named in the caller's input order.
    validate_edge_stream(src, dst, num_vertices=graph.num_vertices)
    if order is None and sort_edges:
        order = degree_sum_order(graph)
    if order is not None:
        src, dst = src[order], dst[order]
    w = edge_weights_np(sc, graph, src, dst)
    zero = jnp.zeros((0,), jnp.float32)
    part, _, _, _ = _streaming_scan(
        jnp.asarray(src),
        jnp.asarray(dst),
        zero if w is None else jnp.asarray(w[0]),
        zero if w is None else jnp.asarray(w[1]),
        num_parts=num_parts,
        num_vertices=graph.num_vertices,
        weighted=sc.weighted,
        balance=sc.balance,
        ce=ce,
        cv=cv,
        eps=eps,
    )
    return PartitionResult(
        part=part, num_parts=num_parts, order=None if order is None else np.asarray(order)
    )


# ---------------------------------------------------------- chunked driver


def _score_commit_loop(
    e_count, v_count, mu0, mv0, valb, wub, wvb, *,
    num_parts: int, weighted: bool, balance: str, window: bool,
    ce: float, cv: float, eps: float, inv_e, inv_v,
    ub=None, vb=None,
):
    """The sequential exact in-block commit shared by every dense-membership
    block driver (the in-memory chunked scan, the out-of-core per-block
    step, and the shard_map'd sharded-state step — bit-parity between them
    is by construction, not by test alone). Scores the block's edges
    against the block-start miss tables (mu0/mv0: [p, B]), commits balance
    counters exactly and sequentially, and returns
    (e_count, v_count, parts). Pad edges (valid=False) are scored but
    never committed and route to the out-of-bounds row `num_parts`.
    `window=True` replays each commit's membership consequences onto later
    conflicted columns (needs ub/vb) — assignments bit-identical to the
    one-edge-at-a-time scan driver."""
    p = num_parts
    B = valb.shape[0]

    def body(j, carry):
        e_c, v_c, mu, mv, parts = carry
        if balance == "static":
            norm = inv_e
        else:
            norm = 1.0 / (eps + (jnp.max(e_c) - jnp.min(e_c)))
        gain = wub[j] * mu[:, j] + wvb[j] * mv[:, j] if weighted else mu[:, j] + mv[:, j]
        score = gain + ce * e_c * norm + cv * v_c * inv_v
        i = jnp.argmin(score).astype(jnp.int32)
        live = valb[j].astype(jnp.float32)
        e_c = e_c.at[i].add(live)
        v_c = v_c.at[i].add(live * (mu[i, j] + mv[i, j]))
        if window:
            # Speculative window commit: the block was scored in one
            # shot from block-start state; replay this commit onto the
            # remaining columns (clear the winner's miss rows where a
            # later edge touches the committed endpoints) so only
            # CONFLICTED edges see corrected scores — bit-identical
            # to the one-edge-at-a-time scan driver.
            hit_u = (ub == ub[j]) | (ub == vb[j])
            hit_v = (vb == ub[j]) | (vb == vb[j])
            mu = mu.at[i].set(jnp.where(hit_u & valb[j], 0.0, mu[i]))
            mv = mv.at[i].set(jnp.where(hit_v & valb[j], 0.0, mv[i]))
        return e_c, v_c, mu, mv, parts.at[j].set(jnp.where(valb[j], i, p))

    e_count, v_count, _, _, parts = jax.lax.fori_loop(
        0, B, body, (e_count, v_count, mu0, mv0, jnp.zeros((B,), jnp.int32))
    )
    return e_count, v_count, parts


@functools.partial(
    jax.jit,
    static_argnames=("num_parts", "num_vertices", "block", "backend", "weighted", "balance",
                     "window"),
)
def _streaming_chunked(
    src, dst, valid, wu, wv, num_real_edges, *, num_parts: int, num_vertices: int,
    block: int, backend: str, weighted: bool, balance: str,
    ce: float, cv: float, eps: float, window: bool = False,
):
    E = src.shape[0]
    p = num_parts
    assert E % block == 0
    # Balance terms are normalized by the REAL edge count — pad edges must
    # not dilute the ce term. Traced (not static) so graphs sharing a
    # padded shape share one compiled executable.
    inv_e = p / num_real_edges.astype(jnp.float32)
    inv_v = p / jnp.float32(num_vertices)

    e0 = jnp.zeros((p,), dtype=jnp.float32)
    v0 = jnp.zeros((p,), dtype=jnp.float32)

    if backend == "xla":
        # Dense (p, V) bool membership table, batched gathers for the score
        # phase. Kept as the A/B baseline for the bitset path below.
        keep0_state = jnp.zeros((p, num_vertices), dtype=jnp.bool_)

        def step(state, uv_block):
            keep, e_count, v_count = state
            if weighted:
                ub, vb, valb, wub, wvb = uv_block  # [B]
            else:
                ub, vb, valb = uv_block
            # Vectorized membership lookups against block-start keep: (p, B),
            # then the shared sequential exact in-block commit
            # (`_score_commit_loop`). Pad edges are scored (uniform work
            # per lane) but never committed: they leave e_count/v_count
            # untouched and route to row `p`.
            mu0 = (~keep[:, ub]).astype(jnp.float32)
            mv0 = (~keep[:, vb]).astype(jnp.float32)
            e_count, v_count, parts = _score_commit_loop(
                e_count, v_count, mu0, mv0, valb,
                wub if weighted else None, wvb if weighted else None,
                num_parts=p, weighted=weighted, balance=balance, window=window,
                ce=ce, cv=cv, eps=eps, inv_e=inv_e, inv_v=inv_v, ub=ub, vb=vb,
            )
            # Batched keep update after the block commits; pad edges carry the
            # out-of-bounds row `p` and are dropped by the scatter.
            keep = keep.at[parts, ub].set(True, mode="drop")
            keep = keep.at[parts, vb].set(True, mode="drop")
            return (keep, e_count, v_count), parts

    else:
        # Packed uint32 bitset membership (32x smaller than the dense bool
        # table: p=32, V=1M -> 4 MB, VMEM-resident for the Pallas kernel).
        # The whole block — membership score, argmin, exact balance commit,
        # bitset update — runs inside one fused ops.ebg_commit_block call
        # (ref oracle or Pallas kernel), parameterized by the scorer's
        # coefficient vector and weight streams; assignments stay identical
        # to the dense path because membership is pinned to block-start
        # state and the commit arithmetic is term-for-term the same.
        vw = (num_vertices + 31) // 32
        keep0_state = jnp.zeros((p, vw), dtype=jnp.uint32)

        def step(state, uv_block):
            keep_bits, e_count, v_count = state
            if weighted:
                ub, vb, valb, wub, wvb = uv_block  # [B]
            else:
                ub, vb, valb = uv_block
                wub = wvb = None
            keep_bits, e_count, v_count, parts = ops.ebg_commit_block(
                keep_bits, e_count, v_count, ub, vb, valb,
                alpha=ce, beta=cv, inv_e=inv_e, inv_v=inv_v,
                eps=eps, balance=balance, wu=wub, wv=wvb, impl=backend,
                window=window,
            )
            return (keep_bits, e_count, v_count), parts

    blocks = [src.reshape(-1, block), dst.reshape(-1, block), valid.reshape(-1, block)]
    if weighted:
        blocks += [wu.reshape(-1, block), wv.reshape(-1, block)]
    (keep, e_count, v_count), part = jax.lax.scan(step, (keep0_state, e0, v0), tuple(blocks))
    return part.reshape(-1), keep, e_count, v_count


def streaming_chunked_partition(
    graph: Graph,
    num_parts: int,
    scorer: Union[str, EdgeScorer],
    *,
    ce: Optional[float] = None,
    cv: Optional[float] = None,
    eps: Optional[float] = None,
    block: int = 256,
    sort_edges: Optional[bool] = None,
    compute_backend: str = "xla",
    commit: str = "frozen",
) -> PartitionResult:
    """Blocked throughput variant of the stream (block=1 ≡ faithful) for
    any registered scorer.

    compute_backend "xla" scores against the dense bool membership table;
    "ref"/"pallas" run each block through the fused packed-bitset
    `repro.kernels.ops.ebg_commit_block` — assignments are identical.

    commit="frozen" (default) scores every edge in a block against the
    block-start membership (the chunked quality/throughput trade);
    commit="window" is the speculative window commit: the block is still
    scored in one vectorized shot, but each commit replays its membership
    consequences onto the remaining in-block columns, so only conflicted
    edges are rescored and the assignments are BIT-IDENTICAL to the scan
    driver at every block size (tests/test_megakernel.py pins this for
    all registered scorers).
    """
    check_compute_backend(compute_backend)
    if commit not in ("frozen", "window"):
        raise ValueError(f"commit must be 'frozen' or 'window', got {commit!r}")
    sc = get_scorer(scorer)
    ce, cv, eps = sc.coefficients(ce, cv, eps)
    if sort_edges is None:
        sort_edges = sc.sort_edges
    src = np.asarray(graph.src, dtype=np.int32)
    dst = np.asarray(graph.dst, dtype=np.int32)
    # Validate BEFORE reorder and BEFORE the masked self-loop padding below
    # (pad rows are synthetic and exempt); rows are named in input order.
    validate_edge_stream(src, dst, num_vertices=graph.num_vertices)
    order = degree_sum_order(graph) if sort_edges else None
    if order is not None:
        src, dst = src[order], dst[order]
    w = edge_weights_np(sc, graph, src, dst)
    E = src.shape[0]
    pad = (-E) % block
    valid = np.ones((E + pad,), bool)
    if pad:
        # Pad with self-loops on vertex 0, masked out of the commit loop
        # (and dropped from the result). Pad weights are never committed;
        # 1.0 keeps the scored lanes finite.
        src = np.concatenate([src, np.zeros((pad,), np.int32)])
        dst = np.concatenate([dst, np.zeros((pad,), np.int32)])
        valid[E:] = False
        if w is not None:
            one = np.ones((pad,), np.float32)
            w = (np.concatenate([w[0], one]), np.concatenate([w[1], one]))
    zero = jnp.zeros((0,), jnp.float32)
    part, _, _, _ = _streaming_chunked(
        jnp.asarray(src),
        jnp.asarray(dst),
        jnp.asarray(valid),
        zero if w is None else jnp.asarray(w[0]),
        zero if w is None else jnp.asarray(w[1]),
        jnp.float32(E),
        num_parts=num_parts,
        num_vertices=graph.num_vertices,
        block=block,
        backend=compute_backend,
        weighted=sc.weighted,
        balance=sc.balance,
        ce=ce,
        cv=cv,
        eps=eps,
        window=commit == "window",
    )
    part = part[:E]
    return PartitionResult(part=part, num_parts=num_parts, order=order)


# ----------------------------------------------- stock scorer partitioners


@register_partitioner(
    "ebg",
    config=EBGConfig,
    deterministic=True,
    jit_compatible=True,
    scorer="ebv",
    description="Faithful EBG scan (paper Algorithm 1 + degree-sum order)",
)
def ebg_partition(
    graph: Graph,
    num_parts: int,
    *,
    alpha: float = 1.0,
    beta: float = 1.0,
    order: Optional[np.ndarray] = None,
    sort_edges: bool = True,
) -> PartitionResult:
    """Faithful EBG (Algorithm 1 + §IV-C degree-sum ordering)."""
    return streaming_scan_partition(
        graph, num_parts, EBV, ce=alpha, cv=beta, order=order, sort_edges=sort_edges
    )


@register_partitioner(
    "ebg_chunked",
    config=EBGConfig,
    deterministic=True,
    chunked=True,
    jit_compatible=True,
    benchmark_default=False,
    compute_backends=("xla", "ref", "pallas"),
    scorer="ebv",
    description="Blocked EBG throughput variant (block=1 ≡ faithful)",
)
def ebg_partition_chunked(
    graph: Graph,
    num_parts: int,
    *,
    alpha: float = 1.0,
    beta: float = 1.0,
    block: int = 256,
    sort_edges: bool = True,
    compute_backend: str = "xla",
    commit: str = "frozen",
) -> PartitionResult:
    """Blocked EBG (beyond-paper throughput variant; block=1 ≡ faithful,
    commit="window" ≡ faithful at ANY block size)."""
    return streaming_chunked_partition(
        graph, num_parts, EBV, ce=alpha, cv=beta, block=block,
        sort_edges=sort_edges, compute_backend=compute_backend, commit=commit,
    )


@register_partitioner(
    "hdrf",
    config=HDRFConfig,
    deterministic=True,
    chunked=True,
    jit_compatible=True,
    compute_backends=("xla", "ref", "pallas"),
    scorer="hdrf",
    description="HDRF [Petroni'15] on the streaming scorer core (block=1 ≡ faithful)",
)
def hdrf_partition(
    graph: Graph,
    num_parts: int,
    *,
    lam: float = 1.0,
    eps: float = 1.0,
    block: int = 256,
    sort_edges: bool = False,
    compute_backend: str = "xla",
    commit: str = "frozen",
) -> PartitionResult:
    """HDRF: highest-degree-replicated-first (paper baseline)."""
    return streaming_chunked_partition(
        graph, num_parts, HDRF, ce=lam, eps=eps, block=block,
        sort_edges=sort_edges, compute_backend=compute_backend, commit=commit,
    )


@register_partitioner(
    "greedy",
    config=GreedyConfig,
    deterministic=True,
    chunked=True,
    jit_compatible=True,
    compute_backends=("xla", "ref", "pallas"),
    scorer="greedy",
    description="PowerGraph Greedy [Gonzalez'12] on the streaming scorer core",
)
def greedy_partition(
    graph: Graph,
    num_parts: int,
    *,
    eps: float = 1.0,
    block: int = 256,
    sort_edges: bool = False,
    compute_backend: str = "xla",
    commit: str = "frozen",
) -> PartitionResult:
    """PowerGraph Greedy: A(u)∩A(v) heuristic (paper baseline)."""
    return streaming_chunked_partition(
        graph, num_parts, GREEDY, eps=eps, block=block,
        sort_edges=sort_edges, compute_backend=compute_backend, commit=commit,
    )
