"""Pure-numpy EBG oracle (test reference for the JAX implementation)."""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.order import degree_sum_order
from repro.core.types import Graph, PartitionResult


def ebg_partition_np(
    graph: Graph,
    num_parts: int,
    *,
    alpha: float = 1.0,
    beta: float = 1.0,
    order: Optional[np.ndarray] = None,
    sort_edges: bool = True,
) -> PartitionResult:
    if order is None and sort_edges:
        order = degree_sum_order(graph)
    src = np.asarray(graph.src, dtype=np.int64)
    dst = np.asarray(graph.dst, dtype=np.int64)
    if order is not None:
        src, dst = src[order], dst[order]
    E, V, p = src.shape[0], graph.num_vertices, num_parts
    keep = np.zeros((p, V), dtype=bool)
    # float32 state in the same op order as the JAX scan, so both
    # implementations resolve near-ties identically.
    e_count = np.zeros((p,), dtype=np.float32)
    v_count = np.zeros((p,), dtype=np.float32)
    part = np.empty((E,), dtype=np.int32)
    inv_e = np.float32(p) / np.float32(E)
    inv_v = np.float32(p) / np.float32(V)
    alpha = np.float32(alpha)
    beta = np.float32(beta)
    for m in range(E):
        u, v = src[m], dst[m]
        miss_u = ~keep[:, u]
        miss_v = ~keep[:, v]
        score = (
            miss_u.astype(np.float32)
            + miss_v.astype(np.float32)
            + alpha * e_count * inv_e
            + beta * v_count * inv_v
        )
        i = int(np.argmin(score))
        part[m] = i
        e_count[i] += 1
        v_count[i] += float(miss_u[i]) + float(miss_v[i])
        keep[i, u] = True
        keep[i, v] = True
    return PartitionResult(part=part, num_parts=p, order=None if order is None else np.asarray(order))
