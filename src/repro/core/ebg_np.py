"""Pure-numpy EBG oracle — legacy import path.

The reference loop now lives in `repro.core.streaming_np`, parameterized
by the same `EdgeScorer` definitions the JAX drivers consume; EBG is its
stock "ebv" instance (ce=alpha, cv=beta).
"""
from __future__ import annotations

from repro.core.streaming_np import ebg_partition_np

__all__ = ["ebg_partition_np"]
