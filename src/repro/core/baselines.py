"""Hash-family baseline partitioners from the paper: random hash, DBH, CVC.

All three are single-pass, fully vectorized (no sequential state), and run
as one fused jnp/numpy expression — the TPU-native analogue of the paper's
"simple and efficient" hash partitioners.
"""
from __future__ import annotations

import numpy as np

from repro.api.config import HashConfig
from repro.api.registry import register_partitioner
from repro.core.types import Graph, PartitionResult

_MIX = np.uint64(0x9E3779B97F4A7C15)


def _hash_u64(x: np.ndarray, seed: int = 0) -> np.ndarray:
    """splitmix64-style vectorized integer hash."""
    z = x.astype(np.uint64) + np.uint64(seed) * _MIX + _MIX
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


@register_partitioner(
    "hash",
    config=HashConfig,
    deterministic=True,
    benchmark_default=False,
    description="Random edge hashing (Giraph/PowerGraph default)",
)
def random_hash_partition(graph: Graph, num_parts: int, *, seed: int = 0) -> PartitionResult:
    """Random edge hashing (Giraph/PowerGraph default)."""
    src = np.asarray(graph.src, dtype=np.uint64)
    dst = np.asarray(graph.dst, dtype=np.uint64)
    h = _hash_u64(src * np.uint64(2654435761) + dst, seed)
    return PartitionResult(part=(h % np.uint64(num_parts)).astype(np.int32), num_parts=num_parts)


@register_partitioner(
    "dbh",
    config=HashConfig,
    deterministic=True,
    description="Degree-Based Hashing [Xie et al., NeurIPS'14]",
)
def dbh_partition(graph: Graph, num_parts: int, *, seed: int = 0) -> PartitionResult:
    """Degree-Based Hashing [Xie et al., NeurIPS'14].

    Hash the endpoint with the LOWER degree — hub (high-degree) vertices get
    cut, low-degree vertices stay whole.
    """
    src = np.asarray(graph.src)
    dst = np.asarray(graph.dst)
    deg = graph.degrees()
    lower = np.where(deg[src] <= deg[dst], src, dst)
    h = _hash_u64(lower.astype(np.uint64), seed)
    return PartitionResult(part=(h % np.uint64(num_parts)).astype(np.int32), num_parts=num_parts)


def _grid_shape(p: int) -> tuple[int, int]:
    """Closest-to-square factorization pr*pc = p."""
    pr = int(np.floor(np.sqrt(p)))
    while p % pr:
        pr -= 1
    return pr, p // pr


@register_partitioner(
    "cvc",
    config=HashConfig,
    deterministic=True,
    description="Cartesian Vertex-Cut 2D grid hashing [Boman et al., SC'13]",
)
def cvc_partition(graph: Graph, num_parts: int, *, seed: int = 0) -> PartitionResult:
    """Cartesian Vertex-Cut [Boman et al., SC'13] — 2D block partition of the
    adjacency matrix: edge (u,v) -> block (h(u) mod pr, h(v) mod pc)."""
    pr, pc = _grid_shape(num_parts)
    src = np.asarray(graph.src, dtype=np.uint64)
    dst = np.asarray(graph.dst, dtype=np.uint64)
    r = _hash_u64(src, seed) % np.uint64(pr)
    c = _hash_u64(dst, seed + 1) % np.uint64(pc)
    return PartitionResult(part=(r * np.uint64(pc) + c).astype(np.int32), num_parts=num_parts)
