"""BEYOND-PAPER: EBG applied to MoE expert→device placement.

The token→expert routing multigraph of a trained MoE is power-law (a few
hot experts dominate). Assigning experts to EP devices is the paper's
problem in miniature, and we reuse the paper's core idea — a greedy
assignment driven by an evaluation function that jointly scores
*communication* (here: co-activation affinity, the analogue of the
membership/replication term) and *balance* (here: routed-token load and
slot count, the analogues of e_count/v_count):

    Score_e(d) = gamma·(1 − affinity(e,d)/w_e)           # "miss" term
               + alpha·load[d]/(T/D)                     # load balance
               + beta·slots[d]/(E/D)                     # slot balance

Experts are processed in **descending popularity** — the mirror image of
the paper's ascending degree-sum edge order: there, low-degree edges seed
subgraphs and hubs are cut last; here, hub *experts* must be placed first
or no later placement can rebalance them (an expert is atomic — it cannot
be "cut" like an edge).

`moe_ffn(expert_perm=...)` consumes the resulting permutation, so the
standard contiguous EP sharding realizes the placement.
"""
from __future__ import annotations

import numpy as np


def ebg_expert_placement(
    pairs: np.ndarray,  # [T, 2] co-activated expert ids (top-2 routing stats)
    num_experts: int,
    num_devices: int,
    *,
    alpha: float = 1.0,
    beta: float = 1.0,
    gamma: float = 0.5,
) -> np.ndarray:
    """Returns perm[e] = new slot of expert e (device = slot // per_dev)."""
    assert num_experts % num_devices == 0
    per_dev = num_experts // num_devices
    pairs = np.asarray(pairs, dtype=np.int64)
    T, E, D = pairs.shape[0], num_experts, num_devices

    # routing stats: expert popularity + co-activation weights
    pop = np.bincount(pairs.reshape(-1), minlength=E).astype(np.float64)
    W = np.zeros((E, E), np.float64)
    np.add.at(W, (pairs[:, 0], pairs[:, 1]), 1.0)
    np.add.at(W, (pairs[:, 1], pairs[:, 0]), 1.0)

    dev_of = np.full(E, -1, np.int64)
    load = np.zeros(D, np.float64)
    slots = np.zeros(D, np.int64)
    affinity = np.zeros((E, D), np.float64)  # co-activation weight to device
    mean_load = pop.sum() / D

    for e in np.argsort(-pop):  # hot experts first (see module docstring)
        w_e = max(W[e].sum(), 1e-9)
        score = (
            gamma * (1.0 - affinity[e] / w_e)
            + alpha * load / mean_load
            + beta * slots / per_dev
        )
        score[slots >= per_dev] = np.inf  # device full
        d = int(np.argmin(score))
        dev_of[e] = d
        load[d] += pop[e]
        slots[d] += 1
        affinity[:, d] += W[:, e]

    perm = np.empty(E, np.int64)
    next_slot = np.zeros(D, np.int64)
    for e in range(E):
        d = dev_of[e]
        perm[e] = d * per_dev + next_slot[d]
        next_slot[d] += 1
    return perm


def placement_report(pairs: np.ndarray, perm: np.ndarray, num_experts: int, num_devices: int) -> dict:
    """Predicted EP traffic profile under a placement permutation."""
    per_dev = num_experts // num_devices
    dev = perm[np.asarray(pairs, np.int64)] // per_dev  # [T, 2]
    load = np.bincount(dev.reshape(-1), minlength=num_devices).astype(np.float64)
    cross = (dev[:, 0] != dev[:, 1]).mean()
    return dict(
        load_max_mean=float(load.max() / load.mean()),
        cross_frac=float(cross),
        per_device_load=load.tolist(),
    )
