"""Partition-quality metrics from the paper (§III) + message-balance metrics (§V-C)."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.types import Graph, PartitionResult


@dataclasses.dataclass(frozen=True)
class PartitionMetrics:
    replication_factor: float  # sum_i |V_i| / |V|
    edge_imbalance: float  # max_i |E_i| / (|E|/p)
    vertex_imbalance: float  # max_i |V_i| / (sum_i |V_i| / p)
    edges_per_part: np.ndarray
    vertices_per_part: np.ndarray

    def row(self) -> dict:
        return dict(
            replication_factor=round(self.replication_factor, 3),
            edge_imbalance=round(self.edge_imbalance, 3),
            vertex_imbalance=round(self.vertex_imbalance, 3),
        )


def partition_metrics(graph: Graph, result: PartitionResult) -> PartitionMetrics:
    part = result.part_in_input_order()
    p = result.num_parts
    src = np.asarray(graph.src, dtype=np.int64)
    dst = np.asarray(graph.dst, dtype=np.int64)
    V = graph.num_vertices

    e_counts = np.bincount(part, minlength=p).astype(np.int64)

    # |V_i| = #unique endpoints among edges of part i.
    keys = np.concatenate([part.astype(np.int64) * V + src, part.astype(np.int64) * V + dst])
    uniq = np.unique(keys)
    v_counts = np.bincount((uniq // V).astype(np.int64), minlength=p).astype(np.int64)

    # |V| counted over vertices actually covered by edges (isolated vertices
    # have no replicas in any edge partition).
    covered = graph.covered_vertices().shape[0]

    E = part.shape[0]
    rep = float(v_counts.sum()) / max(covered, 1)
    e_imb = float(e_counts.max()) / (E / p) if E else 1.0
    v_imb = float(v_counts.max()) / (v_counts.sum() / p) if v_counts.sum() else 1.0
    return PartitionMetrics(rep, e_imb, v_imb, e_counts, v_counts)


def max_mean_ratio(per_worker_counts: np.ndarray) -> float:
    """max/mean message-balance metric (paper Table V)."""
    c = np.asarray(per_worker_counts, dtype=np.float64)
    mean = c.mean()
    return float(c.max() / mean) if mean > 0 else 1.0


def theorem1_edge_bound(E: int, p: int, alpha: float, beta: float) -> float:
    """Worst-case edge imbalance bound (paper Theorem 1)."""
    return 1.0 + (p - 1) / E * (1 + np.floor(2 * E / (alpha * p) + (beta / alpha) * E))


def theorem2_vertex_bound(sum_vi: int, V: int, p: int, alpha: float, beta: float) -> float:
    """Worst-case vertex imbalance bound (paper Theorem 2)."""
    return 1.0 + (p - 1) / sum_vi * (1 + np.floor(2 * V / (beta * p) + (alpha / beta) * V))
