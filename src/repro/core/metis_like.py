"""METIS-style multilevel vertex partitioner (stand-in for the METIS binary).

Implements the class of algorithm the paper evaluates (and criticizes on
power-law graphs): multilevel coarsening by heavy-edge matching (vectorized
Luby-style propose/accept rounds), greedy graph-growing initial partition
balanced on VERTEX weight, and label-propagation refinement minimizing
edge-cut under a balance cap. The derived EDGE partition (each edge goes to
its source's owner) therefore balances vertices and minimizes replication,
but — on power-law graphs — produces the large edge-imbalance factors of
the paper's Table III.
"""
from __future__ import annotations

import numpy as np

from repro.api.config import MetisLikeConfig
from repro.api.registry import register_partitioner
from repro.core.types import Graph, PartitionResult


def _to_undirected_arrays(src, dst, V):
    """Deduplicated undirected weighted edge list (u < v)."""
    u = np.minimum(src, dst)
    v = np.maximum(src, dst)
    m = u != v
    key = u[m].astype(np.int64) * V + v[m]
    uk, w = np.unique(key, return_counts=True)
    return (uk // V).astype(np.int64), (uk % V).astype(np.int64), w.astype(np.int64)


def _csr(heads, tails, ww, V):
    order = np.argsort(heads, kind="stable")
    heads, tails, ww = heads[order], tails[order], ww[order]
    indptr = np.zeros(V + 1, np.int64)
    indptr[1:] = np.cumsum(np.bincount(heads, minlength=V))
    return indptr, tails, ww


def _propose_match(eu, ev, ew, V, rng, rounds: int = 4):
    """Vectorized heavy-edge matching: each vertex proposes to its heaviest
    unmatched neighbor; mutual proposals match. A few rounds per level."""
    match = np.arange(V, dtype=np.int64)  # self = unmatched
    matched = np.zeros(V, bool)
    heads = np.concatenate([eu, ev])
    tails = np.concatenate([ev, eu])
    ww = np.concatenate([ew, ew])
    for _ in range(rounds):
        live = ~(matched[heads] | matched[tails])
        if not live.any():
            break
        h, t, w = heads[live], tails[live], ww[live]
        # heaviest neighbor per head: sort by (head, weight desc, jitter)
        jitter = rng.random(h.shape[0])
        order = np.lexsort((jitter, -w, h))
        hs = h[order]
        first = np.ones(hs.shape[0], bool)
        first[1:] = hs[1:] != hs[:-1]
        propose = np.full(V, -1, np.int64)
        propose[hs[first]] = t[order][first]
        # mutual proposals match
        cand = np.flatnonzero(propose >= 0)
        mutual = cand[propose[propose[cand]] == cand]
        a = mutual[mutual < propose[mutual]]
        b = propose[a]
        match[a], match[b] = b, a
        matched[a] = matched[b] = True
    rep = np.minimum(np.arange(V), match)  # representative = smaller id
    uniq, cmap_all = np.unique(rep, return_inverse=True)
    return cmap_all.astype(np.int64), uniq.shape[0]


def _build_coarse(cmap, nc, eu, ev, ew, vw):
    hu, hv = cmap[eu], cmap[ev]
    m = hu != hv
    u = np.minimum(hu[m], hv[m])
    v = np.maximum(hu[m], hv[m])
    key = u * nc + v
    uk, inv = np.unique(key, return_inverse=True)
    ws = np.zeros(uk.shape[0], np.int64)
    np.add.at(ws, inv, ew[m])
    cvw = np.zeros(nc, np.int64)
    np.add.at(cvw, cmap, vw)
    return (uk // nc).astype(np.int64), (uk % nc).astype(np.int64), ws, cvw


def _grow_initial(eu, ev, vw, V, p, rng):
    indptr, adj, _ = _csr(np.concatenate([eu, ev]), np.concatenate([ev, eu]),
                          np.concatenate([np.ones_like(eu)] * 2), V)
    part = np.full(V, -1, np.int32)
    cap = vw.sum() / p
    unused = set(range(V))
    for k in range(p):
        if not unused:
            break
        frontier = [next(iter(unused))]
        load = 0
        while load < cap and (frontier or unused):
            if not frontier:
                frontier.append(next(iter(unused)))
            v = frontier.pop()
            if part[v] >= 0:
                continue
            part[v] = k
            unused.discard(v)
            load += vw[v]
            frontier.extend(int(n) for n in adj[indptr[v]:indptr[v + 1]] if part[n] < 0)
    for v in list(unused):
        part[v] = p - 1
    return part


def _lp_refine(eu, ev, ew, vw, part, p, passes=6, tol=1.05):
    """Vectorized label-propagation refinement with a balance cap."""
    V = vw.shape[0]
    cap = vw.sum() / p * tol
    heads = np.concatenate([eu, ev])
    tails = np.concatenate([ev, eu])
    ww = np.concatenate([ew, ew]).astype(np.int64)
    for _ in range(passes):
        conn = np.zeros((V, p), np.int64)
        np.add.at(conn, (heads, part[tails]), ww)
        cur_conn = conn[np.arange(V), part]
        tgt = conn.argmax(axis=1).astype(np.int32)
        gain = conn[np.arange(V), tgt] - cur_conn
        want = (tgt != part) & (gain > 0)
        if not want.any():
            break
        # apply moves greedily by gain, respecting the balance cap
        loads = np.bincount(part, weights=vw, minlength=p).astype(np.float64)
        idx = np.flatnonzero(want)
        idx = idx[np.argsort(-gain[idx])]
        moved = 0
        for v in idx:
            t = tgt[v]
            if loads[t] + vw[v] <= cap:
                loads[part[v]] -= vw[v]
                loads[t] += vw[v]
                part[v] = t
                moved += 1
        if moved == 0:
            break
    return part


@register_partitioner(
    "metis",
    config=MetisLikeConfig,
    deterministic=True,
    description="Multilevel METIS-style vertex partitioner (derived edge cut)",
)
def metis_like_partition(
    graph: Graph,
    num_parts: int,
    *,
    seed: int = 0,
    coarsen_to: int = 4096,
    refine_passes: int = 6,
) -> PartitionResult:
    src = np.asarray(graph.src, dtype=np.int64)
    dst = np.asarray(graph.dst, dtype=np.int64)
    V = graph.num_vertices
    rng = np.random.default_rng(seed)

    eu, ev, ew = _to_undirected_arrays(src, dst, V)
    vw = np.ones(V, np.int64)
    levels = []
    n = V
    while n > coarsen_to:
        cmap, nc = _propose_match(eu, ev, ew, n, rng)
        if nc >= n * 0.98:  # stalled
            break
        levels.append((cmap, eu, ev, ew, vw))
        eu, ev, ew, vw = _build_coarse(cmap, nc, eu, ev, ew, vw)
        n = nc

    part = _grow_initial(eu, ev, vw, n, num_parts, rng)
    part = _lp_refine(eu, ev, ew, vw, part, num_parts, refine_passes)

    for cmap, fu, fv, fw, fvw in reversed(levels):
        part = part[cmap]
        part = _lp_refine(fu, fv, fw, fvw, part, num_parts, passes=2)

    epart = part[src].astype(np.int32)
    return PartitionResult(part=epart, num_parts=num_parts)
