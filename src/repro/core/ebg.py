"""EBG — Efficient and Balanced Greedy edge partitioner (paper Algorithm 1).

Since the EdgeScorer refactor both EBG entry points live on the generic
streaming core in `repro.core.streaming`: `ebg` is the faithful
`lax.scan` stream and `ebg_chunked` the blocked throughput variant, each
a stock instance of the `"ebv"` scorer (unit membership term + static
p/|E|, p/|V| balance normalizers — the paper's evaluation function

    Score_(u,v)(i) = 1[u∉keep[i]] + 1[v∉keep[i]]
                   + alpha * e_count[i]/(|E|/p) + beta * v_count[i]/(|V|/p)

minimized with ties toward the lowest subgraph index). Assignments are
bit-identical to the pre-refactor hard-coded implementation; this module
remains as the legacy import path.
"""
from __future__ import annotations

from repro.core.streaming import ebg_partition, ebg_partition_chunked

__all__ = ["ebg_partition", "ebg_partition_chunked"]
