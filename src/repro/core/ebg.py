"""EBG — Efficient and Balanced Greedy edge partitioner (paper Algorithm 1).

Faithful JAX implementation: a `jax.lax.scan` over the degree-sum-sorted
edge stream. State is the `keep` membership bitset (p × V bool), and the
running `e_count` / `v_count` per subgraph. Each step evaluates the paper's
evaluation function

    Score_(u,v)(i) = 1[u∉keep[i]] + 1[v∉keep[i]]
                   + alpha * e_count[i]/(|E|/p) + beta * v_count[i]/(|V|/p)

over all p subgraphs at once (vectorized over i) and commits the argmin.
Ties break toward the lowest subgraph index; the paper's Appendix-B example
breaks its single tie the other way, so tests compare up to a relabeling of
subgraph ids.

`ebg_partition_chunked` is a BEYOND-PAPER throughput variant: scores for a
block of B edges are evaluated against the block-start state in one
vectorized pass (VPU/MXU-friendly), then assignments are committed exactly
and sequentially *within* the block via a small fori_loop on (p,B)-local
state. With B=1 it is exactly the faithful algorithm; with larger B the
membership term inside a block is computed against slightly stale `keep`
(the balance terms are exact), trading a small replication-factor increase
for ~B× fewer scan steps. The paper names a distributed/online extension as
future work — this is our step in that direction.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import EBGConfig, check_compute_backend
from repro.api.registry import register_partitioner
from repro.core.order import degree_sum_order
from repro.core.types import Graph, PartitionResult
from repro.kernels import ops


@functools.partial(jax.jit, static_argnames=("num_parts", "num_vertices"))
def _ebg_scan(src, dst, *, num_parts: int, num_vertices: int, alpha: float, beta: float):
    E = src.shape[0]
    p = num_parts
    inv_e = p / jnp.float32(E)  # 1/(|E|/p)
    inv_v = p / jnp.float32(num_vertices)

    keep0 = jnp.zeros((p, num_vertices), dtype=jnp.bool_)
    e0 = jnp.zeros((p,), dtype=jnp.float32)
    v0 = jnp.zeros((p,), dtype=jnp.float32)

    def step(state, uv):
        keep, e_count, v_count = state
        u, v = uv
        miss_u = ~keep[:, u]
        miss_v = ~keep[:, v]
        score = (
            miss_u.astype(jnp.float32)
            + miss_v.astype(jnp.float32)
            + alpha * e_count * inv_e
            + beta * v_count * inv_v
        )
        i = jnp.argmin(score).astype(jnp.int32)
        e_count = e_count.at[i].add(1.0)
        v_count = v_count.at[i].add(miss_u[i].astype(jnp.float32) + miss_v[i].astype(jnp.float32))
        keep = keep.at[i, u].set(True).at[i, v].set(True)
        return (keep, e_count, v_count), i

    (keep, e_count, v_count), part = jax.lax.scan(step, (keep0, e0, v0), (src, dst))
    return part, keep, e_count, v_count


@register_partitioner(
    "ebg",
    config=EBGConfig,
    deterministic=True,
    jit_compatible=True,
    description="Faithful EBG scan (paper Algorithm 1 + degree-sum order)",
)
def ebg_partition(
    graph: Graph,
    num_parts: int,
    *,
    alpha: float = 1.0,
    beta: float = 1.0,
    order: Optional[np.ndarray] = None,
    sort_edges: bool = True,
) -> PartitionResult:
    """Faithful EBG (Algorithm 1 + §IV-C degree-sum ordering)."""
    if order is None and sort_edges:
        order = degree_sum_order(graph)
    src = jnp.asarray(np.asarray(graph.src), dtype=jnp.int32)
    dst = jnp.asarray(np.asarray(graph.dst), dtype=jnp.int32)
    if order is not None:
        o = jnp.asarray(order)
        src, dst = src[o], dst[o]
    part, _, _, _ = _ebg_scan(
        src,
        dst,
        num_parts=num_parts,
        num_vertices=graph.num_vertices,
        alpha=float(alpha),
        beta=float(beta),
    )
    return PartitionResult(part=part, num_parts=num_parts, order=None if order is None else np.asarray(order))


@functools.partial(
    jax.jit, static_argnames=("num_parts", "num_vertices", "block", "backend")
)
def _ebg_chunked(
    src, dst, valid, num_real_edges, *, num_parts: int, num_vertices: int,
    alpha: float, beta: float, block: int, backend: str = "xla",
):
    E = src.shape[0]
    p = num_parts
    assert E % block == 0
    # Balance terms are normalized by the REAL edge count — pad edges must
    # not dilute the alpha term. Traced (not static) so graphs sharing a
    # padded shape share one compiled executable.
    inv_e = p / num_real_edges.astype(jnp.float32)
    inv_v = p / jnp.float32(num_vertices)

    e0 = jnp.zeros((p,), dtype=jnp.float32)
    v0 = jnp.zeros((p,), dtype=jnp.float32)

    if backend == "xla":
        # Dense (p, V) bool membership table, batched gathers for the score
        # phase. Kept as the A/B baseline for the bitset path below.
        keep0 = jnp.zeros((p, num_vertices), dtype=jnp.bool_)

        def step(state, uv_block):
            keep, e_count, v_count = state
            ub, vb, valb = uv_block  # [B]
            # Vectorized membership lookups against block-start keep: (p, B).
            miss_u = ~keep[:, ub]
            miss_v = ~keep[:, vb]
            memb = miss_u.astype(jnp.float32) + miss_v.astype(jnp.float32)

            # Sequential exact commit of balance terms within the block. Pad
            # edges are scored (uniform work per lane) but never committed:
            # they leave e_count/v_count untouched and route to row `p`.
            def body(j, carry):
                e_c, v_c, parts = carry
                score = memb[:, j] + alpha * e_c * inv_e + beta * v_c * inv_v
                i = jnp.argmin(score).astype(jnp.int32)
                live = valb[j].astype(jnp.float32)
                e_c = e_c.at[i].add(live)
                v_c = v_c.at[i].add(live * memb[i, j])
                return e_c, v_c, parts.at[j].set(jnp.where(valb[j], i, p))

            e_count, v_count, parts = jax.lax.fori_loop(
                0, ub.shape[0], body, (e_count, v_count, jnp.zeros((ub.shape[0],), jnp.int32))
            )
            # Batched keep update after the block commits; pad edges carry the
            # out-of-bounds row `p` and are dropped by the scatter.
            keep = keep.at[parts, ub].set(True, mode="drop")
            keep = keep.at[parts, vb].set(True, mode="drop")
            return (keep, e_count, v_count), parts

        keep0_state = keep0
    else:
        # Packed uint32 bitset membership (32x smaller than the dense bool
        # table: p=32, V=1M -> 4 MB, VMEM-resident for the Pallas kernel).
        # The whole block — membership score, argmin, exact balance commit,
        # bitset update — runs inside one fused ops.ebg_commit_block call
        # (ref oracle or Pallas kernel); assignments stay identical to the
        # dense path because membership is pinned to block-start state and
        # the commit arithmetic is term-for-term the same.
        vw = (num_vertices + 31) // 32
        keep0_state = jnp.zeros((p, vw), dtype=jnp.uint32)

        def step(state, uv_block):
            keep_bits, e_count, v_count = state
            ub, vb, valb = uv_block  # [B]
            keep_bits, e_count, v_count, parts = ops.ebg_commit_block(
                keep_bits, e_count, v_count, ub, vb, valb,
                alpha=alpha, beta=beta, inv_e=inv_e, inv_v=inv_v, impl=backend,
            )
            return (keep_bits, e_count, v_count), parts

    (keep, e_count, v_count), part = jax.lax.scan(
        step,
        (keep0_state, e0, v0),
        (src.reshape(-1, block), dst.reshape(-1, block), valid.reshape(-1, block)),
    )
    return part.reshape(-1), keep, e_count, v_count


@register_partitioner(
    "ebg_chunked",
    config=EBGConfig,
    deterministic=True,
    chunked=True,
    jit_compatible=True,
    benchmark_default=False,
    compute_backends=("xla", "ref", "pallas"),
    description="Blocked EBG throughput variant (block=1 ≡ faithful)",
)
def ebg_partition_chunked(
    graph: Graph,
    num_parts: int,
    *,
    alpha: float = 1.0,
    beta: float = 1.0,
    block: int = 256,
    sort_edges: bool = True,
    compute_backend: str = "xla",
) -> PartitionResult:
    """Blocked EBG (beyond-paper throughput variant; block=1 ≡ faithful).

    compute_backend "xla" scores against the dense bool membership table;
    "ref"/"pallas" score against the packed uint32 bitset via
    repro.kernels.ops.ebg_membership — assignments are identical.
    """
    check_compute_backend(compute_backend)
    order = degree_sum_order(graph) if sort_edges else None
    src = np.asarray(graph.src, dtype=np.int32)
    dst = np.asarray(graph.dst, dtype=np.int32)
    if order is not None:
        src, dst = src[order], dst[order]
    E = src.shape[0]
    pad = (-E) % block
    valid = np.ones((E + pad,), bool)
    if pad:
        # Pad with self-loops on vertex 0, masked out of the commit loop
        # (and dropped from the result).
        src = np.concatenate([src, np.zeros((pad,), np.int32)])
        dst = np.concatenate([dst, np.zeros((pad,), np.int32)])
        valid[E:] = False
    part, _, _, _ = _ebg_chunked(
        jnp.asarray(src),
        jnp.asarray(dst),
        jnp.asarray(valid),
        jnp.float32(E),
        num_parts=num_parts,
        num_vertices=graph.num_vertices,
        alpha=float(alpha),
        beta=float(beta),
        block=block,
        backend=compute_backend,
    )
    part = part[:E]
    return PartitionResult(part=part, num_parts=num_parts, order=order)
