"""Neighbor Expansion (NE) edge partitioner [Zhang et al., KDD'17].

Search-based: each partition grows from a seed vertex by repeatedly
expanding the boundary vertex with the fewest unassigned incident edges,
claiming those edges, until the partition reaches its edge capacity
|E|/p. Produces near-perfect EDGE balance but (on power-law graphs) poor
VERTEX balance — exactly the pathology Table III of the paper reports
(NE vertex imbalance 2.1–3.6 on power-law graphs).

This is a host-side (numpy + heap) reference implementation: the paper
treats NE as an offline sequential baseline and so do we.
"""
from __future__ import annotations

import heapq

import numpy as np

from repro.api.config import NEConfig
from repro.api.registry import register_partitioner
from repro.core.types import Graph, PartitionResult


@register_partitioner(
    "ne",
    config=NEConfig,
    deterministic=True,
    description="Neighbor Expansion search baseline [Zhang et al., KDD'17]",
)
def ne_partition(graph: Graph, num_parts: int, *, seed: int = 0) -> PartitionResult:
    src = np.asarray(graph.src, dtype=np.int64)
    dst = np.asarray(graph.dst, dtype=np.int64)
    E, V, p = src.shape[0], graph.num_vertices, num_parts

    # CSR over the undirected view (each directed edge indexed once; a
    # vertex's incident list contains edge ids where it is src or dst).
    ends = np.concatenate([src, dst])
    eids = np.concatenate([np.arange(E), np.arange(E)])
    order = np.argsort(ends, kind="stable")
    ends_s, eids_s = ends[order], eids[order]
    indptr = np.zeros(V + 1, dtype=np.int64)
    counts = np.bincount(ends, minlength=V)
    indptr[1:] = np.cumsum(counts)
    incident = eids_s  # incident[indptr[v]:indptr[v+1]] = edge ids at v

    part = np.full(E, -1, dtype=np.int32)
    unassigned_deg = counts.astype(np.int64).copy()
    rng = np.random.default_rng(seed)
    capacity = int(np.ceil(E / p))

    assigned_total = 0
    for k in range(p):
        remaining_parts = p - k
        target = min(capacity, int(np.ceil((E - assigned_total) / remaining_parts)))
        size = 0
        heap: list[tuple[int, int]] = []  # (unassigned_deg, vertex)
        in_boundary = np.zeros(V, dtype=bool)

        def push(v: int) -> None:
            if not in_boundary[v] and unassigned_deg[v] > 0:
                in_boundary[v] = True
                heapq.heappush(heap, (int(unassigned_deg[v]), int(v)))

        while size < target and assigned_total < E:
            # Pick expansion vertex: min unassigned degree in boundary.
            x = -1
            while heap:
                d, v = heapq.heappop(heap)
                in_boundary[v] = False
                if unassigned_deg[v] > 0:
                    if d != unassigned_deg[v]:
                        push(v)  # stale entry, reinsert with fresh key
                        continue
                    x = v
                    break
            if x < 0:
                # Fresh random seed vertex with unassigned edges.
                scan = np.flatnonzero(unassigned_deg > 0)
                if scan.size == 0:
                    break
                x = int(scan[rng.integers(0, scan.size)])
            # Claim all unassigned edges incident to x.
            for e in incident[indptr[x] : indptr[x + 1]]:
                if part[e] >= 0 or size >= target:
                    continue
                part[e] = k
                size += 1
                assigned_total += 1
                for v in (src[e], dst[e]):
                    unassigned_deg[v] -= 1
                    if v != x:
                        push(int(v))

    # Any leftovers (capacity rounding) go to the last partition.
    part[part < 0] = p - 1
    return PartitionResult(part=part, num_parts=p)
