"""repro.core — the paper's contribution: partitioners + metrics.

Partitioner modules self-register with the `repro.api` registry at import
time (see `repro.api.register_partitioner`). The `PARTITIONERS` dict
below is a *derived* backwards-compatibility view of that registry — new
code should use `repro.api.get_partitioner` / `GraphPipeline` instead.

The streaming vertex-cut family (EBV/`ebg`, HDRF, Greedy) lives on the
pluggable `EdgeScorer` core in `repro.core.streaming`, with one shared
numpy oracle in `repro.core.streaming_np`.
"""
from repro.api.registry import RegistryFunctionView
from repro.core.streaming import (
    EBV,
    GREEDY,
    HDRF,
    EdgeScorer,
    ebg_partition,
    ebg_partition_chunked,
    get_scorer,
    greedy_partition,
    hdrf_partition,
    list_scorers,
    register_scorer,
    scorer_names,
    streaming_chunked_partition,
    streaming_scan_partition,
)
from repro.core.streaming_np import ebg_partition_np, streaming_partition_np
from repro.core.baselines import cvc_partition, dbh_partition, random_hash_partition
from repro.core.ne import ne_partition
from repro.core.metis_like import metis_like_partition
from repro.core.metrics import (
    PartitionMetrics,
    max_mean_ratio,
    partition_metrics,
    theorem1_edge_bound,
    theorem2_vertex_bound,
)
from repro.core.order import degree_sum_order
from repro.core.types import Graph, PartitionResult

# DEPRECATED: kept for legacy call sites. A live Mapping over the repro.api
# registry — partitioners registered later remain visible through it.
PARTITIONERS = RegistryFunctionView()

__all__ = [
    "Graph",
    "PartitionResult",
    "PartitionMetrics",
    "PARTITIONERS",
    "EdgeScorer",
    "EBV",
    "HDRF",
    "GREEDY",
    "register_scorer",
    "get_scorer",
    "scorer_names",
    "list_scorers",
    "streaming_scan_partition",
    "streaming_chunked_partition",
    "streaming_partition_np",
    "ebg_partition",
    "ebg_partition_chunked",
    "ebg_partition_np",
    "hdrf_partition",
    "greedy_partition",
    "dbh_partition",
    "cvc_partition",
    "ne_partition",
    "metis_like_partition",
    "random_hash_partition",
    "degree_sum_order",
    "partition_metrics",
    "max_mean_ratio",
    "theorem1_edge_bound",
    "theorem2_vertex_bound",
]
