"""repro.core — the paper's contribution: partitioners + metrics."""
from repro.core.baselines import cvc_partition, dbh_partition, random_hash_partition
from repro.core.ebg import ebg_partition, ebg_partition_chunked
from repro.core.ebg_np import ebg_partition_np
from repro.core.metis_like import metis_like_partition
from repro.core.metrics import (
    PartitionMetrics,
    max_mean_ratio,
    partition_metrics,
    theorem1_edge_bound,
    theorem2_vertex_bound,
)
from repro.core.ne import ne_partition
from repro.core.order import degree_sum_order
from repro.core.types import Graph, PartitionResult

PARTITIONERS = {
    "ebg": ebg_partition,
    "ebg_chunked": ebg_partition_chunked,
    "dbh": dbh_partition,
    "cvc": cvc_partition,
    "ne": ne_partition,
    "metis": metis_like_partition,
    "hash": random_hash_partition,
}

__all__ = [
    "Graph",
    "PartitionResult",
    "PartitionMetrics",
    "PARTITIONERS",
    "ebg_partition",
    "ebg_partition_chunked",
    "ebg_partition_np",
    "dbh_partition",
    "cvc_partition",
    "ne_partition",
    "metis_like_partition",
    "random_hash_partition",
    "degree_sum_order",
    "partition_metrics",
    "max_mean_ratio",
    "theorem1_edge_bound",
    "theorem2_vertex_bound",
]
