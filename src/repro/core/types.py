"""Core graph / partition datatypes.

A graph is stored as a flat edge list (src, dst) of int32 vertex ids in
[0, num_vertices). Undirected graphs are represented by both directions
(paper §III). All partitioners consume the edge list and emit a per-edge
partition assignment in [0, num_parts) — an *edge partition* (vertex-cut),
which is what the subgraph-centric model consumes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Graph:
    """Edge-list graph. Arrays may be numpy or jax; int32 ids."""

    src: jax.Array  # [E]
    dst: jax.Array  # [E]
    num_vertices: int = dataclasses.field(metadata=dict(static=True))

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])

    def degrees(self) -> np.ndarray:
        """Total (in+out) degree per vertex, numpy."""
        src = np.asarray(self.src)
        dst = np.asarray(self.dst)
        deg = np.bincount(src, minlength=self.num_vertices)
        deg += np.bincount(dst, minlength=self.num_vertices)
        return deg.astype(np.int64)

    def covered_vertices(self) -> np.ndarray:
        """Sorted unique vertices incident to at least one edge. Isolated
        vertices have no replicas in any edge partition, so coverage is the
        domain for replication metrics, CC labels, and SSSP sources."""
        return np.unique(np.concatenate([np.asarray(self.src), np.asarray(self.dst)]))

    def validate(self) -> None:
        """Raise ValueError naming the offending field on malformed graphs
        (real exceptions, not `assert`s — they survive `python -O`)."""
        src = np.asarray(self.src)
        dst = np.asarray(self.dst)
        if src.ndim != 1 or src.shape != dst.shape:
            raise ValueError(
                f"src/dst must be 1-D and the same shape; got src {src.shape}, dst {dst.shape}"
            )
        for name, arr in (("src", src), ("dst", dst)):
            if arr.min(initial=0) < 0:
                raise ValueError(f"{name} has negative vertex id {int(arr.min())}")
            if arr.max(initial=-1) >= self.num_vertices:
                raise ValueError(
                    f"{name} has vertex id {int(arr.max())} >= num_vertices={self.num_vertices}"
                )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PartitionResult:
    """Result of an edge partitioner."""

    part: jax.Array  # [E] int32 in [0, num_parts)
    num_parts: int = dataclasses.field(metadata=dict(static=True))
    # Optional permutation applied to edges before assignment (EBG sorts
    # edges by degree-sum); part[i] corresponds to edge order[i] of the
    # ORIGINAL edge list when order is not None.
    order: Optional[jax.Array] = None

    def part_in_input_order(self) -> np.ndarray:
        """Per-edge assignment aligned with the original edge list."""
        part = np.asarray(self.part)
        if self.order is None:
            return part
        out = np.empty_like(part)
        out[np.asarray(self.order)] = part
        return out


def edge_weights_placeholder(num_edges: int) -> np.ndarray:
    """Unit weights (paper's graphs are unweighted; SSSP uses unit/1.0)."""
    return np.ones((num_edges,), dtype=np.float32)
