"""Out-of-core streaming partition driver over an `EdgeShardStore`.

Feeds sharded edge files through the SAME chunked block-commit machinery
as the in-memory driver (`repro.core.streaming`), one block at a time:
the per-block score/commit arithmetic is the shared
`streaming._score_commit_loop` (dense path) or the fused
`ops.ebg_commit_block` kernel (bitset path), so `out_of_core ≡
in_memory` assignments are bit-identical by construction whenever the
edge stream order matches — and it does: `edgeshards.degree_sum_stream`
reproduces the §IV-C in-memory permutation exactly.

Partition state, not the edge list, is what stays resident:

  state_layout="replicated"  one device holds the whole membership table
                             (dense bool for "xla", packed uint32 bitset
                             for "ref"/"pallas" — p×⌈V/32⌉, 32x smaller).
  state_layout="sharded"     membership rows laid out along the worker
                             axis via shard_map (repro.compat +
                             launch.mesh): each device holds p/d rows,
                             scores its rows locally, and an all_gather
                             of the per-block miss tables feeds the same
                             replicated commit loop — assignments
                             bit-identical to the replicated layout.

Memory: O(p·V/32 + block) for the bitset layout, O(p·V/d + block) per
device for the sharded layout; the edge list itself never materializes
(blocks stream from disk, the per-edge assignment is the only O(E) array
kept, int32).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Iterator, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import check_commit_mode, check_compute_backend
from repro.core import streaming
from repro.core.streaming import EdgeScorer, get_scorer, validate_edge_stream
from repro.core.types import PartitionResult
from repro.data.edgeshards import (
    EdgeShardStore,
    OrderedEdgeStream,
    degree_sum_stream,
    degrees_from_shards,
)
from repro.kernels import ops

STATE_LAYOUTS = ("replicated", "sharded")


def check_state_layout(layout) -> str:
    if layout not in STATE_LAYOUTS:
        raise ValueError(f"state_layout must be one of {STATE_LAYOUTS}, got {layout!r}")
    return layout


# ----------------------------------------------------- per-block jit steps


@functools.partial(
    jax.jit,
    static_argnames=("num_parts", "num_vertices", "backend", "weighted", "balance", "window"),
    donate_argnums=(0, 1, 2),
)
def _oc_block_step(
    keep, e_count, v_count, ub, vb, valb, wub, wvb, inv_e, ce, cv, eps, *,
    num_parts: int, num_vertices: int, backend: str, weighted: bool, balance: str,
    window: bool,
):
    """One streamed block against resident state — the same score/commit
    code paths as `streaming._streaming_chunked`, jitted per block with the
    state buffers donated (the carry never copies)."""
    p = num_parts
    inv_v = p / jnp.float32(num_vertices)
    if backend == "xla":
        mu0 = (~keep[:, ub]).astype(jnp.float32)
        mv0 = (~keep[:, vb]).astype(jnp.float32)
        e_count, v_count, parts = streaming._score_commit_loop(
            e_count, v_count, mu0, mv0, valb,
            wub if weighted else None, wvb if weighted else None,
            num_parts=p, weighted=weighted, balance=balance, window=window,
            ce=ce, cv=cv, eps=eps, inv_e=inv_e, inv_v=inv_v, ub=ub, vb=vb,
        )
        keep = keep.at[parts, ub].set(True, mode="drop")
        keep = keep.at[parts, vb].set(True, mode="drop")
        return keep, e_count, v_count, parts
    keep, e_count, v_count, parts = ops.ebg_commit_block(
        keep, e_count, v_count, ub, vb, valb,
        alpha=ce, beta=cv, inv_e=inv_e, inv_v=inv_v, eps=eps, balance=balance,
        wu=wub if weighted else None, wv=wvb if weighted else None,
        impl=backend, window=window,
    )
    return keep, e_count, v_count, parts


def _make_sharded_step(
    mesh, axis: str, *, num_parts: int, num_vertices: int, weighted: bool,
    balance: str, window: bool,
):
    """shard_map'd block step: membership rows sharded over `axis`, an
    extra per-device dump row absorbing commits owned by other devices.
    The per-block miss tables are all_gather'd so every device runs the
    IDENTICAL `_score_commit_loop` (replicated compute, sharded state) —
    assignments are bit-identical to the replicated dense path."""
    from repro.compat import shard_map_compat

    p = num_parts

    def step(keep_local, e_count, v_count, ub, vb, valb, wub, wvb, inv_e, ce, cv, eps):
        # keep_local: [p_local + 1, V] (last row = dump); counters replicated.
        p_local = keep_local.shape[0] - 1
        inv_v = p / jnp.float32(num_vertices)
        mu_l = (~keep_local[:p_local, ub]).astype(jnp.float32)
        mv_l = (~keep_local[:p_local, vb]).astype(jnp.float32)
        mu0 = jax.lax.all_gather(mu_l, axis, axis=0, tiled=True)  # [p, B]
        mv0 = jax.lax.all_gather(mv_l, axis, axis=0, tiled=True)
        e_count, v_count, parts = streaming._score_commit_loop(
            e_count, v_count, mu0, mv0, valb,
            wub if weighted else None, wvb if weighted else None,
            num_parts=p, weighted=weighted, balance=balance, window=window,
            ce=ce, cv=cv, eps=eps, inv_e=inv_e, inv_v=inv_v, ub=ub, vb=vb,
        )
        # Commit this device's rows; foreign rows (and the pad row p) land
        # in the local dump row.
        off = jax.lax.axis_index(axis) * p_local
        local = parts - off
        tgt = jnp.where((local >= 0) & (local < p_local), local, p_local)
        keep_local = keep_local.at[tgt, ub].set(True)
        keep_local = keep_local.at[tgt, vb].set(True)
        return keep_local, e_count, v_count, parts

    from jax.sharding import PartitionSpec as P

    sharded = shard_map_compat(
        step,
        mesh=mesh,
        in_specs=(P(axis), P(), P(), P(), P(), P(), P(), P(), P(), P(), P(), P()),
        out_specs=(P(axis), P(), P(), P()),
    )
    return jax.jit(sharded, donate_argnums=(0, 1, 2))


# --------------------------------------------------------------- the driver


@dataclasses.dataclass(frozen=True)
class OutOfCoreResult:
    """Out-of-core partition output. `result.part` is aligned with the
    streamed (possibly degree-sum-ordered) edge order; `result.order`
    carries the original store positions, so `part_in_input_order()`
    recovers store alignment. `edge_part_stream` re-streams
    (src, dst, part) blocks in partition order — what the streamed
    builder (`repro.graph.build_stream`) consumes."""

    result: PartitionResult
    e_count: np.ndarray  # [p] f32 committed edge counts
    v_count: np.ndarray  # [p] f32 committed new-vertex counts (= |V(i)|)
    covered: int  # vertices with degree > 0
    num_blocks: int
    edge_part_stream: Callable[[int], Iterator[tuple[np.ndarray, np.ndarray, np.ndarray]]]

    @property
    def replication_factor(self) -> float:
        """Paper RF: total vertex replicas over covered vertices — exact,
        from the commit counters alone (no part array scan)."""
        return float(self.v_count.sum() / max(self.covered, 1))


def partition_store(
    store: EdgeShardStore,
    num_parts: int,
    scorer: Union[str, EdgeScorer] = "ebv",
    *,
    ce: Optional[float] = None,
    cv: Optional[float] = None,
    eps: Optional[float] = None,
    block: int = 4096,
    sort_edges: Optional[bool] = None,
    compute_backend: str = "xla",
    commit: str = "frozen",
    state_layout: str = "replicated",
    mesh=None,
    degrees: Optional[np.ndarray] = None,
    ordered: Optional[OrderedEdgeStream] = None,
    order_workdir=None,
    validate: bool = True,
) -> OutOfCoreResult:
    """Partition a sharded on-disk edge store without materializing its
    edge list: blocks stream from disk through the chunked commit machinery
    (same arithmetic as `streaming_chunked_partition`, so results on a
    small graph are bit-identical to the in-memory driver given the same
    stream order — and the external degree-sum sort emits exactly the
    in-memory §IV-C order).

    `compute_backend` picks the membership state: "xla" dense bool,
    "ref"/"pallas" packed uint32 bitsets through `ops.ebg_commit_block`.
    `state_layout="sharded"` shards the dense membership rows over a mesh
    worker axis (requires compute_backend="xla"; `mesh` defaults to
    `launch.mesh.make_host_mesh()`); num_parts must divide evenly over
    the mesh devices. `commit` is the chunked commit mode ("window" makes
    any block size bit-identical to the one-edge scan). Pass precomputed
    `degrees` / an `ordered` stream to reuse external passes.
    """
    check_compute_backend(compute_backend)
    check_commit_mode(commit)
    check_state_layout(state_layout)
    sc = get_scorer(scorer)
    ce, cv, eps = sc.coefficients(ce, cv, eps)
    if sort_edges is None:
        sort_edges = sc.sort_edges
    if block < 1:
        raise ValueError(f"block must be >= 1, got {block}")
    p = int(num_parts)
    V = store.num_vertices
    E = store.num_edges
    if V > np.iinfo(np.int32).max:
        raise ValueError(
            f"streaming state addresses vertices in int32: num_vertices={V} >= 2^31"
        )
    if degrees is None and (sort_edges or sc.weighted):
        degrees = degrees_from_shards(store)
    deg32 = degrees.astype(np.float32) if sc.weighted else None

    if sort_edges:
        if ordered is None:
            ordered = degree_sum_stream(store, degrees, workdir=order_workdir)
        block_iter = lambda b: ordered.iter_blocks(b)  # noqa: E731
    else:
        block_iter = lambda b: store.iter_blocks(b)  # noqa: E731

    window = commit == "window"
    if state_layout == "sharded":
        if compute_backend != "xla":
            raise ValueError(
                "state_layout='sharded' shards the dense membership table; "
                f"it requires compute_backend='xla', got {compute_backend!r}"
            )
        if mesh is None:
            from repro.launch.mesh import make_host_mesh

            mesh = make_host_mesh()
        axis = mesh.axis_names[0]
        ndev = int(np.prod(mesh.devices.shape))
        if p % ndev != 0:
            raise ValueError(f"num_parts={p} must divide evenly over {ndev} mesh devices")
        step = _make_sharded_step(
            mesh, axis, num_parts=p, num_vertices=V, weighted=sc.weighted,
            balance=sc.balance, window=window,
        )
        keep = jnp.zeros((p + ndev, V), jnp.bool_)  # p rows + one dump row per device
    else:
        step = functools.partial(
            _oc_block_step, num_parts=p, num_vertices=V, backend=compute_backend,
            weighted=sc.weighted, balance=sc.balance, window=window,
        )
        if compute_backend == "xla":
            keep = jnp.zeros((p, V), jnp.bool_)
        else:
            keep = jnp.zeros((p, (V + 31) // 32), jnp.uint32)

    e_count = jnp.zeros((p,), jnp.float32)
    v_count = jnp.zeros((p,), jnp.float32)
    inv_e = jnp.float32(p) / jnp.float32(E)
    one = np.ones((block,), np.float32)
    zero_w = jnp.zeros((0,), jnp.float32)
    parts_out: list[np.ndarray] = []
    order_out: list[np.ndarray] = []
    num_blocks = 0

    for bsrc, bdst, bidx in block_iter(block):
        n = bsrc.shape[0]
        if validate:
            validate_edge_stream(bsrc, bdst, num_vertices=V)
        ub = np.zeros(block, np.int32)
        vb = np.zeros(block, np.int32)
        ub[:n] = bsrc
        vb[:n] = bdst
        valb = np.zeros(block, bool)
        valb[:n] = True
        if sc.weighted:
            # Same f32 formula as streaming.edge_weights_np, blockwise.
            du, dv = deg32[bsrc], deg32[bdst]
            tot = du + dv
            wub, wvb = one.copy(), one.copy()
            wub[:n] = np.float32(2.0) - du / tot
            wvb[:n] = np.float32(2.0) - dv / tot
            wub, wvb = jnp.asarray(wub), jnp.asarray(wvb)
        else:
            wub = wvb = zero_w
        keep, e_count, v_count, parts = step(
            keep, e_count, v_count, jnp.asarray(ub), jnp.asarray(vb), jnp.asarray(valb),
            wub, wvb, inv_e, jnp.float32(ce), jnp.float32(cv), jnp.float32(eps),
        )
        parts_out.append(np.asarray(parts[:n], np.int32))
        order_out.append(np.asarray(bidx, np.int64))
        num_blocks += 1

    part_np = np.concatenate(parts_out) if parts_out else np.zeros(0, np.int32)
    order_np = np.concatenate(order_out) if order_out else np.zeros(0, np.int64)
    e_np, v_np = np.asarray(e_count), np.asarray(v_count)
    covered = int((degrees > 0).sum()) if degrees is not None else int(
        np.unique(np.concatenate([s for s, _ in store.iter_shards()] or [np.zeros(0)])).size
    )
    result = PartitionResult(
        part=part_np, num_parts=p, order=order_np if sort_edges else None
    )

    def edge_part_stream(b: int) -> Iterator[tuple[np.ndarray, np.ndarray, np.ndarray]]:
        off = 0
        for s, d, _ in block_iter(b):
            yield s, d, part_np[off: off + s.shape[0]].astype(np.int64)
            off += s.shape[0]

    return OutOfCoreResult(
        result=result,
        e_count=e_np,
        v_count=v_np,
        covered=covered,
        num_blocks=num_blocks,
        edge_part_stream=edge_part_stream,
    )
