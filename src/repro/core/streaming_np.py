"""Pure-numpy oracle for the streaming EdgeScorer core.

ONE reference loop covers every registered scorer (EBV, HDRF, Greedy, and
custom instances): float32 state mutated in the same op order as the JAX
drivers in `repro.core.streaming`, so both implementations resolve
near-ties identically and the parity tests can assert exact equality.
"""
from __future__ import annotations

from typing import Optional, Union

import numpy as np

from repro.core.order import degree_sum_order
from repro.core.streaming import EdgeScorer, edge_weights_np, get_scorer
from repro.core.types import Graph, PartitionResult


def streaming_partition_np(
    graph: Graph,
    num_parts: int,
    scorer: Union[str, EdgeScorer],
    *,
    ce: Optional[float] = None,
    cv: Optional[float] = None,
    eps: Optional[float] = None,
    order: Optional[np.ndarray] = None,
    sort_edges: Optional[bool] = None,
) -> PartitionResult:
    sc = get_scorer(scorer)
    ce, cv, eps = sc.coefficients(ce, cv, eps)
    if sort_edges is None:
        sort_edges = sc.sort_edges
    if order is None and sort_edges:
        order = degree_sum_order(graph)
    src = np.asarray(graph.src, dtype=np.int64)
    dst = np.asarray(graph.dst, dtype=np.int64)
    if order is not None:
        src, dst = src[order], dst[order]
    E, V, p = src.shape[0], graph.num_vertices, num_parts
    w = edge_weights_np(sc, graph, src, dst)
    keep = np.zeros((p, V), dtype=bool)
    # float32 state in the same op order as the JAX scan, so both
    # implementations resolve near-ties identically.
    e_count = np.zeros((p,), dtype=np.float32)
    v_count = np.zeros((p,), dtype=np.float32)
    part = np.empty((E,), dtype=np.int32)
    inv_e = np.float32(p) / np.float32(E)
    inv_v = np.float32(p) / np.float32(V)
    ce = np.float32(ce)
    cv = np.float32(cv)
    eps = np.float32(eps)
    static = sc.balance == "static"
    for m in range(E):
        u, v = src[m], dst[m]
        mu = (~keep[:, u]).astype(np.float32)
        mv = (~keep[:, v]).astype(np.float32)
        base = w[0][m] * mu + w[1][m] * mv if w is not None else mu + mv
        norm = inv_e if static else np.float32(1.0) / (eps + (e_count.max() - e_count.min()))
        score = base + ce * e_count * norm + cv * v_count * inv_v
        i = int(np.argmin(score))
        part[m] = i
        e_count[i] += 1
        v_count[i] += mu[i] + mv[i]
        keep[i, u] = True
        keep[i, v] = True
    return PartitionResult(part=part, num_parts=p, order=None if order is None else np.asarray(order))


def ebg_partition_np(
    graph: Graph,
    num_parts: int,
    *,
    alpha: float = 1.0,
    beta: float = 1.0,
    order: Optional[np.ndarray] = None,
    sort_edges: bool = True,
) -> PartitionResult:
    """EBV oracle — the generic loop with the stock "ebv" scorer."""
    return streaming_partition_np(
        graph, num_parts, "ebv", ce=alpha, cv=beta, order=order, sort_edges=sort_edges
    )
