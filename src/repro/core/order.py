"""Edge processing order for EBG (paper §IV-C).

Edges are sorted ascending by the sum of their end-vertices' total degrees,
so low-degree edges seed the subgraphs and high-degree hubs are cut late.
Ties are broken by original edge index (stable sort) to match the paper's
worked example (Appendix B) deterministically.
"""
from __future__ import annotations

import numpy as np

from repro.core.types import Graph


def degree_sum_order(graph: Graph) -> np.ndarray:
    """Return a permutation of edge indices, ascending by degree-sum."""
    deg = graph.degrees()
    src = np.asarray(graph.src)
    dst = np.asarray(graph.dst)
    key = deg[src] + deg[dst]
    return np.argsort(key, kind="stable").astype(np.int64)
